"""Push-Sum de-biasing primitives (Kempe et al. 2003; Assran et al. 2019).

Under a column-stochastic mixing matrix the iterates ``x_i`` are biased —
``sum_j P[i, j] != 1`` in general.  Each client therefore tracks a scalar
push-sum weight ``w_i`` (init 1) mixed with the *same* matrix; the ratio
``z_i = x_i / w_i`` is the de-biased parameter.  Mass conservation gives
``sum_i w_i = n`` for all t, and ``z_i -> (1/n) sum_j x_j`` under repeated
mixing of a B-strongly-connected graph sequence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "gossip",
    "gossip_bank",
    "gossip_weights",
    "debias",
    "debias_bank",
    "consensus_error",
    "consensus_error_bank",
]


def gossip(P, stacked_params, use_kernel: bool | None = None):
    """One mixing step ``X' = P @ X`` applied leaf-wise to a client-stacked
    pytree (every leaf has leading dim n).  ``P`` may be the dense matrix
    or a :class:`~repro.core.topology.NeighborList`; backend selection is
    shared with the bank path via :func:`repro.kernels.ops.gossip_mix` /
    ``gossip_mix_sparse``; pass ``use_kernel=False`` to pin the kernel-free
    oracle."""
    from repro.core.topology import NeighborList
    from repro.kernels import ops as kops

    def mix(x):
        flat = x.reshape(x.shape[0], -1)
        if isinstance(P, NeighborList):
            out = kops.gossip_mix_sparse(P.idx, P.wgt, flat, use_kernel)
        else:
            out = kops.gossip_mix(P, flat, use_kernel)
        return out.reshape(x.shape)

    return jax.tree.map(mix, stacked_params)


def gossip_bank(P, X: jnp.ndarray,
                use_kernel: bool | None = None) -> jnp.ndarray:
    """One mixing step ``X' = P @ X`` on the flat (n, D) parameter bank —
    the entire model in a single matmul, or a single O(n * k_max * D)
    neighbor gather when ``P`` is a
    :class:`~repro.core.topology.NeighborList`.  Backend selection is
    shared with the pytree path via :func:`repro.kernels.ops.gossip_mix` /
    ``gossip_mix_sparse`` (the Pallas kernel whenever the bank is big
    enough to amortize it).  A :class:`~repro.core.topology.TwoTierOp`
    splits into a shard-local batched intra-pod matmul plus one sparse
    cross-pod gather — under a row-sharded bank the intra term never
    leaves its device and the gather is the round's only collective."""
    from repro.core.topology import NeighborList, TwoTierOp
    from repro.kernels import ops as kops

    if isinstance(P, TwoTierOp):
        n, D = X.shape
        n_pods, ps, _ = P.intra.shape
        intra = jnp.einsum(
            "pij,pjd->pid", P.intra, X.reshape(n_pods, ps, D).astype(
                jnp.float32),
            precision=jax.lax.Precision.HIGHEST,
        ).reshape(n, D).astype(X.dtype)
        inter = kops.gossip_mix_sparse(
            P.inter.idx, P.inter.wgt, X, use_kernel)
        return intra + inter
    if isinstance(P, NeighborList):
        return kops.gossip_mix_sparse(P.idx, P.wgt, X, use_kernel)
    return kops.gossip_mix(P, X, use_kernel)


def gossip_weights(P, w: jnp.ndarray) -> jnp.ndarray:
    """Mix the push-sum weights: ``w' = P @ w`` (shape (n,)) — the same
    neighbor gather as the bank when ``P`` is a NeighborList, so the full
    push-sum round never materializes (n, n).  The dense path pins
    ``Precision.HIGHEST`` exactly like the bank matmul in
    ``repro.kernels.ops.gossip_mix``: on TPU a default-precision ``P @ w``
    would run the weight mixing in bf16 while the bank mixes in f32,
    drifting the de-bias ratio z = x / w between the two."""
    from repro.core.topology import NeighborList, TwoTierOp

    if isinstance(P, TwoTierOp):
        n_pods, ps, _ = P.intra.shape
        wf = w.astype(jnp.float32)
        intra = jnp.einsum(
            "pij,pj->pi", P.intra, wf.reshape(n_pods, ps),
            precision=jax.lax.Precision.HIGHEST,
        ).reshape(-1)
        inter = jnp.sum(P.inter.wgt * wf[P.inter.idx], axis=1)
        return (intra + inter).astype(w.dtype)
    if isinstance(P, NeighborList):
        wf = w.astype(jnp.float32)
        return jnp.sum(P.wgt * wf[P.idx], axis=1).astype(w.dtype)
    out = jnp.einsum(
        "ij,j->i", P, w.astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST,
    )
    return out.astype(w.dtype)


def debias(stacked_params, w: jnp.ndarray):
    """z_i = x_i / w_i, broadcasting the per-client scalar across leaves."""

    def div(x):
        shape = (x.shape[0],) + (1,) * (x.ndim - 1)
        return x / w.reshape(shape).astype(x.dtype)

    return jax.tree.map(div, stacked_params)


def debias_bank(X: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """z_i = x_i / w_i on the flat (n, D) bank."""
    return X / w[:, None].astype(X.dtype)


def consensus_error_bank(X: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Flat-bank equivalent of :func:`consensus_error`."""
    z = debias_bank(X, w)
    mean = X.mean(axis=0, keepdims=True)
    return jnp.sum((z - mean) ** 2) / X.shape[0]


def consensus_error(stacked_params, w: jnp.ndarray) -> jnp.ndarray:
    """Mean squared distance of de-biased params from the true average
    (the quantity bounded by Lemma 4)."""
    z = debias(stacked_params, w)

    def leaf_err(x, zx):
        mean = x.mean(axis=0, keepdims=True)
        return jnp.sum((zx - mean) ** 2) / x.shape[0]

    errs = jax.tree.map(leaf_err, stacked_params, z)
    return jax.tree.reduce(jnp.add, errs)
