"""The composable round program: a pure ``init``/``step`` core over stages.

``make_program`` wires a (LocalSolver, Compressor, Mixer) composition —
usually resolved from an ``AlgoConfig`` via ``repro.core.stages`` — into a
:class:`RoundProgram` whose

    state           = program.init(key)          # FLState
    state, metrics  = program.step(state)        # one communication round
    state, history  = program.run(state, rounds) # lax.scan over step

are plain jittable functions of traced state only (topology, data, and the
stage composition are closed over as constants), optax-style.  Callers can
``jax.jit(program.step, donate_argnums=0)`` to update the (n, D) banks in
place, or scan whole training runs inside one jit.  ``FLTrainer`` in
``repro.core.engine`` is a thin stateful wrapper around exactly this API.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import topology
from repro.core.flat import BankSpec, make_spec
from repro.core.stages import IdentityCompressor, make_stages

__all__ = ["FLState", "RoundProgram", "make_program"]


class FLState(NamedTuple):
    """Full round state — everything a warm restart needs."""

    params: Any  # flat (n, D) bank / (D,) central row; pytree when flat=False
    # End-of-round momentum bank, (n, D) float32 (None on the legacy path).
    # Algorithm 1 re-initializes v to zero each round, so training never
    # reads it back — it is carried for observability and checkpoint/warm-
    # restart of momentum-persistent variants.
    mom: Any
    w: jnp.ndarray  # (n,) push-sum weights (all-ones when unused)
    key: jax.Array
    round: jnp.ndarray  # int32 scalar
    losses: jnp.ndarray  # (n,) last local losses (drives selection)
    comp: Any = ()  # compressor state (e.g. error-feedback residual bank)


@dataclasses.dataclass(frozen=True)
class RoundProgram:
    """One federated-optimization algorithm as a stage composition.

    All fields are trace-time constants; ``init``/``step``/``run`` below are
    the only functions of traced values.
    """

    solver: Any
    compressor: Any
    mixer: Any
    loss_fn: Callable
    init_fn: Callable
    data: Any  # client-stacked pytree, leading dims (n_clients, m, ...)
    topo: topology.TopologyConfig
    spec: BankSpec
    n: int
    participation: float
    lr: float
    lr_decay: float
    selection: bool
    exp_cycle: Any  # (hops, n, n) stack for time-varying exponential graphs

    # -- pure state constructor ---------------------------------------------

    def init(self, key: jax.Array) -> FLState:
        pkey, skey = jax.random.split(key)
        params0 = self.init_fn(pkey)
        w0 = self.mixer.init_weights(self.n)
        losses0 = jnp.zeros((self.n,), jnp.float32)
        if self.mixer.kind == "central":
            row = self.spec.ravel(params0)
            return FLState(row, None, w0, skey, jnp.int32(0), losses0, ())
        row = self.spec.ravel(params0)
        bank = jnp.broadcast_to(row, (self.n, self.spec.dim))
        mom = jnp.zeros((self.n, self.spec.dim), jnp.float32)
        comp = self.compressor.init_state(self.n, self.spec.dim)
        return FLState(bank, mom, w0, skey, jnp.int32(0), losses0, comp)

    # -- mixing-matrix selection --------------------------------------------

    def mixing_matrix(self, tkey: jax.Array, state: FLState) -> jnp.ndarray:
        k_link = max(int(self.participation * self.n), 1)
        if self.mixer.kind == "symmetric":
            return topology.sample_symmetric_k_regular(tkey, self.n, k_link)
        if self.selection:
            return topology.sample_kout_selective(
                tkey, state.losses, self.n, k_link
            )
        if self.exp_cycle is not None:
            # Time-varying exponential graph: round t uses cycle[t % hops].
            hops = self.exp_cycle.shape[0]
            return self.exp_cycle[jnp.mod(state.round, hops)]
        return topology.sample_mixing(tkey, self.topo, t=0)

    # -- one communication round --------------------------------------------

    def step(self, state: FLState):
        lr = self.lr * self.lr_decay ** state.round.astype(jnp.float32)
        keys = jax.random.split(state.key, 2 + self.n)
        key, tkey, ckeys = keys[0], keys[1], keys[2:]
        if self.mixer.kind == "central":
            return self._central_step(state, lr, key, tkey, ckeys)

        X, V, losses, accs = self.solver.update(
            self.loss_fn, self.spec, state.params, state.w, ckeys,
            self.data, lr
        )
        comp, X = self.compressor.apply(state.comp, X)
        P = self.mixing_matrix(tkey, state)
        X, w_new = self.mixer.mix(P, X, state.w)
        new_state = FLState(
            X, V, w_new, key, state.round + 1, losses, comp
        )
        return new_state, {"loss": losses.mean(), "acc": accs.mean()}

    def _central_step(self, state: FLState, lr, key, tkey, ckeys):
        m = max(int(self.participation * self.n), 1)
        sel = jax.random.permutation(tkey, self.n)[:m]
        data_sel = jax.tree.map(lambda d: d[sel], self.data)
        Xrep = jnp.broadcast_to(state.params, (m,) + state.params.shape)
        ones = jnp.ones((m,), jnp.float32)
        X, _, losses, accs = self.solver.update(
            self.loss_fn, self.spec, Xrep, ones, ckeys[:m], data_sel, lr
        )
        new_params = self.mixer.reduce(X)
        new_state = FLState(
            new_params, state.mom, state.w, key, state.round + 1,
            state.losses, state.comp
        )
        return new_state, {"loss": losses.mean(), "acc": accs.mean()}

    # -- whole training runs inside one jit ---------------------------------

    def run(self, state: FLState, rounds: int):
        """``lax.scan`` ``rounds`` steps; returns (state, stacked metrics)."""
        return jax.lax.scan(
            lambda s, _: self.step(s), state, None, length=rounds
        )


def make_program(
    loss_fn: Callable,
    init_fn: Callable,
    client_data,
    algo,
    topo: topology.TopologyConfig,
    participation: float = 0.1,
) -> RoundProgram:
    """Compose an ``AlgoConfig`` into a :class:`RoundProgram`.

    The bank spec is built from ``jax.eval_shape`` of ``init_fn`` — no
    parameters are materialized here; ``program.init`` owns that.
    """
    solver, compressor, mixer = make_stages(algo)
    if mixer.kind == "central" and not isinstance(
        compressor, IdentityCompressor
    ):
        # The central round has no gossip step to compress; silently
        # training uncompressed would misreport communication savings.
        raise ValueError(
            "central (server) rounds do not model compressed communication; "
            f"drop compressor={algo.compressor!r}/quantize_gossip"
        )
    spec = make_spec(jax.eval_shape(init_fn, jax.random.PRNGKey(0)))
    # Exponential graphs cycle through log2(n) hop matrices; precompute
    # the stack once so the (traced) round index can select the graph.
    exp_cycle = (
        topology.exponential_cycle(topo.n_clients)
        if topo.kind == "exponential" and topo.time_varying
        else None
    )
    return RoundProgram(
        solver=solver,
        compressor=compressor,
        mixer=mixer,
        loss_fn=loss_fn,
        init_fn=init_fn,
        data=client_data,
        topo=topo,
        spec=spec,
        n=topo.n_clients,
        participation=participation,
        lr=algo.lr,
        lr_decay=algo.lr_decay,
        selection=algo.selection,
        exp_cycle=exp_cycle,
    )
