"""The composable round program: a pure ``init``/``step`` core over stages.

``make_program`` wires a (LocalSolver, Compressor, Mixer) composition —
usually resolved from an ``AlgoConfig`` via ``repro.core.stages`` — into a
:class:`RoundProgram` whose

    state           = program.init(key)          # FLState
    state, metrics  = program.step(state)        # one communication round
    state, history  = program.run(state, rounds) # lax.scan over step

are plain jittable functions of traced state only (topology, data, and the
stage composition are closed over as constants), optax-style.  Callers can
``jax.jit(program.step, donate_argnums=0)`` to update the (n, D) banks in
place, or scan whole training runs inside one jit.

``run_superstep`` is the production driver built on top: it jits one
``lax.scan`` over a whole *superstep* of rounds with donated carry and
performs the masked fixed-shape evaluation *in-scan* at the configured
cadence, so the host is only touched at superstep boundaries (checkpoint /
logging) — the Stochastic Gradient Push recipe for keeping the device,
not the Python loop, as the wall-clock ceiling.  ``FLTrainer`` in
``repro.core.engine`` is a thin stateful wrapper around exactly this API.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import topology
from repro.core.flat import (
    BankSpec,
    BoundDeltaSpec,
    DeltaConfig,
    bind_delta_spec,
    make_delta_spec,
    make_spec,
)
from repro.core.stages import (
    ChurnState,
    DelayedPushSumMixer,
    EventTriggeredMixer,
    IdentityCompressor,
    LinkState,
    comm_phase,
    make_stages,
)

__all__ = [
    "FLState",
    "ActiveSlots",
    "RoundProgram",
    "make_program",
    "plan_keys",
]


def plan_keys(key: jax.Array):
    """The paged round's PRNG chain: one split of the round key into
    ``(key_next, akey, tkey, ckey_base)`` — next round's key, the active-set
    permutation key, the topology pick key, and the base every client folds
    its global id into.  Host planner and the fully-resident reference
    driver both derive from exactly this chain, which is what makes
    paged == resident equivalence testable stream-for-stream."""
    ks = jax.random.split(key, 4)
    return ks[0], ks[1], ks[2], ks[3]


class ActiveSlots(NamedTuple):
    """Device-side view of one round's fault-in closure.

    ``ids[s]`` is the global client id resident in compact slot ``s``
    (layout ``[active | cold | pads]``; only the first ``k_active`` entries
    are read, for per-client PRNG folding).  ``idx``/``wgt`` are the
    compact-slot :class:`~repro.core.topology.NeighborList` of the
    closure-restricted mixing operator built by
    :func:`repro.store.paging.build_plan`."""

    ids: jnp.ndarray  # (c_max,) int32 global ids per resident slot
    idx: jnp.ndarray  # (c_max, 1 + k_in) int32 compact in-neighbor slots
    wgt: jnp.ndarray  # (c_max, 1 + k_in) float32 mixing weights


class FLState(NamedTuple):
    """Full round state — everything a warm restart needs."""

    params: Any  # flat (n, D) bank / (D,) central row; pytree when flat=False
    # End-of-round momentum bank, (n, D) float32 (None on the legacy path).
    # Algorithm 1 re-initializes v to zero each round, so training never
    # reads it back — it is carried for observability and checkpoint/warm-
    # restart of momentum-persistent variants.
    mom: Any
    w: jnp.ndarray  # (n,) push-sum weights (all-ones when unused)
    key: jax.Array
    round: jnp.ndarray  # int32 scalar
    losses: jnp.ndarray  # (n,) last local losses (drives selection)
    comp: Any = ()  # compressor state (e.g. error-feedback residual bank)
    # Unreliable-link carry (stages.LinkState): its own PRNG stream for
    # drop/delay draws plus the delayed in-flight payload buffers or the
    # event-trigger last-broadcast cache.  () on perfect-link programs.
    link: Any = ()
    # Node-churn carry (stages.ChurnState): its own PRNG stream plus the
    # (n,) liveness vector (and the cold-resurrection template row).
    # () on churn-free programs — immortal clients.
    churn: Any = ()


@dataclasses.dataclass(frozen=True)
class RoundProgram:
    """One federated-optimization algorithm as a stage composition.

    All fields are trace-time constants; ``init``/``step``/``run`` below are
    the only functions of traced values.
    """

    solver: Any
    compressor: Any
    mixer: Any
    loss_fn: Callable
    init_fn: Callable
    data: Any  # client-stacked pytree, leading dims (n_clients, m, ...)
    topo: topology.TopologyConfig
    spec: BankSpec
    n: int
    participation: float
    lr: float
    lr_decay: float
    selection: bool
    # Stack for time-varying exponential graphs: (hops, n, n) dense, or a
    # stacked (hops, n, 2) NeighborList on the sparse path.
    exp_cycle: Any
    # Mixing-operator representation: with sparse_mix the round samples
    # fixed-shape (n, k_max) neighbor lists and the whole push-sum step
    # (bank AND weight vector) runs O(n * k_max * D) without ever
    # materializing (n, n).  Resolved at build time by the density rule in
    # repro.kernels.ops.use_sparse_gossip (gossip="auto") or forced.
    gossip: str = "auto"
    sparse_mix: bool = False
    # Unreliable-link scenario (topology.LinkModel) — None models perfect
    # links and keeps the round bitwise identical to the pre-link program.
    # ``linked`` is the static routing flag: True when the link model is
    # active or the mixer carries link state, in which case the step
    # threads ``state.link`` and samples drops/delays from its key.
    link: Any = None
    linked: bool = False
    # Node-churn scenario (topology.ChurnModel) — None models immortal
    # clients and keeps the round bitwise identical to the pre-churn
    # program.  When set, the step threads ``state.churn`` (its own PRNG
    # stream + the (n,) liveness vector), masks dead nodes out of the
    # sampled operator before the link model's drops, and freezes their
    # mass on the self-loop so live + in-flight + frozen mass == n.
    churn_model: Any = None

    @property
    def churned(self) -> bool:
        return self.churn_model is not None
    # GSPMD row-sharded bank: a 1-D device mesh whose ``shard_axis`` names
    # the axis bank rows (params, momentum, EF residual, push-sum weights,
    # link carry) are partitioned along.  None keeps the single-device
    # program bitwise unchanged (all sharding constraints degrade to
    # identity).
    mesh: Any = None
    shard_axis: str = "clients"

    def __post_init__(self):
        # Per-program memo of compiled superstep drivers, keyed on the
        # (rounds, eval cadence, test-data identity) signature — repeated
        # supersteps of the same shape must hit the jit cache, not retrace.
        object.__setattr__(self, "_superstep_cache", {})
        from repro.launch.sharding import bank_row_pins

        pin, pin_link = bank_row_pins(self.mesh, self.shard_axis)
        object.__setattr__(self, "_pin", pin)
        object.__setattr__(self, "_pin_link", pin_link)

    # -- pure state constructor ---------------------------------------------

    def init_row(self, pkey: jax.Array) -> jnp.ndarray:
        """The broadcast initial bank row.  Dense bank: the ravelled
        ``init_fn(pkey)`` model.  Delta bank: the spec's init row (zero
        deltas over the frozen base; low-rank leaves LoRA-initialized) —
        every client starts at exactly the base model either way."""
        if isinstance(self.spec, BoundDeltaSpec):
            return self.spec.init_row(pkey)
        return self.spec.ravel(self.init_fn(pkey))

    def init(self, key: jax.Array) -> FLState:
        pkey, skey = jax.random.split(key)
        w0 = self.mixer.init_weights(self.n)
        losses0 = jnp.zeros((self.n,), jnp.float32)
        if self.mixer.kind == "central":
            row = self.spec.ravel(self.init_fn(pkey))
            return FLState(row, None, w0, skey, jnp.int32(0), losses0, ())
        row = self.init_row(pkey)
        bank = jnp.broadcast_to(row, (self.n, self.spec.dim))
        mom = jnp.zeros((self.n, self.spec.dim), jnp.float32)
        comp = self.compressor.init_state(self.n, self.spec.dim)
        link = ()
        if self.linked:
            # The link stream is folded off the seed key so the main
            # params/round stream stays exactly the perfect-link one.
            link = LinkState(
                key=jax.random.fold_in(key, 0x11AB),
                **self.mixer.link_buffers(bank),
            )
        churn = ()
        if self.churned:
            # Same isolation for the churn stream: folded off the seed,
            # never touching the main params/round chain.
            churn = ChurnState(
                key=jax.random.fold_in(key, 0x0C4B),
                live=jnp.full((self.n,), topology.LIVE, jnp.int8),
                tpl=(row if self.churn_model.resurrect == "cold" else ()),
            )
        return self.shard_state(
            FLState(bank, mom, w0, skey, jnp.int32(0), losses0, comp, link,
                    churn)
        )

    # -- GSPMD placement -----------------------------------------------------

    def shard_state(self, state: FLState) -> FLState:
        """Place every bank-row leaf of ``state`` on the ``shard_axis`` of
        the program mesh (scalars/keys replicated).  Identity without a
        mesh, so single-device callers — and ``init`` itself — compose
        through unconditionally.  ``engine.FLTrainer.restore`` routes
        host-loaded checkpoints through here so a resumed run is sharded
        from its first round."""
        if self.mesh is None or self.mixer.kind == "central":
            return state
        from jax.sharding import NamedSharding, PartitionSpec

        def _sh(lead, ndim):
            spec = [None] * ndim
            spec[lead] = self.shard_axis
            return NamedSharding(self.mesh, PartitionSpec(*spec))

        def row(x, lead=0):
            if x is None or isinstance(x, tuple):
                return x
            return jax.device_put(x, _sh(lead, x.ndim))

        def rep(x):
            return jax.device_put(
                x, NamedSharding(self.mesh, PartitionSpec())
            )

        link = state.link
        if link:
            link = link._replace(
                key=rep(link.key),
                bufx=row(link.bufx, 1),
                bufw=rep(link.bufw) if not isinstance(
                    link.bufw, tuple) else (),
                last=row(link.last),
            )
        churn = state.churn
        if churn:
            churn = churn._replace(
                key=rep(churn.key),
                live=row(churn.live),
                tpl=rep(churn.tpl) if not isinstance(
                    churn.tpl, tuple) else (),
            )
        return state._replace(
            params=row(state.params),
            mom=row(state.mom),
            w=row(state.w),
            key=rep(state.key),
            round=rep(state.round),
            losses=row(state.losses),
            comp=row(state.comp),
            link=link,
            churn=churn,
        )

    # -- mixing-matrix selection --------------------------------------------

    def mixing_matrix(self, tkey: jax.Array, state: FLState):
        # Every sampled family honors the configured ``topo.k_out`` —
        # ``participation`` only drives central (server) client sampling.
        # Returns the dense (n, n) matrix, or the fixed-shape NeighborList
        # when the density rule picked the sparse representation; every
        # downstream consumer (mixers, pushsum, kernels) dispatches on the
        # type.
        k_link = self.topo.k_out
        if self.sparse_mix:
            if self.mixer.kind == "symmetric":
                return topology.sample_symmetric_neighbors(
                    tkey, self.n, k_link
                )
            if self.selection:
                return topology.sample_kout_selective_neighbors(
                    tkey, state.losses, self.n, k_link
                )
            if self.exp_cycle is not None:
                hops = self.exp_cycle.idx.shape[0]
                t = jnp.mod(state.round, hops)
                return topology.NeighborList(
                    self.exp_cycle.idx[t], self.exp_cycle.wgt[t]
                )
            return topology.sample_neighbors(tkey, self.topo, t=0)
        if self.mixer.kind == "symmetric":
            return topology.sample_symmetric_k_regular(tkey, self.n, k_link)
        if self.selection:
            return topology.sample_kout_selective(
                tkey, state.losses, self.n, k_link
            )
        if self.exp_cycle is not None:
            # Time-varying exponential graph: round t uses cycle[t % hops].
            hops = self.exp_cycle.shape[0]
            return self.exp_cycle[jnp.mod(state.round, hops)]
        return topology.sample_mixing(tkey, self.topo, t=0)

    # -- one communication round --------------------------------------------

    def step(self, state: FLState):
        lr = self.lr * self.lr_decay ** state.round.astype(jnp.float32)
        keys = jax.random.split(state.key, 2 + self.n)
        key, tkey, ckeys = keys[0], keys[1], keys[2:]
        if self.mixer.kind == "central":
            return self._central_step(state, lr, key, tkey, ckeys)

        # Node churn resolves FIRST: this round's liveness decides who
        # trains and whose edges survive.  A node down this round neither
        # trains nor communicates — its row and mass freeze on the
        # self-loop.  All branches are trace-time (self.churned is a
        # Python bool), so churn-free programs stay bitwise unchanged.
        alive = None
        params0, mom0, comp0 = state.params, state.mom, state.comp
        if self.churned:
            nkey, ckey = jax.random.split(state.churn.key)
            live_new = topology.churn_transition(
                ckey, state.churn.live, self.churn_model
            )
            alive = live_new == topology.LIVE
            if self.churn_model.resurrect == "cold":
                # A node rejoining this round restarts at the init
                # template in de-biased coordinates: x := w * template
                # keeps its frozen mass w bit-for-bit (the invariant),
                # while x/w == template exactly.  Momentum and any
                # compressor residual rows are zeroed with it.
                reborn = (
                    (state.churn.live == topology.DOWN)
                    & (live_new == topology.LIVE)
                )[:, None]
                params0 = jnp.where(
                    reborn,
                    (state.w[:, None] * state.churn.tpl).astype(
                        params0.dtype),
                    params0,
                )
                if mom0 is not None:
                    mom0 = jnp.where(reborn, 0.0, mom0)
                if not (isinstance(comp0, tuple) and comp0 == ()):
                    comp0 = jnp.where(reborn, 0.0, comp0)

        # Per-client PRNG rows and the solver outputs are pinned to the
        # bank's row sharding so the vmapped local phase stays shard-local.
        ckeys = self._pin(ckeys)
        X, V, losses, accs = self.solver.update(
            self.loss_fn, self.spec, params0, state.w, ckeys,
            self.data, lr
        )
        V = self._pin(V) if V is not None else V
        if self.churned:
            # Dead nodes did not train: their rows, momentum and last
            # losses carry through untouched (frozen).
            al = alive[:, None]
            X = jnp.where(al, X, params0)
            if V is not None:
                V = jnp.where(al, V, mom0)
            losses = jnp.where(alive, losses, state.losses)
        # The communication phase — compress, link drops/delays, mix — is
        # the shared ``stages.comm_phase`` (also driving the pod
        # ``round_step``): the compressor shapes what leaves each client
        # over the network while the self-loop contribution P[ii]·X[i]
        # stays full precision; with identity compression and no mesh the
        # phase is bitwise the pre-extraction inline sequence.
        P = self.mixing_matrix(tkey, state)
        if self.churned:
            # Dead nodes leave the operator wholesale (in- AND out-edges,
            # masked before sender normalization); the link model's
            # per-edge drops then fail edges of the surviving support.
            P = self.churn_model.mask_operator(
                P, alive, symmetric=self.mixer.kind == "symmetric"
            )
        X, w_new, comp, link, extras = comm_phase(
            self.compressor, self.mixer, P, X, state.w, comp0,
            state.link,
            linked=self.linked, link_model=self.link,
            symmetric=self.mixer.kind == "symmetric",
            pin=self._pin, pin_link=self._pin_link,
            t=state.round,
        )
        churn = state.churn
        if self.churned:
            churn = ChurnState(nkey, live_new, state.churn.tpl)
        new_state = FLState(
            X, V, w_new, key, state.round + 1, losses, comp, link, churn
        )
        if self.churned:
            n_live = jnp.maximum(alive.sum(), 1).astype(jnp.float32)
            metrics = {
                "loss": jnp.where(alive, losses, 0.0).sum() / n_live,
                "acc": jnp.where(alive, accs, 0.0).sum() / n_live,
                **extras,
            }
            metrics["live_frac"] = alive.mean(dtype=jnp.float32)
            # Frozen mass parked on dead nodes' self-loops — the third
            # term of the exact invariant live + in-flight + frozen == n.
            metrics["dead_mass"] = jnp.where(alive, 0.0, w_new).sum()
        else:
            metrics = {"loss": losses.mean(), "acc": accs.mean(), **extras}
        if self.linked or self.churned:
            # Total push-sum mass, in-flight shares included — the exact
            # conservation invariant the link/churn subsystems are pinned
            # by (frozen dead mass stays in w, so it is already counted).
            inflight = (link.bufw.sum()
                        if self.linked and not isinstance(link.bufw, tuple)
                        else jnp.float32(0.0))
            metrics["w_mass"] = w_new.sum() + inflight
        return new_state, metrics

    def _central_step(self, state: FLState, lr, key, tkey, ckeys):
        m = max(int(self.participation * self.n), 1)
        sel = jax.random.permutation(tkey, self.n)[:m]
        data_sel = jax.tree.map(lambda d: d[sel], self.data)
        Xrep = jnp.broadcast_to(state.params, (m,) + state.params.shape)
        ones = jnp.ones((m,), jnp.float32)
        X, _, losses, accs = self.solver.update(
            self.loss_fn, self.spec, Xrep, ones, ckeys[:m], data_sel, lr
        )
        new_params = self.mixer.reduce(X)
        # The sampled clients' end-of-round losses refresh their slots in
        # the (n,) loss vector (it rides checkpoints and drives selection);
        # it used to be returned unchanged — zeros forever on this path.
        new_losses = state.losses.at[sel].set(losses)
        new_state = FLState(
            new_params, state.mom, state.w, key, state.round + 1,
            new_losses, state.comp, state.link
        )
        return new_state, {"loss": losses.mean(), "acc": accs.mean()}

    # -- one paged round on the compact resident bank -------------------------

    def step_active(
        self, state: FLState, slots: ActiveSlots, data_active, *,
        k_active: int,
    ):
        """One communication round over a **compact** ``(c_max, D)`` bank —
        the paged twin of :meth:`step` for partial participation.

        ``state`` here is the *resident* state: every bank leaf holds only
        the round's fault-in closure (layout ``[active | cold | pads]``,
        see :mod:`repro.store.paging`), ``state.key`` is the round's
        ``ckey_base`` from :func:`plan_keys` (the paged key chain lives on
        the host), and ``state.link`` is ``()`` — link scenarios are not
        paged.  Only the first ``k_active`` rows train locally; the mix
        runs the same :func:`~repro.core.stages.comm_phase` over the
        slot-remapped NeighborList in ``slots``, so compressors (including
        stateful EF residuals, resident like every other bank leaf) and the
        full-precision self-loop rule compose unchanged.  ``k_active`` is
        static: jit with ``static_argnames=("k_active",)``.
        """
        lr = self.lr * self.lr_decay ** state.round.astype(jnp.float32)
        ckeys = jax.vmap(
            lambda i: jax.random.fold_in(state.key, i)
        )(slots.ids[:k_active])
        Xa, Va, losses, accs = self.solver.update(
            self.loss_fn, self.spec, state.params[:k_active],
            state.w[:k_active], ckeys, data_active, lr,
        )
        X = state.params.at[:k_active].set(Xa)
        mom = (
            state.mom.at[:k_active].set(Va)
            if state.mom is not None else None
        )
        P = topology.NeighborList(slots.idx, slots.wgt)
        Xm, w_new, comp, _, extras = comm_phase(
            self.compressor, self.mixer, P, X, state.w, state.comp, (),
            t=state.round,
        )
        losses_res = state.losses.at[:k_active].set(losses)
        new_state = FLState(
            Xm, mom, w_new, state.key, state.round + 1, losses_res, comp, ()
        )
        # w_sum counts every resident slot; the runner subtracts the
        # (c_max - c) inert unit pads to report real closure mass.
        metrics = {
            "loss": losses.mean(), "acc": accs.mean(),
            "w_sum": w_new.sum(), **extras,
        }
        return new_state, metrics

    # -- whole training runs inside one jit ---------------------------------

    def run(self, state: FLState, rounds: int):
        """``lax.scan`` ``rounds`` steps; returns (state, stacked metrics)."""
        return jax.lax.scan(
            lambda s, _: self.step(s), state, None, length=rounds
        )

    # -- jit-resident supersteps (the production driver) ---------------------

    def make_eval_fn(self, test_data, batch: int = 1024):
        """Jittable masked fixed-shape evaluation of the consensus model.

        The test set is padded and stacked into ``(n_chunks, batch, ...)``
        constants once, so ``eval_fn(state) -> (test_loss, test_acc)`` has a
        single fixed shape regardless of the ragged final chunk and can run
        inside ``lax.scan``/``lax.cond``.  Per-example metrics are vmapped
        and the pad rows masked out of the sums exactly (``where``, not
        multiply — a non-finite loss on a zero pad row must not poison the
        sum via ``NaN * 0``).
        """
        n = test_data["x"].shape[0]
        n_chunks = -(-n // batch)
        total = n_chunks * batch
        padded = {
            k: jnp.concatenate(
                [v, jnp.zeros((total - n,) + v.shape[1:], v.dtype)]
            ).reshape((n_chunks, batch) + v.shape[1:])
            for k, v in test_data.items()
        }
        mask = (jnp.arange(total) < n).reshape(n_chunks, batch)

        def eval_fn(state: FLState):
            row = (
                state.params
                if self.mixer.kind == "central"
                else state.params.mean(axis=0)
            )
            params = self.spec.unravel(row)

            def one(ex):
                return self.loss_fn(
                    params, jax.tree.map(lambda v: v[None], ex)
                )

            def chunk_sums(carry, cm):
                chunk, m = cm
                per_l, per_a = jax.vmap(one)(chunk)
                return (
                    carry[0] + jnp.sum(jnp.where(m, per_l, 0.0)),
                    carry[1] + jnp.sum(jnp.where(m, per_a, 0.0)),
                ), None

            (tl, ta), _ = jax.lax.scan(
                chunk_sums,
                (jnp.float32(0.0), jnp.float32(0.0)),
                (padded, mask),
            )
            return tl / n, ta / n

        return eval_fn

    def run_superstep(
        self,
        state: FLState,
        rounds: int,
        eval_every: int = 0,
        test_data=None,
        eval_batch: int = 1024,
    ):
        """One jit-resident superstep: ``lax.scan`` ``rounds`` rounds inside
        a single jit with donated carry, evaluating *in-scan* on
        ``test_data`` whenever the global round counter hits ``eval_every``
        (the cadence follows ``state.round``, so it is stable across
        superstep boundaries and checkpoint resume).

        Returns ``(state, history)`` where every history leaf is stacked
        ``(rounds,)``; with eval enabled, ``history`` additionally carries
        ``test_loss`` / ``test_acc`` and the boolean ``eval_mask`` marking
        which rounds the eval values are valid for (non-eval rounds hold
        zeros).  Compiled drivers are memoized per (rounds, eval_every,
        test_data identity, eval_batch), so repeated supersteps of the same
        shape reuse one executable.
        """
        cache_key = (
            int(rounds), int(eval_every),
            id(test_data) if test_data is not None else None,
            int(eval_batch),
        )
        # The cache entry keeps a strong reference to test_data: an id() in
        # the key can only collide with a *live* dict, and a live id is the
        # same object — so a hit can never serve constants baked from a
        # different (freed, address-reused) test set.
        entry = self._superstep_cache.get(cache_key)
        fn = entry[0] if entry is not None else None
        if fn is None:
            eval_fn = (
                self.make_eval_fn(test_data, eval_batch)
                if test_data is not None and eval_every
                else None
            )

            def body(s, _):
                s, metrics = self.step(s)
                if eval_fn is not None:
                    # s.round is already the post-increment (1-based) count.
                    do = jnp.mod(s.round, eval_every) == 0
                    tl, ta = jax.lax.cond(
                        do,
                        eval_fn,
                        lambda _s: (jnp.float32(0.0), jnp.float32(0.0)),
                        s,
                    )
                    metrics = dict(
                        metrics, test_loss=tl, test_acc=ta, eval_mask=do
                    )
                return s, metrics

            fn = jax.jit(
                lambda s: jax.lax.scan(body, s, None, length=rounds),
                donate_argnums=0,
            )
            self._superstep_cache[cache_key] = (fn, test_data)
        return fn(state)


def make_program(
    loss_fn: Callable,
    init_fn: Callable,
    client_data,
    algo,
    topo: topology.TopologyConfig,
    participation: float = 0.1,
    gossip: str = "auto",
    link: topology.LinkModel | None = None,
    churn: topology.ChurnModel | None = None,
    mesh=None,
    shard_axis: str = "clients",
    delta: DeltaConfig | int | str | None = None,
    bank_dtype=None,
) -> RoundProgram:
    """Compose an ``AlgoConfig`` into a :class:`RoundProgram`.

    The bank spec is built from ``jax.eval_shape`` of ``init_fn`` — no
    parameters are materialized here; ``program.init`` owns that.  With
    ``delta`` (a :class:`~repro.core.flat.DeltaConfig`, or just a rank /
    ``"full"``) the bank stores per-client low-rank adapter rows over a
    frozen shared base materialized once from ``init_fn`` — every solver /
    compressor / mixer then operates verbatim on the narrower
    ``(n, d_delta)`` bank.  ``bank_dtype`` overrides the bank storage dtype
    (e.g. ``jnp.bfloat16`` rows with float32 momentum — the EF residual
    stays float32, so top-k error feedback remains exact).

    ``gossip`` picks the mixing-operator representation AND (with a mesh)
    the executor, through the one dispatch rule in
    :func:`repro.comm.plan.resolve_backend`: ``"auto"`` (default) applies
    the density rule in :func:`repro.kernels.ops.use_sparse_gossip` to the
    family's static ``k_max``; ``"sparse"`` / ``"dense"`` force
    neighbor-list or dense sampling (benchmarks compare the two; small
    recorded configs always resolve dense, keeping the golden traces
    bit-for-bit); ``"xla"`` forces the sparse form on the partitionable
    all-gather executor; ``"halo"`` (mesh required) forces the sparse form
    on the ``shard_map`` halo exchange that ships only each shard's
    :class:`~repro.comm.plan.CommPlan` rows.  Under a mesh, ``"auto"`` /
    ``"sparse"`` select halo automatically for the static shift families
    (ring / exponential[_cycle]) and the all-gather otherwise.

    ``link`` is the unreliable-link scenario (:class:`topology.LinkModel`):
    per-round i.i.d. edge drops (renormalized before the send, so ``P_t``
    stays exactly column-stochastic), bounded per-edge delivery delays
    (``DelayedPushSumMixer`` with its in-flight buffers in the round
    state), or event-triggered transmission (``EventTriggeredMixer`` with
    the ``comm_fraction`` metric).  ``None`` — or a model whose fields are
    all zero — builds the exact perfect-link program, bitwise.

    ``churn`` is the node-failure scenario (:class:`topology.ChurnModel`):
    whole clients crash and (optionally) rejoin per round, their in/out
    edges masked from the sampled operator before sender normalization and
    their push-sum mass frozen on the self-loop, keeping
    live + in-flight + frozen mass == n exactly.  Composes with ``link``
    drops and delays (churn masks first, drops fail surviving edges);
    rejected with ``event_threshold``.  ``None`` — or an all-zero model —
    builds the exact immortal-population program, bitwise.

    ``mesh`` row-shards the whole round: bank rows (and the client data)
    are partitioned along ``shard_axis``, the mixers are re-backed onto a
    partitionable gossip executor — the all-gather form or the halo
    exchange, per the dispatch rule above — and ``init``/``step``/
    ``run_superstep`` then run sharded under one jit: intra-shard edges
    stay local, cross-shard edges become one row collective (the full bank
    on the all-gather path, only the plan's O(k) halo rows on the halo
    path).  ``None`` is the exact single-device program.
    """
    from repro.kernels import ops as kops

    solver, compressor, mixer = make_stages(algo)
    if topo.kind == "two_tier":
        if mixer.kind != "directed":
            raise ValueError(
                "the two-tier family is directed push-sum gossip only; "
                f"comm={algo.comm!r} has no two-tier form"
            )
        if algo.selection:
            raise ValueError(
                "loss-selective neighbor sampling has no two-tier form; "
                "disable selection for kind='two_tier'"
            )
    link = link if link is not None and link.active else None
    if link is not None:
        if mixer.kind == "central":
            raise ValueError(
                "the central (server) round has no peer links to degrade; "
                "drop the link model for comm='central'"
            )
        if mixer.kind != "directed" and (link.delay or link.event_threshold):
            raise ValueError(
                "delayed / event-triggered mixing is push-sum (directed) "
                f"only, not comm={algo.comm!r}; symmetric gossip supports "
                "link drops alone"
            )
        if link.delay:
            mixer = DelayedPushSumMixer(delay=link.delay)
        elif link.event_threshold:
            mixer = EventTriggeredMixer(
                threshold=link.event_threshold,
                decay=link.event_decay,
                schedule=link.event_schedule,
            )
    churn = churn if churn is not None and churn.active else None
    if churn is not None:
        if mixer.kind == "central":
            raise ValueError(
                "the central (server) round has no peer population to "
                "churn; drop churn= for comm='central'"
            )
        if link is not None and link.event_threshold:
            # The event mixer keeps ONE last-broadcast row per sender; a
            # node that crashed after its last transmission would keep
            # being mixed from the cache by peers that can no longer hear
            # it (sound modeling needs per-receiver caches).
            raise ValueError(
                "event-triggered mixing assumes immortal senders (the "
                "shared last-broadcast cache cannot model a crashed "
                "transmitter); churn and event_threshold do not compose"
            )
    if mixer.kind == "central" and not isinstance(
        compressor, IdentityCompressor
    ):
        # The central round has no gossip step to compress; silently
        # training uncompressed would misreport communication savings.
        raise ValueError(
            "central (server) rounds do not model compressed communication; "
            f"drop compressor={algo.compressor!r}/quantize_gossip"
        )
    if gossip not in ("auto", "sparse", "dense", "xla", "halo"):
        raise ValueError(
            f"gossip must be auto|sparse|dense|xla|halo, got {gossip!r}"
        )
    if mixer.kind == "central":
        sparse_mix = False
    elif gossip in ("sparse", "xla", "halo"):
        if topo.kind == "full":
            raise ValueError(
                "the full graph has no sparse neighbor-list form"
            )
        sparse_mix = True
    elif gossip == "dense":
        sparse_mix = False
    else:
        sparse_mix = kops.use_sparse_gossip(
            topo.n_clients, topology.neighbor_k_max(topo, mixer.kind)
        )
    if (link is not None and link.drop > 0 and sparse_mix
            and mixer.kind == "symmetric"):
        raise ValueError(
            "link drops on the symmetric neighbor-list form are "
            "unsupported; pass gossip='dense' for symmetric + drops"
        )
    if (link is not None and link.drop > 0 and sparse_mix
            and topo.kind == "two_tier"):
        raise ValueError(
            "link drops on the two-tier operator form are unsupported; "
            "pass gossip='dense' for two_tier + drops"
        )
    if churn is not None and sparse_mix and mixer.kind == "symmetric":
        raise ValueError(
            "churn on the symmetric neighbor-list form is unsupported; "
            "pass gossip='dense' for symmetric + churn"
        )
    if churn is not None and sparse_mix and topo.kind == "two_tier":
        raise ValueError(
            "churn on the two-tier operator form is unsupported; "
            "pass gossip='dense' for two_tier + churn"
        )
    if mesh is not None:
        if shard_axis not in mesh.axis_names:
            raise ValueError(
                f"mesh has no {shard_axis!r} axis (axes: {mesh.axis_names})"
            )
        n_dev = mesh.shape[shard_axis]
        if topo.n_clients % n_dev:
            raise ValueError(
                f"n_clients={topo.n_clients} must be divisible by the "
                f"{shard_axis!r} axis size {n_dev} to row-shard the bank"
            )
        if mixer.kind == "central":
            raise ValueError(
                "the central (server) round keeps one global row — there "
                "is no client bank to shard; drop the mesh"
            )
        # Client-stacked data rows live with their bank rows, so the
        # vmapped local phase never moves examples across shards.
        from jax.sharding import NamedSharding, PartitionSpec

        def _row_put(x):
            spec = [shard_axis] + [None] * (x.ndim - 1)
            return jax.device_put(
                x, NamedSharding(mesh, PartitionSpec(*spec))
            )

        client_data = jax.tree.map(_row_put, client_data)
    if mixer.kind != "central":
        from repro.comm.plan import resolve_backend

        backend = resolve_backend(
            gossip, sparse_mix, topo, mixer.kind, mesh, shard_axis
        )
        if backend is not None:
            # The interpret-mode kernel executors (pallas grids, fori_loop
            # panel slicing) defeat the GSPMD partitioner; under a mesh the
            # mixer is re-backed onto a partitionable executor: the
            # all-gather twin ("xla" — same accumulation order, bitwise)
            # or the shard_map halo exchange (a HaloBackend shipping only
            # the CommPlan's remote rows per shard).
            mixer = dataclasses.replace(mixer, backend=backend)
    shape_tree = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    if delta is not None:
        if not isinstance(delta, DeltaConfig):
            delta = DeltaConfig(rank=delta)
        if mixer.kind == "central":
            raise ValueError(
                "the central (server) round keeps one global row — there "
                "are no per-client deltas to bank; drop delta= for "
                "comm='central'"
            )
        dspec = make_delta_spec(
            shape_tree, rank=delta.rank, adapt=delta.adapt, dtype=bank_dtype
        )
        if dspec.dim == 0:
            raise ValueError(
                f"delta adapt={delta.adapt!r} selected no leaves: every "
                "client would be frozen at the base model"
            )
        # The frozen shared base is materialized exactly once, here; rows
        # in the bank are pure adapter payloads over it.
        base = init_fn(jax.random.PRNGKey(delta.base_seed))
        spec = bind_delta_spec(dspec, base)
    else:
        spec = make_spec(shape_tree, dtype=bank_dtype)
    # Exponential graphs cycle through log2(n) hop matrices; precompute
    # the stack once so the (traced) round index can select the graph.
    exp_cycle = None
    if topo.kind == "exponential" and topo.time_varying:
        exp_cycle = (
            topology.neighbors_exponential_cycle(topo.n_clients)
            if sparse_mix
            else topology.exponential_cycle(topo.n_clients)
        )
    return RoundProgram(
        solver=solver,
        compressor=compressor,
        mixer=mixer,
        loss_fn=loss_fn,
        init_fn=init_fn,
        data=client_data,
        topo=topo,
        spec=spec,
        n=topo.n_clients,
        participation=participation,
        lr=algo.lr,
        lr_decay=algo.lr_decay,
        selection=algo.selection,
        exp_cycle=exp_cycle,
        gossip=gossip,
        sparse_mix=sparse_mix,
        link=link,
        linked=link is not None or mixer.link_stateful,
        churn_model=churn,
        mesh=mesh,
        shard_axis=shard_axis,
    )
