"""Sharpness-Aware Minimization + local momentum primitives.

These implement lines 5–11 of Algorithm 1 as pure pytree transforms so the
same code drives the n-client simulation engine (via vmap), the small-model
paper backbones, and the pod-scale distributed runtime.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = [
    "global_norm",
    "sam_perturb",
    "sam_gradient",
    "momentum_update",
    "apply_update",
]

_EPS = 1e-12


def global_norm(tree) -> jnp.ndarray:
    """Euclidean norm over a whole pytree (float32 accumulation)."""
    sq = jax.tree.map(lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def sam_perturb(params, grads, rho: float):
    """z̆ = z + rho * g / ||g||  (Algorithm 1 line 7)."""
    norm = global_norm(grads)
    scale = (rho / (norm + _EPS)).astype(jnp.float32)
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) + scale * g.astype(jnp.float32))
        .astype(p.dtype),
        params,
        grads,
    )


def sam_gradient(
    loss_fn: Callable, params, batch, rho: float, has_aux: bool = True
):
    """Two-pass SAM gradient at ``params`` with the *same* minibatch.

    Returns ``(grads, (loss, aux))`` of the first (unperturbed) pass.  With
    rho == 0 this degrades to a single vanilla gradient (no second pass).
    """
    vg = jax.value_and_grad(loss_fn, has_aux=has_aux)
    if has_aux:
        (loss, aux), g1 = vg(params, batch)
    else:
        loss, g1 = vg(params, batch)
        aux = None
    if rho == 0.0:
        return g1, (loss, aux)
    perturbed = sam_perturb(params, g1, rho)
    grad_fn = jax.grad(loss_fn, has_aux=has_aux)
    if has_aux:
        g2, _ = grad_fn(perturbed, batch)
    else:
        g2 = grad_fn(perturbed, batch)
    return g2, (loss, aux)


def momentum_update(v, grads, alpha: float):
    """v' = alpha * v + g  (Algorithm 1 line 9; alpha=0 -> plain SGD)."""
    if alpha == 0.0:
        return grads
    return jax.tree.map(
        lambda vi, gi: (alpha * vi.astype(jnp.float32)
                        + gi.astype(jnp.float32)).astype(vi.dtype),
        v,
        grads,
    )


def apply_update(params, v, lr):
    """x' = x - lr * v  (Algorithm 1 line 10)."""
    return jax.tree.map(
        lambda p, vi: (p.astype(jnp.float32)
                       - lr * vi.astype(jnp.float32)).astype(p.dtype),
        params,
        v,
    )
