"""Flat client-parameter bank: the engine's native state representation.

Every client's pytree is ravelled into one contiguous row of an
``(n_clients, D)`` buffer so the whole round becomes two dense primitives:
one column-stochastic gossip matmul ``X' = P @ X`` over the entire model and
one fused elementwise momentum/descent/de-bias update — exactly the two
Pallas kernels this repo ships (``kernels/gossip_matmul.py``,
``kernels/fused_update.py``).  Stochastic Gradient Push (Assran et al. 2019)
and DFedSAM treat client state as a flat vector for the same reason.

A :class:`BankSpec` is built once per model from leaf shape/dtype metadata
(static — safe to construct at trace time from ``ShapeDtypeStruct`` leaves)
and caches the per-leaf offsets, so ``unravel`` is pure static slicing and
jit-compiles to views, not gathers.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["BankSpec", "make_spec"]


@dataclasses.dataclass(frozen=True)
class BankSpec:
    """Static ravel/unravel metadata for one model pytree.

    Attributes:
      treedef: pytree structure of a single client's parameters.
      shapes / dtypes: per-leaf shape and original dtype (restored on
        unravel, so mixed-dtype trees round-trip exactly).
      offsets / sizes: start offset and element count of each leaf inside
        the flat row.
      dim: total row length D.
      dtype: storage dtype of the flat buffer (promotion of all leaf
        dtypes, so no leaf loses precision in the bank).
    """

    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    offsets: tuple[int, ...]
    sizes: tuple[int, ...]
    dim: int
    dtype: Any

    # -- single row <-> single-client pytree --------------------------------

    def ravel(self, tree) -> jnp.ndarray:
        """Pytree -> flat (D,) row in the bank storage dtype."""
        leaves = self.treedef.flatten_up_to(tree)
        return jnp.concatenate(
            [jnp.reshape(x, (-1,)).astype(self.dtype) for x in leaves]
        )

    def unravel(self, row: jnp.ndarray):
        """Flat (D,) row -> pytree (leaf dtypes restored).

        Offsets are static, so under jit this is slicing, not gather.
        """
        leaves = [
            jax.lax.slice(row, (o,), (o + s,)).reshape(shape).astype(dt)
            for o, s, shape, dt in zip(
                self.offsets, self.sizes, self.shapes, self.dtypes
            )
        ]
        return self.treedef.unflatten(leaves)

    # -- (n, D) bank <-> client-stacked pytree ------------------------------

    def ravel_stacked(self, stacked_tree) -> jnp.ndarray:
        """Client-stacked pytree (leading dim n per leaf) -> (n, D) bank."""
        leaves = self.treedef.flatten_up_to(stacked_tree)
        return jnp.concatenate(
            [
                jnp.reshape(x, (x.shape[0], -1)).astype(self.dtype)
                for x in leaves
            ],
            axis=1,
        )

    def unravel_stacked(self, bank: jnp.ndarray):
        """(n, D) bank -> client-stacked pytree."""
        n = bank.shape[0]
        leaves = [
            jax.lax.slice(bank, (0, o), (n, o + s))
            .reshape((n,) + shape)
            .astype(dt)
            for o, s, shape, dt in zip(
                self.offsets, self.sizes, self.shapes, self.dtypes
            )
        ]
        return self.treedef.unflatten(leaves)


def make_spec(tree, dtype=None) -> BankSpec:
    """Build the :class:`BankSpec` for one client's parameter pytree.

    ``tree`` may hold real arrays or ``jax.ShapeDtypeStruct`` leaves — only
    static shape/dtype metadata is read.
    """
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(x.shape) for x in leaves)
    dtypes = tuple(jnp.dtype(x.dtype) for x in leaves)
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    offsets = tuple(int(o) for o in np.cumsum((0,) + sizes)[:-1])
    dim = int(sum(sizes))
    dtype = jnp.dtype(dtype) if dtype is not None else jnp.result_type(*dtypes)
    return BankSpec(treedef, shapes, dtypes, offsets, sizes, dim, dtype)
