"""Flat client-parameter bank: the engine's native state representation.

Every client's pytree is ravelled into one contiguous row of an
``(n_clients, D)`` buffer so the whole round becomes two dense primitives:
one column-stochastic gossip matmul ``X' = P @ X`` over the entire model and
one fused elementwise momentum/descent/de-bias update — exactly the two
Pallas kernels this repo ships (``kernels/gossip_matmul.py``,
``kernels/fused_update.py``).  Stochastic Gradient Push (Assran et al. 2019)
and DFedSAM treat client state as a flat vector for the same reason.

A :class:`BankSpec` is built once per model from leaf shape/dtype metadata
(static — safe to construct at trace time from ``ShapeDtypeStruct`` leaves)
and caches the per-leaf offsets, so ``unravel`` is pure static slicing and
jit-compiles to views, not gathers.

The **low-rank delta bank** (:class:`DeltaBankSpec`) reparametrizes the
same storage: clients share one frozen base pytree and each bank row holds
only per-client adapter payloads — rank-r ``(A, B)`` factors for selected
2-D leaves, a dense delta for small leaves, nothing for frozen leaves — so
the row width ``d_delta`` is a small fraction of D and every downstream
consumer of the bank (gossip, push-sum mass, EF residuals, link buffers,
sharding row-pins, the paged store) shrinks by the same factor with no
change to its math.  The invariant that makes directed push-sum work
unchanged is ``delta_i = x_i - w_i * base``: it is preserved by *any*
linear mixing of ``(delta, w)`` by the same operator (column-stochastic or
doubly-stochastic), and the de-biased model is ``z_i = base +
expand(delta_i) / w_i``.  ``rank="full"`` stores a dense delta per adapted
leaf, which reproduces the dense-bank program exactly — the equivalence
oracle pinned in ``tests/test_delta_bank.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["BankSpec", "make_spec", "DeltaConfig", "DeltaBankSpec",
           "BoundDeltaSpec", "make_delta_spec", "bind_delta_spec"]


@dataclasses.dataclass(frozen=True)
class BankSpec:
    """Static ravel/unravel metadata for one model pytree.

    Attributes:
      treedef: pytree structure of a single client's parameters.
      shapes / dtypes: per-leaf shape and original dtype (restored on
        unravel, so mixed-dtype trees round-trip exactly).
      offsets / sizes: start offset and element count of each leaf inside
        the flat row.
      dim: total row length D.
      dtype: storage dtype of the flat buffer (promotion of all leaf
        dtypes, so no leaf loses precision in the bank).
    """

    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    offsets: tuple[int, ...]
    sizes: tuple[int, ...]
    dim: int
    dtype: Any

    # -- single row <-> single-client pytree --------------------------------

    def ravel(self, tree) -> jnp.ndarray:
        """Pytree -> flat (D,) row in the bank storage dtype."""
        leaves = self.treedef.flatten_up_to(tree)
        return jnp.concatenate(
            [jnp.reshape(x, (-1,)).astype(self.dtype) for x in leaves]
        )

    def unravel(self, row: jnp.ndarray):
        """Flat (D,) row -> pytree (leaf dtypes restored).

        Offsets are static, so under jit this is slicing, not gather.
        """
        leaves = [
            jax.lax.slice(row, (o,), (o + s,)).reshape(shape).astype(dt)
            for o, s, shape, dt in zip(
                self.offsets, self.sizes, self.shapes, self.dtypes
            )
        ]
        return self.treedef.unflatten(leaves)

    # -- solver-facing hooks (overridden by the delta bank) -----------------

    def debias(self, row: jnp.ndarray, w):
        """De-biased model pytree ``z = unravel(row) / w`` (push-sum line 5).

        This is the exact expression the solvers used to inline; the delta
        bank overrides it with ``base + expand(row) / w``.
        """
        return jax.tree.map(lambda p: p / w, self.unravel(row))

    def ravel_grad_stacked(self, G_tree, X: jnp.ndarray) -> jnp.ndarray:
        """Client-stacked loss gradients -> (n, D) bank-space gradient rows.

        For the dense bank the pullback through ``unravel`` is the identity,
        so this is :meth:`ravel_stacked`; the delta bank pulls each leaf
        gradient back through its ``A @ B`` factorization at the current
        rows ``X``.
        """
        return self.ravel_stacked(G_tree)

    # -- (n, D) bank <-> client-stacked pytree ------------------------------

    def ravel_stacked(self, stacked_tree) -> jnp.ndarray:
        """Client-stacked pytree (leading dim n per leaf) -> (n, D) bank."""
        leaves = self.treedef.flatten_up_to(stacked_tree)
        return jnp.concatenate(
            [
                jnp.reshape(x, (x.shape[0], -1)).astype(self.dtype)
                for x in leaves
            ],
            axis=1,
        )

    def unravel_stacked(self, bank: jnp.ndarray):
        """(n, D) bank -> client-stacked pytree."""
        n = bank.shape[0]
        leaves = [
            jax.lax.slice(bank, (0, o), (n, o + s))
            .reshape((n,) + shape)
            .astype(dt)
            for o, s, shape, dt in zip(
                self.offsets, self.sizes, self.shapes, self.dtypes
            )
        ]
        return self.treedef.unflatten(leaves)


def make_spec(tree, dtype=None) -> BankSpec:
    """Build the :class:`BankSpec` for one client's parameter pytree.

    ``tree`` may hold real arrays or ``jax.ShapeDtypeStruct`` leaves — only
    static shape/dtype metadata is read.
    """
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(x.shape) for x in leaves)
    dtypes = tuple(jnp.dtype(x.dtype) for x in leaves)
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    offsets = tuple(int(o) for o in np.cumsum((0,) + sizes)[:-1])
    dim = int(sum(sizes))
    dtype = jnp.dtype(dtype) if dtype is not None else jnp.result_type(*dtypes)
    return BankSpec(treedef, shapes, dtypes, offsets, sizes, dim, dtype)


# ---------------------------------------------------------------------------
# Low-rank delta bank: frozen shared base + per-client adapter rows.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeltaConfig:
    """Knobs selecting which leaves adapt and at what rank.

    ``rank``: adapter rank per selected >=2-D leaf, or ``"full"`` for a
      dense delta on every selected leaf (the equivalence oracle — the
      program is then the dense-bank program to float tolerance).  A leaf
      whose rank-r factors would not be smaller than the leaf itself falls
      back to a dense delta.
    ``adapt``: which leaves carry a delta at all.  ``"auto"`` (default)
      adapts everything — big 2-D leaves low-rank, small leaves dense;
      ``"all"`` is the same selection (spelled for the oracle pairing with
      ``rank="full"``); ``"2d"``/``"matrices"`` adapts only >=2-D leaves and
      freezes the rest at the base; a callable ``(path, shape) -> bool``
      or a path substring selects explicitly — unselected leaves are
      frozen (no delta storage, served straight from the base).
    ``base_seed``: PRNG seed that materializes the frozen shared base via
      the program's ``init_fn`` at ``make_program`` time.
    """

    rank: Any = 8
    adapt: Any = "auto"
    base_seed: int = 0


def _leaf_selected(adapt, path: str, shape) -> bool:
    if adapt in ("auto", "all"):
        return True
    if adapt in ("2d", "matrices"):
        return len(shape) >= 2
    if callable(adapt):
        return bool(adapt(path, shape))
    return str(adapt) in path


@dataclasses.dataclass(frozen=True)
class DeltaBankSpec:
    """Static layout of the ``(n, d_delta)`` delta bank over one base model.

    Per leaf of the base pytree:
      mode ``"lowrank"`` — the row stores ``A`` (``lead + (p, r)``) then
        ``B`` (``lead + (r, q)``); the leaf delta is ``A @ B``.
      mode ``"dense"`` — the row stores the leaf delta verbatim.
      mode ``"frozen"`` — no storage; the leaf is served from the base.

    All methods take the base pytree explicitly; :class:`BoundDeltaSpec`
    closes over a concrete base and presents the ``BankSpec`` interface the
    rest of the engine consumes.  Offsets are static, so ``unravel`` is
    slicing + one small matmul per low-rank leaf — no gathers.
    """

    full: BankSpec              # spec of the full model pytree
    paths: tuple[str, ...]      # per-leaf path strings (for adapt= filters)
    modes: tuple[str, ...]      # per-leaf "lowrank" | "dense" | "frozen"
    ranks: tuple[int, ...]      # per-leaf adapter rank (0 unless lowrank)
    offsets: tuple[int, ...]    # per-leaf start offset in the delta row
    sizes: tuple[int, ...]      # per-leaf payload length (0 if frozen)
    asizes: tuple[int, ...]     # A-factor length within the payload
    dim: int                    # d_delta
    dtype: Any

    # -- factor geometry ----------------------------------------------------

    def _factor_shapes(self, i):
        shape, r = self.full.shapes[i], self.ranks[i]
        lead, p, q = shape[:-2], shape[-2], shape[-1]
        return lead + (p, r), lead + (r, q)

    def factors(self, row: jnp.ndarray, i: int):
        """(A, B) of low-rank leaf ``i`` sliced out of one row."""
        o, a, s = self.offsets[i], self.asizes[i], self.sizes[i]
        sa, sb = self._factor_shapes(i)
        A = jax.lax.slice(row, (o,), (o + a,)).reshape(sa)
        B = jax.lax.slice(row, (o + a,), (o + s,)).reshape(sb)
        return A, B

    def _delta_leaf(self, row: jnp.ndarray, i: int):
        """The expanded delta of leaf ``i`` (float32), or None if frozen."""
        mode = self.modes[i]
        if mode == "frozen":
            return None
        o, s = self.offsets[i], self.sizes[i]
        if mode == "dense":
            seg = jax.lax.slice(row, (o,), (o + s,))
            return seg.reshape(self.full.shapes[i]).astype(jnp.float32)
        A, B = self.factors(row, i)
        return jnp.matmul(A.astype(jnp.float32), B.astype(jnp.float32))

    # -- row <-> pytree -----------------------------------------------------

    def unravel(self, base, row: jnp.ndarray):
        """``base + expand(row)`` as a pytree (leaf dtypes restored)."""
        return self.debias(base, row, None)

    def debias(self, base, row: jnp.ndarray, w):
        """De-biased model ``z = base + expand(row) / w`` (``w=None`` skips
        the division — plain unravel)."""
        base_leaves = self.full.treedef.flatten_up_to(base)
        out = []
        for i, bl in enumerate(base_leaves):
            d = self._delta_leaf(row, i)
            if d is None:
                out.append(jnp.asarray(bl, self.full.dtypes[i]))
                continue
            if w is not None:
                d = d / w
            out.append((bl + d.astype(bl.dtype)).astype(self.full.dtypes[i]))
        return self.full.treedef.unflatten(out)

    def ravel(self, base, tree) -> jnp.ndarray:
        """Pytree -> delta row (``w = 1``).  Only dense-mode leaves can hold
        an arbitrary delta; a non-zero residual on a low-rank or frozen leaf
        cannot be represented and raises."""
        leaves = self.full.treedef.flatten_up_to(tree)
        base_leaves = self.full.treedef.flatten_up_to(base)
        segs = []
        for i, (x, b) in enumerate(zip(leaves, base_leaves)):
            mode = self.modes[i]
            if mode == "dense":
                segs.append(jnp.reshape(x - b, (-1,)).astype(self.dtype))
            elif mode == "lowrank":
                raise ValueError(
                    f"leaf {self.paths[i]!r} is low-rank (r={self.ranks[i]}):"
                    " an arbitrary delta cannot be factored into its row;"
                    " use rank='full' or write the (A, B) factors directly"
                )
        if not segs:
            return jnp.zeros((0,), self.dtype)
        return jnp.concatenate(segs)

    def init_row(self, key: jax.Array) -> jnp.ndarray:
        """The broadcast initial row: zero deltas everywhere; low-rank leaves
        get ``A ~ N(0, 1/p)``, ``B = 0`` so the initial delta is exactly zero
        but gradients flow into ``B`` from the first step (standard LoRA
        init)."""
        segs = []
        keys = jax.random.split(key, max(len(self.modes), 1))
        for i, mode in enumerate(self.modes):
            if mode == "frozen":
                continue
            if mode == "dense":
                segs.append(jnp.zeros((self.sizes[i],), self.dtype))
                continue
            sa, _ = self._factor_shapes(i)
            p = sa[-2]
            A = jax.random.normal(keys[i], sa, jnp.float32) / np.sqrt(p)
            segs.append(jnp.reshape(A, (-1,)).astype(self.dtype))
            segs.append(
                jnp.zeros((self.sizes[i] - self.asizes[i],), self.dtype))
        if not segs:
            return jnp.zeros((0,), self.dtype)
        return jnp.concatenate(segs)

    # -- gradient pullback --------------------------------------------------

    def grad_rows(self, G_tree, X: jnp.ndarray) -> jnp.ndarray:
        """Client-stacked loss gradients -> ``(n, d_delta)`` gradient rows.

        Dense leaves pull back as identity (exactly the dense bank's
        semantics — the local step moves ``delta`` by what it would have
        moved ``x``).  Low-rank leaves pull the leaf gradient back through
        ``A @ B`` at the *stored* factors: ``dA = G @ B^T``, ``dB = A^T @
        G``.  Frozen leaves train nothing — their gradient is dropped.
        """
        leaves = self.full.treedef.flatten_up_to(G_tree)
        n = X.shape[0]
        segs = []
        for i, g in enumerate(leaves):
            mode = self.modes[i]
            if mode == "frozen":
                continue
            if mode == "dense":
                segs.append(jnp.reshape(g, (n, -1)).astype(self.dtype))
                continue
            sa, sb = self._factor_shapes(i)
            o, a, s = self.offsets[i], self.asizes[i], self.sizes[i]
            A = jax.lax.slice(X, (0, o), (n, o + a)).reshape((n,) + sa)
            B = jax.lax.slice(X, (0, o + a), (n, o + s)).reshape((n,) + sb)
            gf = g.astype(jnp.float32)
            dA = jnp.matmul(gf, jnp.swapaxes(B.astype(jnp.float32), -1, -2))
            dB = jnp.matmul(jnp.swapaxes(A.astype(jnp.float32), -1, -2), gf)
            segs.append(jnp.reshape(dA, (n, -1)).astype(self.dtype))
            segs.append(jnp.reshape(dB, (n, -1)).astype(self.dtype))
        if not segs:
            return jnp.zeros((n, 0), self.dtype)
        return jnp.concatenate(segs, axis=1)


def make_delta_spec(tree, rank=8, adapt="auto", dtype=None) -> DeltaBankSpec:
    """Build the :class:`DeltaBankSpec` for one client's parameter pytree.

    Like :func:`make_spec`, only static shape/dtype metadata is read, so
    ``tree`` may hold ``ShapeDtypeStruct`` leaves.  ``rank="full"`` (with
    any selecting ``adapt``) stores dense deltas everywhere selected — the
    layout then matches :func:`make_spec` of the selected leaves and the
    program reproduces the dense bank.
    """
    full = make_spec(tree, dtype=dtype)
    path_leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
    paths = tuple(jax.tree_util.keystr(p) for p, _ in path_leaves)
    modes, ranks, sizes, asizes = [], [], [], []
    for path, shape, size in zip(paths, full.shapes, full.sizes):
        if not _leaf_selected(adapt, path, shape):
            modes.append("frozen"); ranks.append(0)
            sizes.append(0); asizes.append(0)
            continue
        r = 0
        if rank != "full" and len(shape) >= 2:
            r = min(int(rank), shape[-2], shape[-1])
            lead = int(np.prod(shape[:-2])) if shape[:-2] else 1
            a = lead * shape[-2] * r
            b = lead * r * shape[-1]
            if a + b < size:
                modes.append("lowrank"); ranks.append(r)
                sizes.append(a + b); asizes.append(a)
                continue
        modes.append("dense"); ranks.append(0)
        sizes.append(size); asizes.append(0)
    offsets = tuple(int(o) for o in np.cumsum((0,) + tuple(sizes))[:-1])
    return DeltaBankSpec(full, paths, tuple(modes), tuple(ranks), offsets,
                         tuple(sizes), tuple(asizes), int(sum(sizes)),
                         full.dtype)


def bind_delta_spec(spec: DeltaBankSpec, base) -> "BoundDeltaSpec":
    """Close a static delta layout over its concrete frozen base."""
    return BoundDeltaSpec(spec, base)


@dataclasses.dataclass(frozen=True, eq=False)
class BoundDeltaSpec:
    """A :class:`DeltaBankSpec` closed over its concrete frozen base — the
    object ``RoundProgram.spec`` holds for delta programs, presenting the
    same interface the dense :class:`BankSpec` does so solvers, eval, the
    paged store and serving all consume it blindly."""

    delta: DeltaBankSpec
    base: Any  # concrete base pytree (the frozen shared model)

    @property
    def dim(self) -> int:
        return self.delta.dim

    @property
    def dtype(self):
        return self.delta.dtype

    @property
    def treedef(self):
        return self.delta.full.treedef

    def unravel(self, row: jnp.ndarray):
        return self.delta.unravel(self.base, row)

    def debias(self, row: jnp.ndarray, w):
        return self.delta.debias(self.base, row, w)

    def ravel(self, tree) -> jnp.ndarray:
        return self.delta.ravel(self.base, tree)

    def ravel_grad_stacked(self, G_tree, X: jnp.ndarray) -> jnp.ndarray:
        return self.delta.grad_rows(G_tree, X)

    def init_row(self, key: jax.Array) -> jnp.ndarray:
        return self.delta.init_row(key)

    def base_row(self) -> jnp.ndarray:
        """The base ravelled under the *full* model spec (checkpoint v3)."""
        return self.delta.full.ravel(self.base)

    def unravel_stacked(self, bank: jnp.ndarray):
        return jax.vmap(self.unravel)(bank)

    def debias_stacked(self, bank: jnp.ndarray, w: jnp.ndarray):
        return jax.vmap(self.debias)(bank, w)
