"""Stacked-client simulation engine for (decentralized) federated learning.

The engine's native state is the **flat client-parameter bank**: every
client's pytree is ravelled into one contiguous row of an ``(n_clients, D)``
buffer (plus a parallel float32 momentum bank), so one round is exactly the
paper's two dense primitives — a single column-stochastic gossip matmul
``X' = P @ X`` over the whole model and one fused momentum/descent/de-bias
elementwise pass — both dispatched to the Pallas kernels in
``repro.kernels`` (interpret mode on CPU, Mosaic on TPU).  Local training is
``vmap`` over bank rows; pytrees only reappear inside the loss closure via a
cached static unravel.  The seed per-leaf pytree path is retained
(``flat=False``) as the equivalence oracle and benchmark baseline.

Algorithm 1 (DFedSGPSM) is the flagship; all seven paper baselines plus the
ablation variant DFedSGPM are expressed as configurations of the same round.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import pushsum, topology
from repro.core.flat import make_spec
from repro.core.sam import (
    apply_update,
    momentum_update,
    sam_gradient,
)

__all__ = ["AlgoConfig", "ALGORITHMS", "FLState", "FLTrainer", "make_algo"]


@dataclasses.dataclass(frozen=True)
class AlgoConfig:
    """One federated-optimization algorithm = one point in this space."""

    name: str = "dfedsgpsm"
    comm: str = "directed"  # directed | symmetric | central
    local_steps: int = 5
    rho: float = 0.0  # SAM perturbation radius (0 = off)
    alpha: float = 0.0  # local momentum coefficient (0 = off)
    selection: bool = False  # DFedSGPSM-S neighbor selection
    lr: float = 0.1
    lr_decay: float = 0.998
    batch_size: int = 32
    # Beyond-paper: quantize gossip payloads to int8 (+ scales).
    quantize_gossip: bool = False


ALGORITHMS: dict[str, AlgoConfig] = {
    "fedavg": AlgoConfig("fedavg", "central"),
    "dpsgd": AlgoConfig("dpsgd", "symmetric", local_steps=1),
    "dfedavg": AlgoConfig("dfedavg", "symmetric"),
    "dfedavgm": AlgoConfig("dfedavgm", "symmetric", alpha=0.9),
    "dfedsam": AlgoConfig("dfedsam", "symmetric", rho=0.25),
    "sgp": AlgoConfig("sgp", "directed", local_steps=1),
    "osgp": AlgoConfig("osgp", "directed"),
    "dfedsgpm": AlgoConfig("dfedsgpm", "directed", alpha=0.9),
    "dfedsgpsm": AlgoConfig("dfedsgpsm", "directed", alpha=0.9, rho=0.1),
    "dfedsgpsm_s": AlgoConfig(
        "dfedsgpsm_s", "directed", alpha=0.9, rho=0.1, selection=True
    ),
}


def make_algo(name: str, **overrides) -> AlgoConfig:
    return dataclasses.replace(ALGORITHMS[name], **overrides)


class FLState(NamedTuple):
    params: Any  # flat (n, D) bank / (D,) central row; pytree when flat=False
    # End-of-round momentum bank, (n, D) float32 (None on the legacy path).
    # Algorithm 1 re-initializes v to zero each round, so training never
    # reads it back — it is carried for observability and checkpoint/warm-
    # restart of momentum-persistent variants.
    mom: Any
    w: jnp.ndarray  # (n,) push-sum weights (all-ones when unused)
    key: jax.Array
    round: jnp.ndarray  # int32 scalar
    losses: jnp.ndarray  # (n,) last local losses (drives selection)


def _sample_batch(data: dict, key: jax.Array, batch_size: int):
    m = data["x"].shape[0]
    idx = jax.random.randint(key, (batch_size,), 0, m)
    return {k: v[idx] for k, v in data.items()}


def _quantize_dequantize(tree):
    """Simulated int8 symmetric quantization of gossip payloads."""

    def qdq(x):
        flat_x = x.astype(jnp.float32)
        scale = jnp.max(jnp.abs(flat_x)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(flat_x / scale), -127, 127)
        return (q * scale).astype(x.dtype)

    return jax.tree.map(qdq, tree)


def _quantize_dequantize_rows(X: jnp.ndarray) -> jnp.ndarray:
    """Int8 symmetric quantization with one scale per client row of the
    flat bank — tighter than the per-leaf global scale of the pytree path."""
    Xf = X.astype(jnp.float32)
    scale = jnp.max(jnp.abs(Xf), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(Xf / scale), -127, 127)
    return (q * scale).astype(X.dtype)


class FLTrainer:
    """Drives rounds of a configured algorithm over client-partitioned data.

    Args:
      loss_fn: ``loss_fn(params, batch) -> (loss, accuracy)``.
      init_fn: ``init_fn(key) -> params`` for a single client.
      client_data: pytree whose leaves have leading dims (n_clients, m, ...).
      algo: AlgoConfig.
      topo: TopologyConfig (ignored for centralized algorithms).
      flat: run rounds on the flat (n, D) bank through the Pallas kernels
        (default); ``False`` selects the seed per-leaf pytree path.
    """

    def __init__(
        self,
        loss_fn: Callable,
        init_fn: Callable,
        client_data,
        algo: AlgoConfig,
        topo: topology.TopologyConfig,
        seed: int = 0,
        participation: float = 0.1,
        flat: bool = True,
    ):
        self.loss_fn = loss_fn
        self.init_fn = init_fn
        self.data = client_data
        self.algo = algo
        self.topo = topo
        self.participation = participation
        self.flat = flat
        self.n = topo.n_clients
        key = jax.random.PRNGKey(seed)
        pkey, self.key = jax.random.split(key)
        params0 = init_fn(pkey)
        self.spec = make_spec(params0)
        # Exponential graphs cycle through log2(n) hop matrices; precompute
        # the stack once so the (traced) round index can select the graph.
        self._exp_cycle = (
            topology.exponential_cycle(self.n)
            if topo.kind == "exponential" and topo.time_varying
            else None
        )
        w0 = jnp.ones((self.n,), jnp.float32)
        losses0 = jnp.zeros((self.n,), jnp.float32)
        if algo.comm == "central":
            p0 = self.spec.ravel(params0) if flat else params0
            self.state = FLState(p0, None, w0, self.key, jnp.int32(0), losses0)
        elif flat:
            row = self.spec.ravel(params0)
            bank = jnp.broadcast_to(row, (self.n, self.spec.dim))
            mom = jnp.zeros((self.n, self.spec.dim), jnp.float32)
            self.state = FLState(bank, mom, w0, self.key, jnp.int32(0), losses0)
        else:
            stacked = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (self.n,) + x.shape), params0
            )
            self.state = FLState(
                stacked, None, w0, self.key, jnp.int32(0), losses0
            )
        # Donate the state: the (n, D) banks are updated in place across
        # rounds instead of reallocating ~2 model copies per round.
        self._round_jit = jax.jit(self._round, donate_argnums=0)

    # -- local training, flat-bank path ------------------------------------

    def _local_update_bank(self, X, w, ckeys, data, lr):
        """K iterations of Algorithm 1 lines 4-11 for all clients at once:
        gradients are vmapped over bank rows, the momentum/descent/de-bias
        step is one fused kernel call on the whole bank."""
        from repro.kernels import ops as kops

        algo = self.algo
        V0 = jnp.zeros_like(X, jnp.float32)

        def grad_one(x_i, w_i, key_i, data_i):
            key_i, bk = jax.random.split(key_i)
            batch = _sample_batch(data_i, bk, algo.batch_size)
            # Unravel OUTSIDE the differentiated closure, fusing the line-5
            # de-bias into the leaf slices; the gradient stays leaf-shaped
            # (no scatter back into a (D,) row per leaf) and is ravelled
            # once — one contiguous write per client.
            z_tree = jax.tree.map(lambda p: p / w_i, self.spec.unravel(x_i))
            g_tree, (loss, acc) = sam_gradient(
                self.loss_fn, z_tree, batch, algo.rho
            )  # lines 6-8
            return key_i, g_tree, loss, acc

        if algo.alpha == 0.0:
            # Momentum off: v' = g exactly, so the momentum bank is never
            # read — keep it out of the scan carry and let XLA fold
            # ``0 * 0 + g`` and DCE the v write on the CPU inline path.
            zeros = jnp.zeros(X.shape, jnp.float32)

            def step0(carry, _):
                X, keys = carry
                keys, G_tree, losses, accs = jax.vmap(grad_one)(X, w, keys, data)
                G = self.spec.ravel_stacked(G_tree)  # one contiguous write
                X, _, _ = kops.fused_update_bank(X, zeros, G, 0.0, lr, w)
                return (X, keys), (losses, accs)

            (X, _), (losses, accs) = jax.lax.scan(
                step0, (X, ckeys), None, length=algo.local_steps
            )
            return X, V0, losses.mean(axis=0), accs.mean(axis=0)

        def step(carry, _):
            X, V, keys = carry
            keys, G_tree, losses, accs = jax.vmap(grad_one)(X, w, keys, data)
            G = self.spec.ravel_stacked(G_tree)  # one contiguous write
            # Lines 9-11 fused over the whole bank.  The de-biased z output
            # feeds the next TPU iteration from VMEM; on the CPU inline
            # path it is unused here and dead-code eliminated.
            X, V, _ = kops.fused_update_bank(X, V, G, algo.alpha, lr, w)
            return (X, V, keys), (losses, accs)

        (X, V, _), (losses, accs) = jax.lax.scan(
            step, (X, V0, ckeys), None, length=algo.local_steps
        )
        return X, V, losses.mean(axis=0), accs.mean(axis=0)

    # -- local training, legacy pytree path --------------------------------

    def _local_update(self, params_i, w_i, key_i, data_i, lr):
        """K iterations of Algorithm 1 lines 4-11 for one client."""
        algo = self.algo
        v0 = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params_i)

        def step(carry, _):
            x, v, key = carry
            key, bk = jax.random.split(key)
            batch = _sample_batch(data_i, bk, algo.batch_size)
            z = jax.tree.map(lambda p: p / w_i, x)  # line 5: de-bias
            g, (loss, acc) = sam_gradient(self.loss_fn, z, batch, algo.rho)  # 6-8
            v = momentum_update(v, g, algo.alpha)  # line 9
            x = apply_update(x, v, lr)  # line 10
            return (x, v, key), (loss, acc)

        (x, _, _), (losses, accs) = jax.lax.scan(
            step, (params_i, v0, key_i), None, length=algo.local_steps
        )
        return x, losses.mean(), accs.mean()

    # -- mixing-matrix selection -------------------------------------------

    def _mixing(self, tkey, state: FLState):
        algo = self.algo
        k_link = max(int(self.participation * self.n), 1)
        if algo.comm == "symmetric":
            return topology.sample_symmetric_k_regular(tkey, self.n, k_link)
        if algo.selection:
            return topology.sample_kout_selective(
                tkey, state.losses, self.n, k_link
            )
        if self._exp_cycle is not None:
            # Time-varying exponential graph: round t uses cycle[t % hops].
            hops = self._exp_cycle.shape[0]
            return self._exp_cycle[jnp.mod(state.round, hops)]
        return topology.sample_mixing(tkey, self.topo, t=0)

    # -- one communication round -------------------------------------------

    def _round(self, state: FLState):
        algo = self.algo
        lr = algo.lr * algo.lr_decay ** state.round.astype(jnp.float32)
        keys = jax.random.split(state.key, 2 + self.n)
        key, tkey, ckeys = keys[0], keys[1], keys[2:]

        if algo.comm == "central":
            return self._fedavg_round(state, lr, key, tkey, ckeys)
        if self.flat:
            return self._round_flat(state, lr, key, tkey, ckeys)
        return self._round_pytree(state, lr, key, tkey, ckeys)

    def _round_flat(self, state, lr, key, tkey, ckeys):
        algo = self.algo
        X, V, losses, accs = self._local_update_bank(
            state.params, state.w, ckeys, self.data, lr
        )
        if algo.quantize_gossip:
            X = _quantize_dequantize_rows(X)
        P = self._mixing(tkey, state)
        X = pushsum.gossip_bank(P, X)  # the whole model in one matmul
        w_new = (
            pushsum.gossip_weights(P, state.w)
            if algo.comm == "directed"
            else state.w
        )
        new_state = FLState(X, V, w_new, key, state.round + 1, losses)
        return new_state, {"loss": losses.mean(), "acc": accs.mean()}

    def _round_pytree(self, state, lr, key, tkey, ckeys):
        algo = self.algo
        x_half, losses, accs = jax.vmap(
            self._local_update, in_axes=(0, 0, 0, 0, None)
        )(state.params, state.w, ckeys, self.data, lr)

        if algo.quantize_gossip:
            x_half = _quantize_dequantize(x_half)

        P = self._mixing(tkey, state)
        x_new = pushsum.gossip(P, x_half)
        w_new = (
            pushsum.gossip_weights(P, state.w)
            if algo.comm == "directed"
            else state.w
        )
        new_state = FLState(x_new, None, w_new, key, state.round + 1, losses)
        return new_state, {"loss": losses.mean(), "acc": accs.mean()}

    def _fedavg_round(self, state, lr, key, tkey, ckeys):
        m = max(int(self.participation * self.n), 1)
        sel = jax.random.permutation(tkey, self.n)[:m]

        if self.flat:
            data_sel = jax.tree.map(lambda d: d[sel], self.data)
            Xrep = jnp.broadcast_to(state.params, (m,) + state.params.shape)
            ones = jnp.ones((m,), jnp.float32)
            X, _, losses, accs = self._local_update_bank(
                Xrep, ones, ckeys[:m], data_sel, lr
            )
            new_params = X.mean(axis=0)
        else:
            def client(i, k):
                data_i = jax.tree.map(lambda d: d[i], self.data)
                return self._local_update(
                    state.params, jnp.float32(1.0), k, data_i, lr
                )

            xs, losses, accs = jax.vmap(client)(sel, ckeys[:m])
            new_params = jax.tree.map(lambda s: s.mean(axis=0), xs)
        new_state = FLState(
            new_params, state.mom, state.w, key, state.round + 1, state.losses
        )
        return new_state, {"loss": losses.mean(), "acc": accs.mean()}

    # -- public API ----------------------------------------------------------

    def run_round(self):
        self.state, metrics = self._round_jit(self.state)
        return metrics

    def average_model(self):
        """Consensus model x̄ (Algorithm 1 output)."""
        if self.algo.comm == "central":
            if self.flat:
                return self.spec.unravel(self.state.params)
            return self.state.params
        if self.flat:
            return self.spec.unravel(self.state.params.mean(axis=0))
        return jax.tree.map(lambda x: x.mean(axis=0), self.state.params)

    def debiased_models(self):
        if self.flat and self.algo.comm != "central":
            z = pushsum.debias_bank(self.state.params, self.state.w)
            return self.spec.unravel_stacked(z)
        return pushsum.debias(self.state.params, self.state.w)

    def consensus_error(self):
        """Mean squared distance of de-biased params from the average."""
        if self.flat and self.algo.comm != "central":
            return pushsum.consensus_error_bank(self.state.params, self.state.w)
        return pushsum.consensus_error(self.state.params, self.state.w)

    @partial(jax.jit, static_argnums=0)
    def _eval(self, params, test_data):
        loss, acc = self.loss_fn(params, test_data)
        return loss, acc

    def evaluate(self, test_data, batch: int = 1024):
        params = self.average_model()
        n = test_data["x"].shape[0]
        tot_l, tot_a, seen = 0.0, 0.0, 0
        for i in range(0, n, batch):
            chunk = {k: v[i : i + batch] for k, v in test_data.items()}
            l, a = self._eval(params, chunk)
            b = chunk["x"].shape[0]
            tot_l += float(l) * b
            tot_a += float(a) * b
            seen += b
        return tot_l / seen, tot_a / seen

    def fit(self, rounds: int, test_data=None, eval_every: int = 0, log=None):
        history = []
        for r in range(rounds):
            metrics = self.run_round()
            rec = {"round": r, **{k: float(v) for k, v in metrics.items()}}
            if test_data is not None and eval_every and (r + 1) % eval_every == 0:
                tl, ta = self.evaluate(test_data)
                rec.update(test_loss=tl, test_acc=ta)
            history.append(rec)
            if log:
                log(rec)
        return history
