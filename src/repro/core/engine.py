"""Stacked-client simulation engine for (decentralized) federated learning.

Every client's parameters live as the leading axis of a pytree
(``(n_clients, ...)`` per leaf).  Local training is ``vmap`` over clients,
communication is a column-stochastic mixing matmul (push-sum for directed
graphs, Metropolis doubly-stochastic for symmetric baselines), and the whole
round is one jitted function — the engine scales to the paper's 100-client
CIFAR setting on a single host and to pod-sharded execution via pjit.

Algorithm 1 (DFedSGPSM) is the flagship; all seven paper baselines plus the
ablation variant DFedSGPM are expressed as configurations of the same round.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import pushsum, topology
from repro.core.sam import (
    apply_update,
    momentum_update,
    sam_gradient,
)

__all__ = ["AlgoConfig", "ALGORITHMS", "FLState", "FLTrainer", "make_algo"]


@dataclasses.dataclass(frozen=True)
class AlgoConfig:
    """One federated-optimization algorithm = one point in this space."""

    name: str = "dfedsgpsm"
    comm: str = "directed"  # directed | symmetric | central
    local_steps: int = 5
    rho: float = 0.0  # SAM perturbation radius (0 = off)
    alpha: float = 0.0  # local momentum coefficient (0 = off)
    selection: bool = False  # DFedSGPSM-S neighbor selection
    lr: float = 0.1
    lr_decay: float = 0.998
    batch_size: int = 32
    # Beyond-paper: quantize gossip payloads to int8 (+ scales).
    quantize_gossip: bool = False


ALGORITHMS: dict[str, AlgoConfig] = {
    "fedavg": AlgoConfig("fedavg", "central"),
    "dpsgd": AlgoConfig("dpsgd", "symmetric", local_steps=1),
    "dfedavg": AlgoConfig("dfedavg", "symmetric"),
    "dfedavgm": AlgoConfig("dfedavgm", "symmetric", alpha=0.9),
    "dfedsam": AlgoConfig("dfedsam", "symmetric", rho=0.25),
    "sgp": AlgoConfig("sgp", "directed", local_steps=1),
    "osgp": AlgoConfig("osgp", "directed"),
    "dfedsgpm": AlgoConfig("dfedsgpm", "directed", alpha=0.9),
    "dfedsgpsm": AlgoConfig("dfedsgpsm", "directed", alpha=0.9, rho=0.1),
    "dfedsgpsm_s": AlgoConfig(
        "dfedsgpsm_s", "directed", alpha=0.9, rho=0.1, selection=True
    ),
}


def make_algo(name: str, **overrides) -> AlgoConfig:
    return dataclasses.replace(ALGORITHMS[name], **overrides)


class FLState(NamedTuple):
    params: Any  # stacked (n, ...) for decentralized; global pytree for CFL
    w: jnp.ndarray  # (n,) push-sum weights (all-ones when unused)
    key: jax.Array
    round: jnp.ndarray  # int32 scalar
    losses: jnp.ndarray  # (n,) last local losses (drives selection)


def _sample_batch(data: dict, key: jax.Array, batch_size: int):
    m = data["x"].shape[0]
    idx = jax.random.randint(key, (batch_size,), 0, m)
    return {k: v[idx] for k, v in data.items()}


def _quantize_dequantize(tree):
    """Simulated int8 symmetric quantization of gossip payloads."""

    def qdq(x):
        flat = x.astype(jnp.float32)
        scale = jnp.max(jnp.abs(flat)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(flat / scale), -127, 127)
        return (q * scale).astype(x.dtype)

    return jax.tree.map(qdq, tree)


class FLTrainer:
    """Drives rounds of a configured algorithm over client-partitioned data.

    Args:
      loss_fn: ``loss_fn(params, batch) -> (loss, accuracy)``.
      init_fn: ``init_fn(key) -> params`` for a single client.
      client_data: pytree whose leaves have leading dims (n_clients, m, ...).
      algo: AlgoConfig.
      topo: TopologyConfig (ignored for centralized algorithms).
    """

    def __init__(
        self,
        loss_fn: Callable,
        init_fn: Callable,
        client_data,
        algo: AlgoConfig,
        topo: topology.TopologyConfig,
        seed: int = 0,
        participation: float = 0.1,
    ):
        self.loss_fn = loss_fn
        self.init_fn = init_fn
        self.data = client_data
        self.algo = algo
        self.topo = topo
        self.participation = participation
        self.n = topo.n_clients
        key = jax.random.PRNGKey(seed)
        pkey, self.key = jax.random.split(key)
        params0 = init_fn(pkey)
        if algo.comm == "central":
            self.state = FLState(
                params0,
                jnp.ones((self.n,), jnp.float32),
                self.key,
                jnp.int32(0),
                jnp.zeros((self.n,), jnp.float32),
            )
        else:
            stacked = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (self.n,) + x.shape), params0
            )
            self.state = FLState(
                stacked,
                jnp.ones((self.n,), jnp.float32),
                self.key,
                jnp.int32(0),
                jnp.zeros((self.n,), jnp.float32),
            )
        self._round_jit = jax.jit(self._round)

    # -- local training ----------------------------------------------------

    def _local_update(self, params_i, w_i, key_i, data_i, lr):
        """K iterations of Algorithm 1 lines 4-11 for one client."""
        algo = self.algo
        v0 = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params_i)

        def step(carry, _):
            x, v, key = carry
            key, bk = jax.random.split(key)
            batch = _sample_batch(data_i, bk, algo.batch_size)
            z = jax.tree.map(lambda p: p / w_i, x)  # line 5: de-bias
            g, (loss, acc) = sam_gradient(self.loss_fn, z, batch, algo.rho)  # 6-8
            v = momentum_update(v, g, algo.alpha)  # line 9
            x = apply_update(x, v, lr)  # line 10
            return (x, v, key), (loss, acc)

        (x, _, _), (losses, accs) = jax.lax.scan(
            step, (params_i, v0, key_i), None, length=algo.local_steps
        )
        return x, losses.mean(), accs.mean()

    # -- one communication round -------------------------------------------

    def _round(self, state: FLState):
        algo = self.algo
        lr = algo.lr * algo.lr_decay ** state.round.astype(jnp.float32)
        keys = jax.random.split(state.key, 2 + self.n)
        key, tkey, ckeys = keys[0], keys[1], keys[2:]

        if algo.comm == "central":
            return self._fedavg_round(state, lr, key, tkey, ckeys)

        x_half, losses, accs = jax.vmap(
            self._local_update, in_axes=(0, 0, 0, 0, None)
        )(state.params, state.w, ckeys, self.data, lr)

        if algo.quantize_gossip:
            x_half = _quantize_dequantize(x_half)

        k_link = max(int(self.participation * self.n), 1)
        if algo.comm == "symmetric":
            P = topology.sample_symmetric_k_regular(tkey, self.n, k_link)
        elif algo.selection:
            P = topology.sample_kout_selective(tkey, state.losses, self.n, k_link)
        else:
            P = topology.sample_mixing(tkey, self.topo, t=0)

        x_new = pushsum.gossip(P, x_half)
        w_new = (
            pushsum.gossip_weights(P, state.w)
            if algo.comm == "directed"
            else state.w
        )
        new_state = FLState(x_new, w_new, key, state.round + 1, losses)
        return new_state, {"loss": losses.mean(), "acc": accs.mean()}

    def _fedavg_round(self, state, lr, key, tkey, ckeys):
        m = max(int(self.participation * self.n), 1)
        sel = jax.random.permutation(tkey, self.n)[:m]

        def client(i, k):
            data_i = jax.tree.map(lambda d: d[i], self.data)
            return self._local_update(
                state.params, jnp.float32(1.0), k, data_i, lr
            )

        xs, losses, accs = jax.vmap(client)(sel, ckeys[:m])
        new_params = jax.tree.map(lambda s: s.mean(axis=0), xs)
        new_state = FLState(
            new_params, state.w, key, state.round + 1, state.losses
        )
        return new_state, {"loss": losses.mean(), "acc": accs.mean()}

    # -- public API ----------------------------------------------------------

    def run_round(self):
        self.state, metrics = self._round_jit(self.state)
        return metrics

    def average_model(self):
        """Consensus model x̄ (Algorithm 1 output)."""
        if self.algo.comm == "central":
            return self.state.params
        return jax.tree.map(lambda x: x.mean(axis=0), self.state.params)

    def debiased_models(self):
        return pushsum.debias(self.state.params, self.state.w)

    @partial(jax.jit, static_argnums=0)
    def _eval(self, params, test_data):
        loss, acc = self.loss_fn(params, test_data)
        return loss, acc

    def evaluate(self, test_data, batch: int = 1024):
        params = self.average_model()
        n = test_data["x"].shape[0]
        tot_l, tot_a, seen = 0.0, 0.0, 0
        for i in range(0, n, batch):
            chunk = {k: v[i : i + batch] for k, v in test_data.items()}
            l, a = self._eval(params, chunk)
            b = chunk["x"].shape[0]
            tot_l += float(l) * b
            tot_a += float(a) * b
            seen += b
        return tot_l / seen, tot_a / seen

    def fit(self, rounds: int, test_data=None, eval_every: int = 0, log=None):
        history = []
        for r in range(rounds):
            metrics = self.run_round()
            rec = {"round": r, **{k: float(v) for k, v in metrics.items()}}
            if test_data is not None and eval_every and (r + 1) % eval_every == 0:
                tl, ta = self.evaluate(test_data)
                rec.update(test_loss=tl, test_acc=ta)
            history.append(rec)
            if log:
                log(rec)
        return history
