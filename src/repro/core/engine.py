"""Stacked-client simulation engine for (decentralized) federated learning.

The engine is a **composable round program** (``repro.core.program``): one
algorithm = a (LocalSolver, Compressor, Mixer) stage composition from
``repro.core.stages`` over the flat ``(n_clients, D)`` client-parameter
bank, so one round is exactly the paper's two dense primitives — a single
column-stochastic gossip matmul ``X' = P @ X`` over the whole model and one
fused momentum/descent/de-bias elementwise pass — both dispatched to the
Pallas kernels in ``repro.kernels`` (interpret mode on CPU, Mosaic on TPU).

``AlgoConfig`` is the declarative point in that composition space and
``ALGORITHMS`` expresses Algorithm 1 (DFedSGPSM, the flagship), all seven
paper baselines, and the DFedSGPM ablation as registry compositions.
:class:`FLTrainer` is a thin stateful wrapper over the pure
``program.init``/``program.step`` core; the seed per-leaf pytree path is
retained (``flat=False``) as the equivalence oracle and benchmark baseline.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import pushsum, topology
from repro.core.program import FLState, RoundProgram, make_program
from repro.core.stages import _sample_batch
from repro.core.sam import (
    apply_update,
    momentum_update,
    sam_gradient,
)

__all__ = [
    "AlgoConfig",
    "ALGORITHMS",
    "FLState",
    "FLTrainer",
    "RoundProgram",
    "make_algo",
    "make_program",
]


@dataclasses.dataclass(frozen=True)
class AlgoConfig:
    """One federated-optimization algorithm = one stage composition.

    ``solver`` / ``compressor`` / ``comm`` name entries in the
    ``repro.core.stages`` registries (``comm`` selects the mixer:
    directed | symmetric | central); the scalar fields are the stage
    hyperparameters.
    """

    name: str = "dfedsgpsm"
    comm: str = "directed"  # mixer: directed | symmetric | central
    local_steps: int = 5
    rho: float = 0.0  # SAM perturbation radius (0 = off)
    alpha: float = 0.0  # local momentum coefficient (0 = off)
    selection: bool = False  # DFedSGPSM-S neighbor selection
    lr: float = 0.1
    lr_decay: float = 0.998
    batch_size: int = 32
    solver: str = "sam_momentum"  # sam_momentum | sgd | proximal
    compressor: str = "identity"  # identity | int8_rows | topk_ef
    topk_ratio: float = 0.05  # kept fraction per row (topk_ef)
    prox_mu: float = 0.01  # proximal pull strength (proximal solver)
    # Legacy spelling of ``compressor="int8_rows"`` (kept for the seed
    # pytree path, which quantizes per-leaf instead of per-row).
    quantize_gossip: bool = False


ALGORITHMS: dict[str, AlgoConfig] = {
    "fedavg": AlgoConfig("fedavg", "central"),
    "dpsgd": AlgoConfig("dpsgd", "symmetric", local_steps=1),
    "dfedavg": AlgoConfig("dfedavg", "symmetric"),
    "dfedavgm": AlgoConfig("dfedavgm", "symmetric", alpha=0.9),
    "dfedsam": AlgoConfig("dfedsam", "symmetric", rho=0.25),
    "sgp": AlgoConfig("sgp", "directed", local_steps=1),
    "osgp": AlgoConfig("osgp", "directed"),
    "dfedsgpm": AlgoConfig("dfedsgpm", "directed", alpha=0.9),
    "dfedsgpsm": AlgoConfig("dfedsgpsm", "directed", alpha=0.9, rho=0.1),
    "dfedsgpsm_s": AlgoConfig(
        "dfedsgpsm_s", "directed", alpha=0.9, rho=0.1, selection=True
    ),
}


def make_algo(name: str, **overrides) -> AlgoConfig:
    return dataclasses.replace(ALGORITHMS[name], **overrides)


def _quantize_dequantize(tree):
    """Simulated int8 symmetric quantization of gossip payloads (per-leaf
    global scale; the flat bank uses the tighter per-row Int8RowCompressor)."""

    def qdq(x):
        flat_x = x.astype(jnp.float32)
        scale = jnp.max(jnp.abs(flat_x)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(flat_x / scale), -127, 127)
        return (q * scale).astype(x.dtype)

    return jax.tree.map(qdq, tree)


class FLTrainer:
    """Thin stateful wrapper over the pure round program.

    Args:
      loss_fn: ``loss_fn(params, batch) -> (loss, accuracy)``.
      init_fn: ``init_fn(key) -> params`` for a single client.
      client_data: pytree whose leaves have leading dims (n_clients, m, ...).
      algo: AlgoConfig (a stage composition).
      topo: TopologyConfig (ignored for centralized algorithms).
      flat: run rounds on the flat (n, D) bank through the Pallas kernels
        (default); ``False`` selects the seed per-leaf pytree path, kept as
        the kernel-free equivalence oracle.
      gossip: mixing-operator representation — ``"auto"`` (density rule:
        neighbor-list sparse gossip once n is large and k_max/n small),
        or force ``"sparse"`` / ``"dense"``.
      link: unreliable-link scenario (``topology.LinkModel``): per-round
        edge drops (exactly column-stochastic after renormalization),
        bounded delivery delays, or event-triggered transmission.  ``None``
        (default) or an all-zero model is bitwise the perfect-link round.
      churn: node-failure scenario (``topology.ChurnModel``): whole
        clients crash and (optionally) rejoin per round; dead nodes leave
        the sampled operator wholesale and their push-sum mass freezes on
        the self-loop, keeping live + in-flight + frozen mass == n
        exactly.  Composes with ``link`` drops and delays.  ``None``
        (default) or an all-zero model is bitwise the immortal round.
      paged: virtual client population — the full (n, D) bank lives in a
        disk-backed :class:`repro.store.ClientStore` under ``store_dir``
        and each round pages in only its fault-in closure (the ``k_active``
        sampled clients plus their in-neighbors), with background prefetch
        and async write-back.  Device/host buffers scale with the closure,
        not n; the checkpoint is the store itself.  Directed push-sum,
        perfect links, single host only.
      delta: low-rank delta bank (``repro.core.DeltaConfig``, or just a
        rank / ``"full"``): clients share a frozen base model and bank
        rows hold only adapter payloads — ``(A, B)`` factors per selected
        2-D leaf, dense deltas for small leaves — so every bank consumer
        (gossip, EF residuals, link buffers, the paged store) shrinks from
        D to d_delta.  ``rank="full"`` reproduces the dense bank to float
        tolerance (the equivalence oracle).
      bank_dtype: storage dtype of the bank rows (e.g. ``jnp.bfloat16``);
        momentum and EF residuals stay float32, so error feedback remains
        exact.

    ``fit`` drives ``program.run_superstep`` — jit-resident supersteps of
    rounds with in-scan eval — and returns per-round history records; for
    the stacked device-side history or custom schedules use
    ``self.program`` (or ``repro.core.make_program``) directly.
    """

    def __init__(
        self,
        loss_fn: Callable,
        init_fn: Callable,
        client_data,
        algo: AlgoConfig,
        topo: topology.TopologyConfig,
        seed: int = 0,
        participation: float = 0.1,
        flat: bool = True,
        gossip: str = "auto",
        link: topology.LinkModel | None = None,
        churn: topology.ChurnModel | None = None,
        mesh=None,
        paged: bool = False,
        store_dir: str | None = None,
        k_active: int = 0,
        rows_per_chunk: int = 256,
        prefetch: bool = True,
        lru_rows: int | None = None,
        faults=None,
        delta=None,
        bank_dtype=None,
    ):
        if paged:
            if not flat:
                raise ValueError("paged training runs on the flat bank")
            if mesh is not None:
                raise ValueError("paged training is single-host; drop the "
                                 "mesh (disk, not devices, bounds n)")
            if link is not None and link.active:
                raise ValueError("paged training models perfect links only")
            if not store_dir:
                raise ValueError("paged=True needs store_dir")
            if k_active < 1:
                raise ValueError("paged=True needs k_active >= 1")
        elif faults is not None:
            raise ValueError(
                "faults= injects into the disk-backed store; it needs "
                "paged=True"
            )
        if not flat and mesh is not None:
            raise ValueError("the flat=False oracle path is single-device")
        if not flat and (delta is not None or bank_dtype is not None):
            raise ValueError(
                "the flat=False oracle path keeps full-precision per-leaf "
                "pytrees; delta=/bank_dtype= need the flat bank"
            )
        if not flat and link is not None and link.active:
            # The oracle predates the link subsystem; silently ignoring the
            # scenario would invalidate it as an equivalence baseline.
            raise ValueError(
                "the flat=False oracle path models perfect links only"
            )
        if not flat and churn is not None and churn.active:
            raise ValueError(
                "the flat=False oracle path models an immortal population "
                "only"
            )
        if not flat and (
            algo.solver != "sam_momentum"
            or algo.compressor not in ("identity", "int8_rows")
        ):
            # The oracle implements exactly the paper compositions; silently
            # running a different algorithm than the flat path would defeat
            # its purpose as the equivalence baseline.
            raise ValueError(
                "the flat=False oracle path only supports the "
                "sam_momentum solver with identity/int8_rows compression, "
                f"not solver={algo.solver!r} compressor={algo.compressor!r}"
            )
        self.loss_fn = loss_fn
        self.init_fn = init_fn
        self.data = client_data
        self.algo = algo
        self.topo = topo
        self.participation = participation
        self.flat = flat
        self.n = topo.n_clients
        # Paged mode drives churn host-side in the runner (dead clients
        # leave the sampling pool; the program itself stays churn-free).
        self.program = make_program(
            loss_fn, init_fn, client_data, algo, topo, participation,
            gossip=gossip, link=link,
            churn=None if paged else churn,
            mesh=mesh, delta=delta, bank_dtype=bank_dtype,
        )
        self.spec = self.program.spec
        self._exp_cycle = self.program.exp_cycle
        self.paged = paged
        self.runner = None

        key = jax.random.PRNGKey(seed)
        if paged:
            # The bank never materializes: the store holds the population,
            # the runner pages closures through program.step_active.
            from repro.store import PagedRunner

            self.runner = PagedRunner(
                self.program, store_dir, k_active, seed=seed,
                rows_per_chunk=rows_per_chunk, prefetch=prefetch,
                lru_rows=lru_rows, churn=churn, faults=faults,
            )
            self.state = None
            self._round_jit = None
        elif flat:
            self.state = self.program.init(key)
            # Donate the state: the (n, D) banks are updated in place across
            # rounds instead of reallocating ~2 model copies per round.
            self._round_jit = jax.jit(self.program.step, donate_argnums=0)
        else:
            pkey, skey = jax.random.split(key)
            params0 = init_fn(pkey)
            w0 = jnp.ones((self.n,), jnp.float32)
            losses0 = jnp.zeros((self.n,), jnp.float32)
            if algo.comm == "central":
                self.state = FLState(
                    params0, None, w0, skey, jnp.int32(0), losses0
                )
            else:
                stacked = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (self.n,) + x.shape), params0
                )
                self.state = FLState(
                    stacked, None, w0, skey, jnp.int32(0), losses0
                )
            self._round_jit = jax.jit(self._round_legacy, donate_argnums=0)

        # Flat path: evaluate() compiles program.make_eval_fn — the same
        # masked fixed-shape eval run_superstep uses in-scan, so the two
        # can never drift numerically.  Entries hold a strong test_data
        # reference so the id() key cannot alias a freed dict.
        self._eval_cache: dict = {}

        # Legacy (flat=False) path: per-chunk masked eval over the pytree
        # params.  Every chunk is padded to the same batch size, so this
        # compiles once per trainer and never re-traces on the ragged final
        # chunk.  Per-example metrics are vmapped so the pad rows can be
        # masked out of the sums exactly.
        def _masked_eval(params, chunk, mask):
            def one(ex):
                return self.loss_fn(
                    params, jax.tree.map(lambda v: v[None], ex)
                )

            per_l, per_a = jax.vmap(one)(chunk)
            # where, not multiply: a non-finite loss on a zero pad row
            # (user loss_fns may divide by input norms) must not poison
            # the masked sum via NaN * 0.
            return (jnp.sum(jnp.where(mask, per_l, 0.0)),
                    jnp.sum(jnp.where(mask, per_a, 0.0)))

        self._eval_jit = jax.jit(_masked_eval)

    # -- legacy per-leaf pytree path (equivalence oracle) -------------------

    def _local_update(self, params_i, w_i, key_i, data_i, lr):
        """K iterations of Algorithm 1 lines 4-11 for one client."""
        algo = self.algo
        v0 = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params_i)

        def step(carry, _):
            x, v, key = carry
            key, bk = jax.random.split(key)
            batch = _sample_batch(data_i, bk, algo.batch_size)
            z = jax.tree.map(lambda p: p / w_i, x)  # line 5: de-bias
            g, (loss, acc) = sam_gradient(self.loss_fn, z, batch, algo.rho)  # 6-8
            v = momentum_update(v, g, algo.alpha)  # line 9
            x = apply_update(x, v, lr)  # line 10
            return (x, v, key), (loss, acc)

        (x, _, _), (losses, accs) = jax.lax.scan(
            step, (params_i, v0, key_i), None, length=algo.local_steps
        )
        return x, losses.mean(), accs.mean()

    def _round_legacy(self, state: FLState):
        algo = self.algo
        lr = algo.lr * algo.lr_decay ** state.round.astype(jnp.float32)
        keys = jax.random.split(state.key, 2 + self.n)
        key, tkey, ckeys = keys[0], keys[1], keys[2:]

        if algo.comm == "central":
            return self._fedavg_round_legacy(state, lr, key, tkey, ckeys)

        x_half, losses, accs = jax.vmap(
            self._local_update, in_axes=(0, 0, 0, 0, None)
        )(state.params, state.w, ckeys, self.data, lr)

        x_send = x_half
        if algo.quantize_gossip or algo.compressor == "int8_rows":
            x_send = _quantize_dequantize(x_half)

        P = self._mixing(tkey, state)
        # The oracle path stays off-kernel by construction — it is what the
        # kernel-backed flat path is validated against.
        x_new = pushsum.gossip(P, x_send, use_kernel=False)
        if x_send is not x_half:
            # Same compressed-gossip semantics as the flat path: the
            # self-loop P[ii]·x_i is local memory and is never quantized.
            from repro.core.stages import _self_weights

            s = _self_weights(P)

            def fresh_self(xn, xh, xq):
                shape = (xn.shape[0],) + (1,) * (xn.ndim - 1)
                return xn + (s.reshape(shape) * (xh - xq)).astype(xn.dtype)

            x_new = jax.tree.map(fresh_self, x_new, x_half, x_send)
        w_new = (
            pushsum.gossip_weights(P, state.w)
            if algo.comm == "directed"
            else state.w
        )
        new_state = FLState(x_new, None, w_new, key, state.round + 1, losses)
        return new_state, {"loss": losses.mean(), "acc": accs.mean()}

    def _fedavg_round_legacy(self, state, lr, key, tkey, ckeys):
        m = max(int(self.participation * self.n), 1)
        sel = jax.random.permutation(tkey, self.n)[:m]

        def client(i, k):
            data_i = jax.tree.map(lambda d: d[i], self.data)
            return self._local_update(
                state.params, jnp.float32(1.0), k, data_i, lr
            )

        xs, losses, accs = jax.vmap(client)(sel, ckeys[:m])
        new_params = jax.tree.map(lambda s: s.mean(axis=0), xs)
        # Refresh the sampled clients' loss slots (parity with the flat
        # central step — the vector rides checkpoints and selection).
        new_state = FLState(
            new_params, state.mom, state.w, key, state.round + 1,
            state.losses.at[sel].set(losses)
        )
        return new_state, {"loss": losses.mean(), "acc": accs.mean()}

    # -- mixing-matrix selection (delegates to the program) -----------------

    def _mixing(self, tkey, state: FLState):
        return self.program.mixing_matrix(tkey, state)

    # -- public API ----------------------------------------------------------

    def run_round(self):
        if self.paged:
            return self.runner.run_round()
        self.state, metrics = self._round_jit(self.state)
        return metrics

    def average_model(self):
        """Consensus model x̄ (Algorithm 1 output)."""
        if self.paged:
            # Streamed over store chunks; (n, D) never materializes.
            return self.spec.unravel(jnp.asarray(self.runner.mean_params()))
        if self.algo.comm == "central":
            if self.flat:
                return self.spec.unravel(self.state.params)
            return self.state.params
        if self.flat:
            return self.spec.unravel(self.state.params.mean(axis=0))
        return jax.tree.map(lambda x: x.mean(axis=0), self.state.params)

    def debiased_models(self):
        if self.paged:
            raise ValueError(
                "debiased_models materializes the full (n, D) bank — the "
                "point of paged mode is that it never exists; stream rows "
                "via trainer.runner.store.iter_chunks() instead"
            )
        if self.flat and self.algo.comm != "central":
            from repro.core.flat import BoundDeltaSpec

            if isinstance(self.spec, BoundDeltaSpec):
                # Delta rows de-bias through the spec: z_i = base +
                # expand(row_i) / w_i (the dense-row division would divide
                # the frozen base by w too).
                return self.spec.debias_stacked(
                    self.state.params, self.state.w
                )
            z = pushsum.debias_bank(self.state.params, self.state.w)
            return self.spec.unravel_stacked(z)
        return pushsum.debias(self.state.params, self.state.w)

    def consensus_error(self):
        """Mean squared distance of de-biased params from the average."""
        if self.paged:
            return self.runner.consensus_error()
        if self.flat and self.algo.comm != "central":
            return pushsum.consensus_error_bank(self.state.params, self.state.w)
        return pushsum.consensus_error(self.state.params, self.state.w)

    def evaluate(self, test_data, batch: int = 1024):
        if self.flat and not self.paged:
            # Exactly the in-scan eval of run_superstep, jitted standalone.
            key = (id(test_data), batch)
            entry = self._eval_cache.get(key)
            if entry is None:
                entry = (
                    jax.jit(self.program.make_eval_fn(test_data, batch)),
                    test_data,
                )
                self._eval_cache[key] = entry
            tl, ta = entry[0](self.state)
            return float(tl), float(ta)
        params = self.average_model()
        n = test_data["x"].shape[0]
        tot_l, tot_a = 0.0, 0.0
        for i in range(0, n, batch):
            chunk = {k: v[i : i + batch] for k, v in test_data.items()}
            b = chunk["x"].shape[0]
            if b < batch:  # pad to the fixed shape; the mask strips it
                chunk = {
                    k: jnp.concatenate(
                        [v, jnp.zeros((batch - b,) + v.shape[1:], v.dtype)]
                    )
                    for k, v in chunk.items()
                }
            mask = jnp.arange(batch) < b
            l, a = self._eval_jit(params, chunk, mask)
            tot_l += float(l)
            tot_a += float(a)
        return tot_l / n, tot_a / n

    def fit(
        self,
        rounds: int,
        test_data=None,
        eval_every: int = 0,
        log=None,
        superstep: int = 0,
    ):
        """Train ``rounds`` rounds and return the per-round history.

        On the flat path this drives ``program.run_superstep``: rounds are
        ``lax.scan``-ned inside one jit per superstep with donated carry and
        the eval runs *in-scan* at the ``eval_every`` cadence (keyed on the
        global round counter, so chunked supersteps and checkpoint resume
        keep the same schedule).  The host — history records and the ``log``
        callback — is only touched at superstep boundaries.

        Args:
          superstep: rounds per jit-resident scan chunk; ``0`` (default)
            runs all ``rounds`` as one superstep.  The ``flat=False`` oracle
            path keeps the per-round Python loop regardless.
        """
        if not self.flat or self.paged:
            # Paged rounds are host-orchestrated by design (the plan /
            # prefetch / write-back pipeline IS the host loop).
            return self._fit_python_loop(rounds, test_data, eval_every, log)
        history = []
        done = 0
        chunk = rounds if superstep <= 0 else superstep
        cadence = eval_every if test_data is not None else 0
        while done < rounds:
            length = min(chunk, rounds - done)
            self.state, hist = self.program.run_superstep(
                self.state, length, cadence, test_data
            )
            # ONE device->host transfer per superstep boundary; indexing
            # device arrays per round would re-introduce the per-round
            # syncs the scanned driver exists to eliminate.
            hist = jax.device_get(hist)
            evals = hist.get("eval_mask")
            for i in range(length):
                rec = {
                    "round": done + i,
                    "loss": float(hist["loss"][i]),
                    "acc": float(hist["acc"][i]),
                }
                # Link-scenario extras: transmitted fraction (event-
                # triggered rounds) and the exact-mass invariant.
                for k in ("comm_fraction", "w_mass", "w_inflight"):
                    if k in hist:
                        rec[k] = float(hist[k][i])
                if evals is not None and bool(evals[i]):
                    rec["test_loss"] = float(hist["test_loss"][i])
                    rec["test_acc"] = float(hist["test_acc"][i])
                history.append(rec)
                if log:
                    log(rec)
            done += length
        return history

    def _fit_python_loop(self, rounds, test_data, eval_every, log):
        """Per-round host loop — the ``flat=False`` oracle's and the paged
        runner's driver.  Paged trainers additionally stream a
        full-population eval (``PagedRunner.eval_population``) at the same
        cadence: cold chunks flow through ``store.iter_chunks`` so the
        record carries population metrics and their delta against the hot
        closure's view — eval breadth the closure alone cannot give."""
        history = []
        for r in range(rounds):
            metrics = self.run_round()
            rec = {"round": r, **{k: float(v) for k, v in metrics.items()}}
            if eval_every and (r + 1) % eval_every == 0:
                if test_data is not None:
                    tl, ta = self.evaluate(test_data)
                    rec.update(test_loss=tl, test_acc=ta)
                if self.paged:
                    rec.update(self.runner.eval_population(
                        closure_loss=metrics.get("loss")
                    ))
            history.append(rec)
            if log:
                log(rec)
        return history

    # -- checkpointing (full FLState) ---------------------------------------

    def save(self, directory: str | None = None, step: int = 0,
             keep: int = 3) -> str:
        """Checkpoint the full ``FLState`` (params + momentum bank +
        push-sum weights + round + key + compressor state).

        Paged trainers ignore ``directory``/``step``/``keep``: the
        checkpoint IS the store — ``save`` flushes dirty rows and commits
        ``(round, key)`` into the store manifest, returning the store path.
        """
        from repro import checkpoint

        if self.paged:
            return self.runner.save()
        if not self.flat:
            raise ValueError("full-state checkpointing needs the flat path")
        if directory is None:
            raise ValueError("save() needs a checkpoint directory")
        return checkpoint.save_state(
            directory, step, self.state, self.spec, keep=keep
        )

    def restore(self, path: str) -> FLState:
        """Warm-restart from a full-``FLState`` checkpoint (paged trainers
        re-sync to their store's last committed manifest)."""
        from repro import checkpoint

        if self.paged:
            self.runner.restore(path)
            return None
        if not self.flat:
            raise ValueError("full-state checkpointing needs the flat path")
        state = checkpoint.restore_state(path, self.spec)
        # Fail fast on compressor-state mismatch: a stateful compressor fed
        # an empty comp (or vice versa) would otherwise crash opaquely at
        # trace time inside the next round.
        needs = self.program.compressor.stateful
        has = not (isinstance(state.comp, tuple) and state.comp == ())
        if needs and not has:
            raise ValueError(
                f"{path} carries no compressor state, but "
                f"compressor={self.algo.compressor!r} needs its residual "
                "bank — it was saved from a stateless composition"
            )
        if has and not needs:
            raise ValueError(
                f"{path} carries compressor state, but this trainer's "
                f"compressor={self.algo.compressor!r} is stateless"
            )
        has_link = not (isinstance(state.link, tuple) and state.link == ())
        if self.program.linked != has_link:
            raise ValueError(
                f"{path} {'carries' if has_link else 'carries no'} "
                "unreliable-link state, but this trainer's link scenario "
                f"{'does not use' if has_link else 'needs'} it — restore "
                "with the composition that saved it"
            )
        if has_link:
            # Presence is not enough: a delayed carry restored into an
            # event-triggered program (or a different delay bound) would
            # crash opaquely inside the next traced round — compare the
            # buffer structure against what this mixer actually carries.
            want = self.program.mixer.link_buffers(state.params)
            for field in ("bufx", "bufw", "last"):
                have = getattr(state.link, field)
                exp = want.get(field)
                have_arr = not isinstance(have, tuple)
                if have_arr != (exp is not None) or (
                    have_arr and tuple(have.shape) != tuple(exp.shape)
                ):
                    raise ValueError(
                        f"{path} link carry field {field!r} is "
                        f"{tuple(have.shape) if have_arr else 'absent'}, "
                        "but this trainer's link composition expects "
                        f"{tuple(exp.shape) if exp is not None else 'none'}"
                        " — restore with the composition that saved it"
                    )
        has_churn = not (
            isinstance(state.churn, tuple) and state.churn == ()
        )
        if self.program.churned != has_churn:
            raise ValueError(
                f"{path} {'carries' if has_churn else 'carries no'} "
                "node-churn state, but this trainer's churn scenario "
                f"{'does not use' if has_churn else 'needs'} it — restore "
                "with the composition that saved it"
            )
        if has_churn:
            cold = self.program.churn_model.resurrect == "cold"
            has_tpl = not isinstance(state.churn.tpl, tuple)
            if cold != has_tpl:
                raise ValueError(
                    f"{path} churn carry "
                    f"{'holds' if has_tpl else 'holds no'} cold-"
                    "resurrection template row, but this trainer's "
                    f"ChurnModel.resurrect="
                    f"{self.program.churn_model.resurrect!r} — restore "
                    "with the composition that saved it"
                )
        # Re-place host-loaded leaves on the program mesh (identity when
        # unsharded) so a resumed run is row-sharded from its first round.
        self.state = self.program.shard_state(state)
        return self.state
