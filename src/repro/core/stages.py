"""Composable round stages: LocalSolver / Compressor / Mixer.

Algorithm 1 of the paper is three stages, and so is every DFL variant in
the related work — same round, different stage:

  LocalSolver   lines 4-11: K local iterations over the flat (n, D) bank
                (SAM two-pass gradients + momentum; plain SGD and a
                FedProx-style proximal solver are drop-in swaps).
  Compressor    what leaves the client before communication: identity,
                per-row int8 quantize/dequantize, or top-k sparsification
                with error feedback (persistent residual state).
  Mixer         lines 12-14: push-sum over a directed column-stochastic
                matrix, doubly-stochastic symmetric gossip (DFedSAM), or a
                central server reduce (FedAvg).

Every stage is a frozen config dataclass with a pure ``init_state`` /
``apply``-style method pair operating on the flat ``(n_clients, D)`` bank,
so the Pallas ``gossip_matmul`` / ``fused_update`` kernels stay the hot
path and any composition is jittable and ``lax.scan``-able end to end.
``repro.core.program`` wires three stages into a round program; the
``SOLVERS`` / ``COMPRESSORS`` / ``MIXERS`` registries map ``AlgoConfig``
fields to stage instances.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import pushsum
from repro.core.sam import sam_gradient
from repro.kernels import ops as kops

__all__ = [
    "SamMomentumSolver",
    "ProximalSolver",
    "IdentityCompressor",
    "Int8RowCompressor",
    "TopKEFCompressor",
    "LinkState",
    "ChurnState",
    "PushSumMixer",
    "SymmetricMixer",
    "DelayedPushSumMixer",
    "EventTriggeredMixer",
    "CentralMixer",
    "SOLVERS",
    "COMPRESSORS",
    "MIXERS",
    "make_stages",
    "comm_phase",
]


def _sample_batch(data: dict, key: jax.Array, batch_size: int):
    m = data["x"].shape[0]
    idx = jax.random.randint(key, (batch_size,), 0, m)
    return {k: v[idx] for k, v in data.items()}


# ---------------------------------------------------------------------------
# LocalSolver: (X, w, keys, data, lr) -> (X, V, losses, accs) on the bank.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SamMomentumSolver:
    """Algorithm 1 lines 4-11 for all clients at once: gradients are vmapped
    over bank rows, the momentum/descent/de-bias step is one fused kernel
    call on the whole bank.  ``rho=0`` degrades to a single gradient pass,
    ``alpha=0`` to plain SGD (the momentum bank drops out of the carry)."""

    local_steps: int = 5
    batch_size: int = 32
    rho: float = 0.0
    alpha: float = 0.0

    def _grad_one(self, loss_fn, spec):
        def grad_one(x_i, w_i, key_i, data_i):
            key_i, bk = jax.random.split(key_i)
            batch = _sample_batch(data_i, bk, self.batch_size)
            # Unravel OUTSIDE the differentiated closure, fusing the line-5
            # de-bias into the leaf slices; the gradient stays leaf-shaped
            # (no scatter back into a (D,) row per leaf) and is ravelled
            # once — one contiguous write per client.  ``spec.debias`` is
            # ``unravel(x) / w`` for the dense bank and ``base +
            # expand(x) / w`` for the delta bank.
            z_tree = spec.debias(x_i, w_i)
            g_tree, (loss, acc) = sam_gradient(
                loss_fn, z_tree, batch, self.rho
            )  # lines 6-8
            return key_i, g_tree, loss, acc

        return grad_one

    def update(self, loss_fn, spec, X, w, keys, data, lr):
        grad_one = self._grad_one(loss_fn, spec)
        V0 = jnp.zeros_like(X, jnp.float32)

        if self.alpha == 0.0:
            # Momentum off: v' = g exactly, so the momentum bank is never
            # read — keep it out of the scan carry and let XLA fold
            # ``0 * 0 + g`` and DCE the v write on the CPU inline path.
            # V0 doubles as the kernel's zero momentum operand (one (n, D)
            # zero bank, not two identical ones).

            def step0(carry, _):
                X, ks = carry
                ks, G_tree, losses, accs = jax.vmap(grad_one)(X, w, ks, data)
                G = spec.ravel_grad_stacked(G_tree, X)  # one contiguous write
                X, _, _ = kops.fused_update_bank(X, V0, G, 0.0, lr, w)
                return (X, ks), (losses, accs)

            (X, _), (losses, accs) = jax.lax.scan(
                step0, (X, keys), None, length=self.local_steps
            )
            return X, V0, losses.mean(axis=0), accs.mean(axis=0)

        def step(carry, _):
            X, V, ks = carry
            ks, G_tree, losses, accs = jax.vmap(grad_one)(X, w, ks, data)
            G = spec.ravel_grad_stacked(G_tree, X)  # one contiguous write
            # Lines 9-11 fused over the whole bank.  The de-biased z output
            # feeds the next TPU iteration from VMEM; on the CPU inline
            # path it is unused here and dead-code eliminated.
            X, V, _ = kops.fused_update_bank(X, V, G, self.alpha, lr, w)
            return (X, V, ks), (losses, accs)

        (X, V, _), (losses, accs) = jax.lax.scan(
            step, (X, V0, keys), None, length=self.local_steps
        )
        return X, V, losses.mean(axis=0), accs.mean(axis=0)


@dataclasses.dataclass(frozen=True)
class ProximalSolver(SamMomentumSolver):
    """FedProx-style local objective f_i(x) + (mu/2) ||x - x_round||^2
    (Li et al. 2020; DFedADMM's dual-constrained solver is the same shape).
    The proximal pull is applied directly on the bank — ``G += mu (X - X0)``
    with X0 the round-start bank — so it composes with any mixer."""

    mu: float = 0.01

    def update(self, loss_fn, spec, X, w, keys, data, lr):
        grad_one = self._grad_one(loss_fn, spec)
        X0 = X  # round-start reference, constant through the local scan
        V0 = jnp.zeros_like(X, jnp.float32)

        if self.alpha == 0.0:
            # Same alpha==0 treatment as SamMomentumSolver: v' = g exactly,
            # so the momentum bank leaves the scan carry and V0 doubles as
            # the kernel's zero momentum operand — one (n, D) zero bank.
            def step0(carry, _):
                X, ks = carry
                ks, G_tree, losses, accs = jax.vmap(grad_one)(X, w, ks, data)
                G = spec.ravel_grad_stacked(G_tree, X)
                G = G + self.mu * (X - X0).astype(G.dtype)
                X, _, _ = kops.fused_update_bank(X, V0, G, 0.0, lr, w)
                return (X, ks), (losses, accs)

            (X, _), (losses, accs) = jax.lax.scan(
                step0, (X, keys), None, length=self.local_steps
            )
            return X, V0, losses.mean(axis=0), accs.mean(axis=0)
        return self._update_momentum(grad_one, spec, X, X0, V0, w, keys,
                                     data, lr)

    def _update_momentum(self, grad_one, spec, X, X0, V0, w, keys, data, lr):
        """Generic momentum-carrying path (also valid, if wasteful, at
        alpha == 0 — the fast path above is pinned bitwise against it)."""

        def step(carry, _):
            X, V, ks = carry
            ks, G_tree, losses, accs = jax.vmap(grad_one)(X, w, ks, data)
            G = spec.ravel_grad_stacked(G_tree, X)
            G = G + self.mu * (X - X0).astype(G.dtype)
            X, V, _ = kops.fused_update_bank(X, V, G, self.alpha, lr, w)
            return (X, V, ks), (losses, accs)

        (X, V, _), (losses, accs) = jax.lax.scan(
            step, (X, V0, keys), None, length=self.local_steps
        )
        return X, V, losses.mean(axis=0), accs.mean(axis=0)


# ---------------------------------------------------------------------------
# Compressor: init_state(n, d) -> state; apply(state, X) -> (state, X').
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IdentityCompressor:
    """No-op communication stage (full-precision gossip)."""

    stateful = False

    def init_state(self, n: int, d: int):
        return ()

    def apply(self, state, X):
        return state, X


@dataclasses.dataclass(frozen=True)
class Int8RowCompressor:
    """Int8 symmetric quantization with one scale per client row of the
    flat bank — tighter than a per-leaf global scale."""

    stateful = False

    def init_state(self, n: int, d: int):
        return ()

    def apply(self, state, X):
        Xf = X.astype(jnp.float32)
        scale = jnp.max(jnp.abs(Xf), axis=1, keepdims=True) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(Xf / scale), -127, 127)
        return state, (q * scale).astype(X.dtype)


@dataclasses.dataclass(frozen=True)
class TopKEFCompressor:
    """Per-row top-k sparsification with error feedback (Stich et al. 2018).

    Each round the residual of what was dropped is carried in a float32
    ``(n, D)`` state bank and added back before the next top-k, so the
    compressed stream is unbiased in the long run:
    ``compressed + residual' == X + residual`` holds exactly.
    ``ratio`` is the kept fraction of coordinates per row (k = ratio * D).
    """

    ratio: float = 0.05
    stateful = True

    def init_state(self, n: int, d: int):
        return jnp.zeros((n, d), jnp.float32)

    def apply(self, state, X):
        y = X.astype(jnp.float32) + state
        k = max(int(self.ratio * y.shape[1]), 1)
        mag = jnp.abs(y)
        kth = jax.lax.top_k(mag, k)[0][:, -1:]
        mask = mag >= kth  # ties may keep a few extra coords — still sparse
        # The transmitted payload is the bank-dtype cast; the residual must
        # be taken against *that*, not the float32 top-k values, or the
        # sub-f32 rounding error is silently dropped instead of fed back
        # (compressed + residual' == X + residual then fails for bf16/f16).
        Xc = (y * mask).astype(X.dtype)
        return y - Xc.astype(jnp.float32), Xc


# ---------------------------------------------------------------------------
# Mixer: init_weights(n) -> w; mix(P, X, w) -> (X', w').
#
# ``mix_round`` is the full communication phase the round program drives:
#   mix_round(P, X, w, link, key, X_full) -> (X', w', link', extras)
# where X is the (possibly compressed) transmitted bank and X_full the
# uncompressed bank.  Every mixer keeps client i's OWN contribution at full
# precision — X'[i] = P[ii]·X_full[i] + sum_{j != i} P[ij]·X[j] — because no
# client quantizes/sparsifies the copy it hands to itself (the self-loop is
# local memory, not a network link).  ``link`` is the LinkState carry for
# stateful mixers (delayed payload buffers, event-trigger caches); stateless
# mixers thread it through untouched.
# ---------------------------------------------------------------------------


class LinkState(NamedTuple):
    """Unreliable-link carry threaded through the round state.

    ``key`` drives the per-round link randomness (drop masks, delay draws)
    on its own PRNG stream, so link-free programs keep a bit-identical main
    stream.  ``bufx``/``bufw`` are the bounded-staleness in-flight payload
    buffers of :class:`DelayedPushSumMixer` — ``bufx[r]`` is the ``(n, D)``
    mass arriving ``r + 1`` rounds from now, so total push-sum mass
    ``w.sum() + bufw.sum() == n`` exactly.  ``last`` is the ``(n, D)``
    last-broadcast cache of :class:`EventTriggeredMixer`.  Unused fields
    stay ``()`` and drop out of the pytree.
    """

    key: jax.Array
    bufx: Any = ()  # (B, n, D) in-flight payload mass (delayed mixer)
    bufw: Any = ()  # (B, n) in-flight push-sum mass (delayed mixer)
    last: Any = ()  # (n, D) last transmitted rows (event-triggered mixer)


class ChurnState(NamedTuple):
    """Node-churn carry threaded through the round state.

    ``key`` drives the per-round failure/recovery draws on its own PRNG
    stream (folded off the seed, so churn-free programs keep a
    bit-identical main stream).  ``live`` is the ``(n,)`` int8 liveness
    vector (``topology.LIVE`` / ``DOWN`` / ``DOWN_PERMANENT``).  ``tpl``
    carries the ``(D,)`` init template row only under cold resurrection
    (``ChurnModel(resurrect="cold")``) — a reborn node's de-biased model
    is reset to it; warm churn keeps ``tpl == ()`` and it drops out of
    the pytree.
    """

    key: jax.Array
    live: jnp.ndarray
    tpl: Any = ()


def _self_weights(P):
    """The self-loop weight per receiver: ``diag(P)`` for a dense matrix,
    slot 0 for a NeighborList (the self-loop by convention; pads and
    permutation self-hits carry weight 0 elsewhere).  For a TwoTierOp the
    self-loop lives on the intra-pod block diagonals — its inter list's
    slot 0 is a zero-weight pad."""
    from repro.core.topology import NeighborList, TwoTierOp

    if isinstance(P, TwoTierOp):
        return jnp.diagonal(P.intra, axis1=1, axis2=2).reshape(-1)
    if isinstance(P, NeighborList):
        return P.wgt[:, 0]
    return jnp.diagonal(P)


def _selfloop_correction(P, X, X_full, mixed):
    """Replace the self-loop contribution ``P[ii]·X[i]`` inside ``mixed``
    with the full-precision ``P[ii]·X_full[i]``.  When ``X_full is X``
    (identity compressor) this is a trace-time no-op, keeping those
    compositions bitwise unchanged."""
    if X_full is X:
        return mixed
    s = _self_weights(P)[:, None]
    return mixed + (s * (X_full.astype(jnp.float32) - X.astype(jnp.float32))
                    ).astype(mixed.dtype)


@dataclasses.dataclass(frozen=True)
class PushSumMixer:
    """Directed column-stochastic gossip + push-sum weight mixing
    (Algorithm 1 lines 12-14): X' = P X, w' = P w.

    ``backend`` is forwarded as ``use_kernel`` into the bank gossip —
    ``None`` keeps the size-based kernel auto-selection; sharded programs
    set ``"xla"`` so the GSPMD partitioner sees plain HLO."""

    backend: Any = None
    kind = "directed"
    link_stateful = False

    def init_weights(self, n: int):
        return jnp.ones((n,), jnp.float32)

    def link_buffers(self, bank) -> dict:
        return {}

    def mix_weights(self, P, w):
        return pushsum.gossip_weights(P, w)

    def mix(self, P, X, w):
        return pushsum.gossip_bank(P, X, self.backend), self.mix_weights(P, w)

    def mix_round(self, P, X, w, link, key, X_full, t=None):
        Xm, wm = self.mix(P, X, w)
        return _selfloop_correction(P, X, X_full, Xm), wm, link, {}


@dataclasses.dataclass(frozen=True)
class SymmetricMixer:
    """Doubly-stochastic gossip over an undirected graph (DFedAvg /
    DFedSAM family): X' = W X, push-sum weights stay all-ones."""

    backend: Any = None
    kind = "symmetric"
    link_stateful = False

    def init_weights(self, n: int):
        return jnp.ones((n,), jnp.float32)

    def link_buffers(self, bank) -> dict:
        return {}

    def mix_weights(self, P, w):
        return w

    def mix(self, P, X, w):
        return pushsum.gossip_bank(P, X, self.backend), self.mix_weights(P, w)

    def mix_round(self, P, X, w, link, key, X_full, t=None):
        Xm, wm = self.mix(P, X, w)
        return _selfloop_correction(P, X, X_full, Xm), wm, link, {}


def _delay_slices(key, P, bound: int):
    """Per-edge delivery delays in {0..bound} as a list of ``bound + 1``
    disjoint mixing operators: slice d carries exactly the edges arriving
    d rounds late; self-loops always land in slice 0.  Summing the slices
    recovers ``P`` exactly, so each column's total outgoing mass is still
    1 — it is merely spread over delivery times."""
    from repro.core.topology import NeighborList

    if isinstance(P, NeighborList):
        d = jax.random.randint(key, P.idx.shape, 0, bound + 1)
        d = d.at[:, 0].set(0)  # the self-loop is local: never delayed
        return [
            NeighborList(P.idx, jnp.where(d == t, P.wgt, 0.0))
            for t in range(bound + 1)
        ]
    n = P.shape[0]
    d = jax.random.randint(key, (n, n), 0, bound + 1)
    d = jnp.where(jnp.eye(n, dtype=bool), 0, d)
    return [P * (d == t) for t in range(bound + 1)]


@dataclasses.dataclass(frozen=True)
class DelayedPushSumMixer:
    """Push-sum over links with bounded random delays (staleness <= B).

    Every surviving edge (j -> i) samples a delivery delay d in {0..B}
    each round; the share ``P[ij]·(x_j, w_j)`` it carries is *in flight*
    for d rounds, riding the ``(B, n, D)`` / ``(B, n)`` buffers in
    :class:`LinkState`, and is added to receiver i when it matures.  The
    self-loop is local memory and always delivers instantly.  Because a
    sender's full column mass leaves every round (just spread over
    delivery times), total push-sum mass is exact at every round:
    ``w.sum() + bufw.sum() == n`` — no silent mass leak, and the de-biased
    ratio z = x / w still converges to the true average (Assran et al.
    2019 treat exactly this overlap/staleness regime for SGP).
    """

    delay: int = 1
    backend: Any = None
    kind = "directed"
    link_stateful = True

    def __post_init__(self):
        if self.delay < 1:
            raise ValueError("DelayedPushSumMixer needs delay >= 1; "
                             "use PushSumMixer for instantaneous links")

    def init_weights(self, n: int):
        return jnp.ones((n,), jnp.float32)

    def link_buffers(self, bank) -> dict:
        n = bank.shape[0]
        return {
            "bufx": jnp.zeros((self.delay,) + bank.shape, bank.dtype),
            "bufw": jnp.zeros((self.delay, n), jnp.float32),
        }

    def mix_weights(self, P, w):
        return pushsum.gossip_weights(P, w)

    def mix_round(self, P, X, w, link: LinkState, key, X_full, t=None):
        slices = _delay_slices(key, P, self.delay)
        sent_x = [pushsum.gossip_bank(Ps, X, self.backend) for Ps in slices]
        sent_w = [pushsum.gossip_weights(Ps, w) for Ps in slices]
        # Slice 0 holds the self-loop: keep it full precision.
        sent_x[0] = _selfloop_correction(P, X, X_full, sent_x[0])
        X_new = sent_x[0] + link.bufx[0].astype(sent_x[0].dtype)
        w_new = sent_w[0] + link.bufw[0]
        # Shift the buffers one round closer to delivery and enqueue the
        # newly sent delayed shares.
        bufx = jnp.concatenate(
            [link.bufx[1:], jnp.zeros_like(link.bufx[:1])], axis=0
        ) + jnp.stack(sent_x[1:]).astype(link.bufx.dtype)
        bufw = jnp.concatenate(
            [link.bufw[1:], jnp.zeros_like(link.bufw[:1])], axis=0
        ) + jnp.stack(sent_w[1:])
        link = link._replace(bufx=bufx, bufw=bufw)
        return X_new, w_new, link, {"w_inflight": bufw.sum()}


@dataclasses.dataclass(frozen=True)
class EventTriggeredMixer:
    """Directed push-sum where a client transmits a fresh row only when it
    drifted more than ``threshold`` (L2) from its last transmission;
    neighbors otherwise mix the receiver-side cached last broadcast
    (`LinkState.last`).  The self-loop always uses the live full-precision
    row — a client never reads itself through the network.  Push-sum
    weights are scalars (n floats per round, vs n·D for the bank) and are
    always mixed fresh, so mass stays exactly n; the consensus error this
    scheme admits is bounded by the threshold, which is the knob the
    ``comm_fraction`` extra (fraction of clients that transmitted) trades
    against.

    The threshold may be a *schedule* (adaptive communication censoring):
    ``schedule(t)`` when given, else ``threshold * decay ** t`` — a
    decaying threshold communicates sparsely early and tightens toward
    full gossip as training converges.  ``decay == 1.0`` with no schedule
    is resolved at trace time to the fixed-threshold mixer, bitwise.
    """

    threshold: float = 0.01
    # Per-round multiplicative threshold decay; 1.0 = fixed threshold.
    decay: float = 1.0
    # Optional callable ``t -> threshold`` (t is the traced round index);
    # overrides ``decay``.  Must be jit-traceable.
    schedule: Any = None
    backend: Any = None
    kind = "directed"
    link_stateful = True

    def _threshold_at(self, t):
        if self.schedule is None and self.decay == 1.0:
            return self.threshold
        if t is None:
            raise ValueError(
                "a scheduled/decaying event threshold needs the round "
                "index: thread t=state.round into comm_phase (the pod "
                "round path supports fixed thresholds only)"
            )
        tf = jnp.asarray(t, jnp.float32)
        if self.schedule is not None:
            return jnp.asarray(self.schedule(tf), jnp.float32)
        return jnp.float32(self.threshold) * jnp.float32(self.decay) ** tf

    def init_weights(self, n: int):
        return jnp.ones((n,), jnp.float32)

    def link_buffers(self, bank) -> dict:
        # Every client's initial row is common knowledge (broadcast init),
        # so the cache starts warm: round 1 only transmits real movement.
        # A copy, not the bank itself — the carry is donated and two
        # aliases of one buffer cannot both be.
        return {"last": jnp.array(bank)}

    def mix_weights(self, P, w):
        return pushsum.gossip_weights(P, w)

    def mix_round(self, P, X, w, link: LinkState, key, X_full, t=None):
        drift = X.astype(jnp.float32) - link.last.astype(jnp.float32)
        send = jnp.sqrt(jnp.sum(drift * drift, axis=1)) > self._threshold_at(t)
        B = jnp.where(send[:, None], X, link.last.astype(X.dtype))
        Xm = pushsum.gossip_bank(P, B, self.backend)
        # The self-loop never reads the cache: always the live full bank
        # (B is a fresh array, so the helper's is-X short-circuit never
        # swallows the correction).
        Xm = _selfloop_correction(P, B, X_full, Xm)
        wm = pushsum.gossip_weights(P, w)
        link = link._replace(last=B)
        return Xm, wm, link, {
            "comm_fraction": send.astype(jnp.float32).mean()
        }


@dataclasses.dataclass(frozen=True)
class CentralMixer:
    """Central-server round (FedAvg): the sampled clients' rows are averaged
    into the single global row; no mixing matrix, no push-sum weights."""

    kind = "central"
    link_stateful = False

    def init_weights(self, n: int):
        return jnp.ones((n,), jnp.float32)

    def link_buffers(self, bank) -> dict:
        return {}

    def reduce(self, X):
        return X.mean(axis=0)


# ---------------------------------------------------------------------------
# The shared communication phase (compress -> link -> mix) — one definition
# driving both the flat-bank round program and the pod round_step.
# ---------------------------------------------------------------------------


def _identity(x):
    return x


def comm_phase(compressor, mixer, P, X, w, comp, link, *,
               linked=False, link_model=None, symmetric=False,
               pin=_identity, pin_link=_identity, t=None):
    """One communication phase on a flat ``(n, D)`` bank:

      compress -> split the link PRNG stream -> apply link drops ->
      ``mixer.mix_round`` -> re-pin the sharded outputs.

    ``pin``/``pin_link`` are GSPMD row-sharding constraints (identity when
    unsharded — every op then reduces to exactly the sequence the program
    and the pod ``round_step`` used to inline, bitwise).  Under a mesh they
    re-assert the bank's ``clients``-axis layout at the phase boundaries so
    the partitioner cannot rematerialize the bank replicated around the
    compressor/mixer reshapes.

    ``t`` is the (traced) round index, consumed only by mixers with a
    per-round schedule (the event-trigger threshold decay); ``None`` keeps
    every fixed-schedule composition bitwise unchanged.

    Returns ``(X_mixed, w_new, comp, link, extras)``.
    """
    X = pin(X)
    if compressor.stateful:
        comp = pin(comp)
    comp, Xc = compressor.apply(comp, X)
    lkey = None
    if linked:
        lkey, nkey = jax.random.split(link.key)
        link = link._replace(key=nkey)
        if link_model is not None and link_model.drop > 0:
            dkey, lkey = jax.random.split(lkey)
            P = link_model.drop_links(dkey, P, symmetric=symmetric)
        link = pin_link(link)
    Xm, w_new, link, extras = mixer.mix_round(P, Xc, w, link, lkey, X, t=t)
    Xm = pin(Xm)
    if compressor.stateful:
        comp = pin(comp)
    if linked:
        link = pin_link(link)
    return Xm, w_new, comp, link, extras


# ---------------------------------------------------------------------------
# Registries: AlgoConfig -> stage instances.
# ---------------------------------------------------------------------------

SOLVERS = {
    # Algorithm 1 inner loop; rho/alpha = 0 recover SGD+momentum / SAM-only.
    "sam_momentum": lambda a: SamMomentumSolver(
        a.local_steps, a.batch_size, a.rho, a.alpha),
    # Plain SGD regardless of the config's rho/alpha knobs.
    "sgd": lambda a: SamMomentumSolver(a.local_steps, a.batch_size, 0.0, 0.0),
    # FedProx-style proximal local objective (uses a.prox_mu).
    "proximal": lambda a: ProximalSolver(
        a.local_steps, a.batch_size, a.rho, a.alpha, a.prox_mu),
}

COMPRESSORS = {
    "identity": lambda a: IdentityCompressor(),
    "int8_rows": lambda a: Int8RowCompressor(),
    # getattr: configs without a topk_ratio field (e.g. the pod StepConfig)
    # still resolve, so the stateful-compressor rejection can fire with its
    # own message instead of an AttributeError.
    "topk_ef": lambda a: TopKEFCompressor(getattr(a, "topk_ratio", 0.05)),
}

MIXERS = {
    "directed": lambda a: PushSumMixer(),
    "symmetric": lambda a: SymmetricMixer(),
    "central": lambda a: CentralMixer(),
}


def make_stages(algo):
    """Resolve an ``AlgoConfig`` into its (solver, compressor, mixer)
    composition.  ``algo.comm`` selects the mixer; ``quantize_gossip`` is the
    legacy spelling of ``compressor="int8_rows"``."""
    comp_name = algo.compressor
    if comp_name == "identity" and algo.quantize_gossip:
        comp_name = "int8_rows"
    try:
        solver = SOLVERS[algo.solver](algo)
        compressor = COMPRESSORS[comp_name](algo)
        mixer = MIXERS[algo.comm](algo)
    except KeyError as e:
        raise ValueError(f"unknown stage {e.args[0]!r} in {algo}") from None
    return solver, compressor, mixer
