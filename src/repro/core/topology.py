"""Communication topologies for decentralized FL.

Convention (matches the paper): ``P[i, j]`` is the weight of the directed
link *from client j to client i* (j sends, i receives).  A sender ``j``
divides its message by its out-degree (self-loop included), hence every
*column* of ``P`` sums to 1 — ``P`` is **column-stochastic** but in general
not row-stochastic.  The gossip step is ``x_i' = sum_j P[i, j] x_j`` i.e.
``X' = P @ X`` for client-stacked ``X``; mass ``sum_i x_i`` is conserved.

Symmetric (undirected) baselines use doubly-stochastic Metropolis-Hastings
weights on an undirected graph.

Every sampled/structured family also exists in a **neighbor-list** form
(:class:`NeighborList`): fixed-shape ``(n, k_max)`` receiver-side index and
weight arrays with ``X'[i] = sum_l wgt[i, l] * X[idx[i, l]]`` — the sparse
representation the ``gossip_gather`` kernel consumes, padded with zero-weight
self-loops so it is jit/scan-safe.  ``dense_from_neighbors`` recovers the
equivalent dense ``P`` (the equivalence the property tests pin).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "TopologyConfig",
    "LinkModel",
    "ChurnModel",
    "churn_transition",
    "churn_links_dense",
    "churn_links_neighbors",
    "NeighborList",
    "TwoTierOp",
    "sample_two_tier",
    "dense_from_two_tier",
    "drop_links_dense",
    "drop_links_neighbors",
    "column_stochastic_from_adjacency",
    "metropolis_weights",
    "directed_ring",
    "directed_exponential",
    "exponential_cycle",
    "sample_kout",
    "sample_kout_selective",
    "sample_symmetric_k_regular",
    "sample_mixing",
    "neighbors_ring",
    "neighbors_exponential",
    "neighbors_exponential_cycle",
    "sample_kout_neighbors",
    "sample_kout_selective_neighbors",
    "sample_symmetric_neighbors",
    "sample_neighbors",
    "sample_active_picks",
    "active_k_in",
    "family_k_in",
    "neighbor_k_max",
    "dense_from_neighbors",
    "is_column_stochastic",
    "union_strongly_connected",
]


@dataclasses.dataclass(frozen=True)
class TopologyConfig:
    """Static description of the communication graph family."""

    kind: str = "kout"  # kout | ring | exponential | symmetric | full | two_tier
    n_clients: int = 100
    # Number of out-neighbors each client picks (excluding the self-loop).
    # For the two-tier family this is the number of *cross-pod* in-edges
    # each client draws; intra-pod gossip is dense by construction.
    k_out: int = 10
    time_varying: bool = True
    # Hierarchical two-tier family only: the clients are n_pods equal pods
    # with dense push-sum gossip inside each pod and sparse directed k_out
    # edges between pods — the natural fit for a bank whose rows are
    # sharded over a mesh "clients" axis (intra-pod mixing stays
    # shard-local; only the k_out inter-pod edges cross shards).
    n_pods: int = 0

    def __post_init__(self):
        if self.k_out >= self.n_clients:
            raise ValueError("k_out must be < n_clients")
        if self.kind == "two_tier":
            if self.n_pods < 2:
                raise ValueError("two_tier topology needs n_pods >= 2")
            if self.n_clients % self.n_pods:
                raise ValueError(
                    "two_tier topology needs n_clients divisible by n_pods"
                )
            ps = self.n_clients // self.n_pods
            if not 1 <= self.k_out <= self.n_clients - ps:
                raise ValueError(
                    "two_tier k_out must be in [1, n_clients - pod_size] "
                    "(every cross-pod edge leaves the receiver's own pod)"
                )
        elif self.n_pods:
            raise ValueError("n_pods is a two_tier-only field")


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Per-round unreliable-link effects, the scenario the paper motivates
    ("susceptible to the impact of network link quality") but perfect
    mixers cannot exercise.

    ``drop``: i.i.d. failure probability per directed non-self edge each
    round.  Drops are applied to the *adjacency* before sender
    normalization (:func:`drop_links_dense` / :func:`drop_links_neighbors`),
    so the effective ``P_t`` stays exactly column-stochastic and push-sum
    mass ``sum_i w_i == n`` is conserved under any drop pattern — a sender
    whose every outgoing link failed simply keeps all its mass on the
    self-loop, which never drops.  The boundary ``drop == 1.0`` is legal
    and pinned: every non-self edge fails every round, the sampled
    operator is exactly the identity, and each node keeps ALL of its mass
    on its self-loop (total isolation conserves mass; nothing leaks).

    ``delay``: staleness bound B (rounds).  ``delay >= 1`` swaps the
    directed mixer for ``DelayedPushSumMixer``: every surviving edge
    samples a delivery delay in {0..B} per round and undelivered payloads
    ride an in-flight buffer carried in the round state, so
    node mass + in-flight mass == n exactly at every round.

    ``event_threshold``: > 0 swaps in ``EventTriggeredMixer`` — a client
    broadcasts a fresh row only when it moved more than the threshold from
    its last transmission; neighbors otherwise mix the cached broadcast.

    All-zero fields mean perfect links; ``make_program`` then builds the
    exact unmodified round (bitwise identical to a link-free program).
    """

    drop: float = 0.0
    delay: int = 0
    event_threshold: float = 0.0
    # Event-trigger *schedule* (adaptive communication censoring, DFL
    # survey 2306.01603): the round-t threshold is
    # ``event_schedule(t)`` when given, else
    # ``event_threshold * event_decay ** t``.  ``event_decay == 1.0`` and
    # ``event_schedule is None`` keep the fixed-threshold mixer bitwise
    # (the decay branch is resolved at trace time).  A decaying threshold
    # starts cheap (few clients moved far enough to transmit) and tightens
    # toward full communication as training converges.
    event_decay: float = 1.0
    event_schedule: Any = None

    def __post_init__(self):
        if not 0.0 <= self.drop <= 1.0:
            raise ValueError(
                f"LinkModel.drop must be a probability in [0, 1], got "
                f"{self.drop!r} (drop=1.0 is the fully-isolated boundary: "
                "every node keeps all mass on its self-loop)"
            )
        if self.delay < 0:
            raise ValueError("delay bound must be >= 0")
        if self.event_threshold < 0.0:
            raise ValueError("event_threshold must be >= 0")
        if not 0.0 < self.event_decay <= 1.0:
            raise ValueError("event_decay must be in (0, 1]")
        if self.event_schedule is not None and not callable(
            self.event_schedule
        ):
            raise ValueError("event_schedule must be callable: t -> "
                             "threshold")
        if (self.event_decay != 1.0 or self.event_schedule is not None
                ) and not self.event_threshold:
            raise ValueError(
                "event_decay / event_schedule modulate event-triggered "
                "mixing; set event_threshold > 0 (the schedule's base / "
                "round-0 value) to enable it"
            )
        if self.delay and self.event_threshold:
            raise ValueError(
                "delayed and event-triggered mixing do not compose; "
                "pick one of delay / event_threshold"
            )
        if self.drop and self.event_threshold:
            # The event mixer keeps ONE last-broadcast row per sender; with
            # per-edge drops a receiver whose link was down during the
            # transmission would later read a broadcast it never received
            # (sound modeling needs per-receiver caches, (n, n, D)).
            raise ValueError(
                "event-triggered mixing assumes reliable links (the shared "
                "last-broadcast cache cannot model per-receiver misses); "
                "drop and event_threshold do not compose"
            )

    @property
    def active(self) -> bool:
        return bool(self.drop or self.delay or self.event_threshold)

    def drop_links(self, key: jax.Array, P, symmetric: bool = False):
        """Sample this round's link failures into the mixing operator
        (dense matrix or :class:`NeighborList`), preserving exact
        column-stochasticity (or double stochasticity when ``symmetric``)."""
        if isinstance(P, TwoTierOp):
            raise ValueError(
                "link drops on the two-tier operator form are unsupported "
                "(a dropped cross-pod edge changes every intra-pod weight "
                "of its sender's pod); force gossip='dense' for two_tier + "
                "link scenarios"
            )
        if isinstance(P, NeighborList):
            if symmetric:
                raise ValueError(
                    "link drops on the symmetric neighbor-list form are "
                    "unsupported (per-edge masks cannot be kept consistent "
                    "across both endpoints' fixed-shape lists); force "
                    "gossip='dense'"
                )
            return drop_links_neighbors(key, P, drop=self.drop)
        return drop_links_dense(key, P, drop=self.drop, symmetric=symmetric)


def drop_links_dense(
    key: jax.Array, P: jnp.ndarray, drop: float, symmetric: bool = False
) -> jnp.ndarray:
    """Fail each non-self edge of ``P``'s support i.i.d. with probability
    ``drop``, then re-normalize from the SURVIVING adjacency.

    The drop mask hits the adjacency *before* sender normalization: a
    sender divides by its surviving out-degree (self-loop always included),
    so every column of the returned matrix sums to exactly 1 — no mass
    leaks through dead links, it stays on the sender.  With ``symmetric``
    the mask is symmetrized (one coin per undirected edge) and Metropolis
    weights are recomputed on the surviving graph, keeping the operator
    exactly doubly stochastic.
    """
    n = P.shape[0]
    u = jax.random.uniform(key, (n, n))
    if symmetric:
        u = jnp.triu(u, 1)
        u = u + u.T  # one coin per undirected edge
    keep = u >= drop
    adj = (P > 0) & keep
    adj = jnp.asarray(adj, jnp.float32)
    if symmetric:
        return metropolis_weights(adj * (1.0 - jnp.eye(n)))
    return column_stochastic_from_adjacency(adj)


def drop_links_neighbors(
    key: jax.Array, nl: "NeighborList", drop: float
) -> "NeighborList":
    """Sparse twin of :func:`drop_links_dense` (directed families).

    Each real non-self slot fails i.i.d.; slot 0 (the self-loop) never
    drops.  Sender out-degrees are re-counted over the *surviving* edges by
    one scatter-add and every surviving edge from sender j carries weight
    ``1 / out_degree(j)`` — exactly the column-stochastic sender
    normalization of ``_kin_weights``, applied after the drops.
    """
    n = nl.idx.shape[0]
    keep = jax.random.uniform(key, nl.idx.shape) >= drop
    keep = keep.at[:, 0].set(True)  # the self-loop never drops
    live = keep & (nl.wgt > 0)  # zero-weight pads stay inert
    # Surviving out-degree per sender (self-loop slots count themselves).
    outdeg = jnp.zeros((n,), jnp.float32).at[
        jnp.where(live, nl.idx, n)
    ].add(1.0, mode="drop")
    wgt = jnp.where(live, 1.0 / outdeg[nl.idx], 0.0)
    return NeighborList(nl.idx, wgt.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Client churn: whole-node failures (the DFL survey's dominant real-world
# fault mode), composable with LinkModel's per-edge effects.
# ---------------------------------------------------------------------------

# Liveness codes carried as an (n,) int8 vector in the round state.
LIVE = 1          # participating normally
DOWN = 0          # crashed, may recover with prob recover_prob per round
DOWN_PERMANENT = -1  # crashed for good; never recovers


@dataclasses.dataclass(frozen=True)
class ChurnModel:
    """Per-round whole-client failures and recoveries (node churn).

    Each round every live client fails i.i.d. with ``fail_prob``; a
    failure is *permanent* with probability ``permanent_frac`` (the node
    never returns), otherwise the node is down-but-recoverable and comes
    back i.i.d. with ``recover_prob`` per round.  A dead node is removed
    from the sampled operator entirely — all of its in- AND out-edges are
    masked from the adjacency *before* sender normalization
    (:func:`churn_links_dense` / :func:`churn_links_neighbors`), so the
    surviving operator is still exactly column-stochastic and a dead
    node's column is the identity column: its push-sum mass is **frozen**
    on its self-loop, not lost.  The exact invariant every round is

        live node mass + in-flight mass + frozen dead mass == n.

    Shares already in flight toward a node that dies are delivered into
    its frozen account (they are queued at the crashed node and reflected
    when it recovers) — mass never leaks.

    ``resurrect`` picks the rejoin semantics: ``"warm"`` (default) means a
    recovering node resumes from its stored row exactly as it left;
    ``"cold"`` means it rejoins at the init template — its de-biased model
    ``x/w`` is reset to the template row while its *mass* ``w`` is kept
    (``x := w * template``), so even cold rebirth conserves the invariant
    bit-for-bit.

    Churn composes with :class:`LinkModel` drops and delays (the churn
    mask is applied to the sampled operator first; drops then fail edges
    of the surviving support).  It does NOT compose with
    ``event_threshold`` — the shared last-broadcast cache cannot model a
    node transmitting while crashed.  All-zero fields mean no churn;
    ``make_program`` then builds the exact unmodified round (bitwise
    identical to a churn-free program).
    """

    fail_prob: float = 0.0
    recover_prob: float = 0.0
    permanent_frac: float = 0.0
    resurrect: str = "warm"  # warm | cold

    def __post_init__(self):
        if not 0.0 <= self.fail_prob <= 1.0:
            raise ValueError(
                f"ChurnModel.fail_prob must be a probability in [0, 1], "
                f"got {self.fail_prob!r}"
            )
        if not 0.0 <= self.recover_prob <= 1.0:
            raise ValueError(
                f"ChurnModel.recover_prob must be a probability in [0, 1], "
                f"got {self.recover_prob!r}"
            )
        if not 0.0 <= self.permanent_frac <= 1.0:
            raise ValueError(
                f"ChurnModel.permanent_frac must be a fraction in [0, 1], "
                f"got {self.permanent_frac!r}"
            )
        if self.resurrect not in ("warm", "cold"):
            raise ValueError(
                f"ChurnModel.resurrect must be 'warm' (resume from the "
                f"stored row) or 'cold' (rejoin at the init template), got "
                f"{self.resurrect!r}"
            )
        if self.fail_prob == 0.0 and (
            self.recover_prob or self.permanent_frac
        ):
            raise ValueError(
                "ChurnModel.recover_prob / permanent_frac modulate node "
                "failures; set fail_prob > 0 to enable churn"
            )

    @property
    def active(self) -> bool:
        return bool(self.fail_prob)

    def mask_operator(self, P, alive: jnp.ndarray, symmetric: bool = False):
        """Remove every in/out edge of dead nodes from the sampled
        operator (dense matrix or :class:`NeighborList`), re-normalizing
        senders over the surviving support."""
        if isinstance(P, TwoTierOp):
            raise ValueError(
                "churn on the two-tier operator form is unsupported (a "
                "dead client changes every intra-pod weight of its pod); "
                "force gossip='dense' for two_tier + churn scenarios"
            )
        if isinstance(P, NeighborList):
            if symmetric:
                raise ValueError(
                    "churn on the symmetric neighbor-list form is "
                    "unsupported (Metropolis degrees cannot be kept "
                    "consistent across both endpoints' fixed-shape "
                    "lists); force gossip='dense'"
                )
            return churn_links_neighbors(P, alive)
        return churn_links_dense(P, alive, symmetric=symmetric)


def churn_transition(
    key: jax.Array, live: jnp.ndarray, model: ChurnModel
) -> jnp.ndarray:
    """One round of the churn Markov chain over liveness codes.

    ``live`` is ``(n,)`` int8 in {LIVE, DOWN, DOWN_PERMANENT}; returns the
    next liveness vector.  Live nodes fail w.p. ``fail_prob`` (permanently
    w.p. ``permanent_frac`` given failure); recoverable-down nodes return
    w.p. ``recover_prob``; permanent deaths are absorbing.
    """
    kf, kp, kr = jax.random.split(key, 3)
    n = live.shape[0]
    u_fail = jax.random.uniform(kf, (n,))
    u_perm = jax.random.uniform(kp, (n,))
    u_rec = jax.random.uniform(kr, (n,))
    fails = (live == LIVE) & (u_fail < model.fail_prob)
    perm = fails & (u_perm < model.permanent_frac)
    recovers = (live == DOWN) & (u_rec < model.recover_prob)
    nxt = jnp.where(fails, jnp.where(perm, DOWN_PERMANENT, DOWN), live)
    nxt = jnp.where(recovers, LIVE, nxt)
    return nxt.astype(jnp.int8)


def churn_links_dense(
    P: jnp.ndarray, alive: jnp.ndarray, symmetric: bool = False
) -> jnp.ndarray:
    """Mask dead nodes out of a dense operator, before sender
    normalization.

    Every edge with a dead endpoint (either direction) is removed from
    ``P``'s support; self-loops never are.  The survivors are re-normalized
    exactly as the family samplers do (uniform ``1/out_degree`` columns,
    or Metropolis weights when ``symmetric``), so a dead node's column is
    the identity column — its mass is frozen on its self-loop — and the
    operator stays exactly column- (or doubly-) stochastic.
    """
    n = P.shape[0]
    a = jnp.asarray(alive, bool)
    pair = a[:, None] & a[None, :]
    keep = pair | jnp.eye(n, dtype=bool)  # self-loops survive death
    adj = (P > 0) & keep
    adj = jnp.asarray(adj, jnp.float32)
    if symmetric:
        return metropolis_weights(adj * (1.0 - jnp.eye(n)))
    return column_stochastic_from_adjacency(adj)


def churn_links_neighbors(
    nl: "NeighborList", alive: jnp.ndarray
) -> "NeighborList":
    """Sparse twin of :func:`churn_links_dense` (directed families).

    A non-self slot survives only when both its sender and its receiver
    are alive; slot 0 (the self-loop) always survives.  Sender out-degrees
    are re-counted over the surviving edges by one scatter-add and every
    surviving edge from sender j carries ``1 / out_degree(j)`` — the same
    column-stochastic renormalization :func:`drop_links_neighbors` uses,
    so churn and drops compose by masking in sequence.
    """
    n = nl.idx.shape[0]
    a = jnp.asarray(alive, bool)
    keep = a[:, None] & a[nl.idx]
    keep = keep.at[:, 0].set(True)  # the self-loop survives death
    live_slots = keep & (nl.wgt > 0)  # zero-weight pads stay inert
    outdeg = jnp.zeros((n,), jnp.float32).at[
        jnp.where(live_slots, nl.idx, n)
    ].add(1.0, mode="drop")
    wgt = jnp.where(live_slots, 1.0 / outdeg[nl.idx], 0.0)
    return NeighborList(nl.idx, wgt.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Mixing-matrix constructors.
# ---------------------------------------------------------------------------

def column_stochastic_from_adjacency(adj: jnp.ndarray) -> jnp.ndarray:
    """adj[i, j] = 1 iff j sends to i.  Self-loops are forced on.

    Returns the column-stochastic P with P[i, j] = adj[i, j] / out_degree(j).
    """
    n = adj.shape[0]
    adj = jnp.asarray(adj, jnp.float32)
    adj = jnp.maximum(adj, jnp.eye(n, dtype=jnp.float32))  # self-loops
    out_degree = adj.sum(axis=0)  # column sums = number of receivers of j
    return adj / out_degree[None, :]


def metropolis_weights(adj: jnp.ndarray) -> jnp.ndarray:
    """Doubly-stochastic weights for a symmetric adjacency (undirected)."""
    n = adj.shape[0]
    adj = jnp.asarray(adj, jnp.float32)
    adj = jnp.maximum(adj, adj.T)  # symmetrize
    adj = adj * (1.0 - jnp.eye(n))  # strip self loops; re-added via residual
    deg = adj.sum(axis=1)
    # W[i,j] = 1 / (1 + max(deg_i, deg_j)) on edges.
    denom = 1.0 + jnp.maximum(deg[:, None], deg[None, :])
    w = adj / denom
    diag = 1.0 - w.sum(axis=1)
    return w + jnp.diag(diag)


def directed_ring(n: int) -> jnp.ndarray:
    """Static directed ring: i -> (i+1) mod n."""
    adj = np.eye(n, dtype=np.float32)
    for j in range(n):
        adj[(j + 1) % n, j] = 1.0
    return column_stochastic_from_adjacency(jnp.asarray(adj))


def directed_exponential(n: int, t: int = 0) -> jnp.ndarray:
    """One-peer exponential graph (time-varying): i -> i + 2^(t mod log n)."""
    hops = max(int(np.ceil(np.log2(max(n, 2)))), 1)
    step = 2 ** (t % hops)
    adj = np.eye(n, dtype=np.float32)
    for j in range(n):
        adj[(j + step) % n, j] = 1.0
    return column_stochastic_from_adjacency(jnp.asarray(adj))


def exponential_cycle(n: int) -> jnp.ndarray:
    """All ``log2(n)`` one-peer exponential graphs, stacked ``(hops, n, n)``.

    The round-t matrix is ``cycle[t % hops]`` — a jittable dynamic index, so
    a traced round counter can select the graph (the union over one full
    cycle is strongly connected, satisfying Assumption 1).
    """
    hops = max(int(np.ceil(np.log2(max(n, 2)))), 1)
    return jnp.stack([directed_exponential(n, t) for t in range(hops)])


# ---------------------------------------------------------------------------
# Random time-varying graphs (jit-friendly samplers).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnums=(1, 2))
def sample_kout(key: jax.Array, n: int, k: int) -> jnp.ndarray:
    """Each client picks k distinct out-neighbors uniformly (plus self).

    Returns the column-stochastic mixing matrix P (n, n).
    """
    # Per-sender random scores; top-k of scores excluding self.
    scores = jax.random.uniform(key, (n, n))
    scores = scores - 2.0 * jnp.eye(n)  # self never in top-k (picked later)
    # adj_out[j, i] = 1 if j sends to i.
    _, idx = jax.lax.top_k(scores, k)  # (n, k) receivers per sender
    adj_out = jnp.zeros((n, n), jnp.float32)
    adj_out = adj_out.at[jnp.arange(n)[:, None], idx].set(1.0)
    adj = adj_out.T  # adj[i, j] = j sends to i
    return column_stochastic_from_adjacency(adj)


@partial(jax.jit, static_argnums=(2, 3))
def sample_kout_selective(
    key: jax.Array, losses: jnp.ndarray, n: int, k: int, temp: float = 1.0
) -> jnp.ndarray:
    """Neighbor-selection strategy of DFedSGPSM-S (paper Eq. 2).

    Sender i picks out-neighbors j with probability proportional to
    ``exp(|f_i - f_j|)`` — favoring neighbors whose loss differs most.
    Sampling without replacement via the Gumbel-top-k trick.
    """
    diff = jnp.abs(losses[:, None] - losses[None, :]) / temp  # (n, n) sender i
    logits = diff - 1e9 * jnp.eye(n)
    gumbel = jax.random.gumbel(key, (n, n))
    _, idx = jax.lax.top_k(logits + gumbel, k)  # receivers per sender
    adj_out = jnp.zeros((n, n), jnp.float32)
    adj_out = adj_out.at[jnp.arange(n)[:, None], idx].set(1.0)
    return column_stochastic_from_adjacency(adj_out.T)


@partial(jax.jit, static_argnums=(1, 2))
def sample_symmetric_k_regular(key: jax.Array, n: int, k: int) -> jnp.ndarray:
    """Random undirected graph with ~k neighbors each; Metropolis weights."""
    scores = jax.random.uniform(key, (n, n))
    scores = jnp.triu(scores, 1)
    scores = scores + scores.T - 2.0 * jnp.eye(n)
    _, idx = jax.lax.top_k(scores, k)
    adj = jnp.zeros((n, n), jnp.float32)
    adj = adj.at[jnp.arange(n)[:, None], idx].set(1.0)
    adj = jnp.maximum(adj, adj.T)
    return metropolis_weights(adj)


def sample_mixing(
    key: jax.Array,
    cfg: TopologyConfig,
    t: int = 0,
    losses: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Sample the round-t mixing matrix for the configured family."""
    n, k = cfg.n_clients, cfg.k_out
    if cfg.kind == "ring":
        return directed_ring(n)
    if cfg.kind == "exponential":
        return directed_exponential(n, t if cfg.time_varying else 0)
    if cfg.kind == "full":
        return jnp.full((n, n), 1.0 / n, jnp.float32)
    if cfg.kind == "symmetric":
        return sample_symmetric_k_regular(key, n, k)
    if cfg.kind == "two_tier":
        return dense_from_two_tier(sample_two_tier(key, n, cfg.n_pods, k))
    if cfg.kind == "kout":
        if losses is not None:
            return sample_kout_selective(key, losses, n, k)
        return sample_kout(key, n, k)
    raise ValueError(f"unknown topology kind: {cfg.kind}")


# ---------------------------------------------------------------------------
# Neighbor-list (sparse) representation.
# ---------------------------------------------------------------------------

class NeighborList(NamedTuple):
    """Receiver-side sparse mixing operator, fixed shape ``(n, k_max)``.

    ``idx[i, l]`` names the l-th in-neighbor of client i (the sender) and
    ``wgt[i, l]`` its mixing weight: ``X'[i] = sum_l wgt[i,l] * X[idx[i,l]]``.
    Slot 0 is the self-loop by convention; padding slots point back at
    ``i`` with weight 0, so ragged in-degrees share one jittable shape and
    duplicate indices simply accumulate.  A NamedTuple, hence a pytree —
    it rides through ``jax.lax.scan`` carries and ``jax.jit`` untouched,
    and a stacked ``(hops, n, k_max)`` cycle indexes per-field.
    """

    idx: jnp.ndarray  # (n, k_max) int32 sender indices
    wgt: jnp.ndarray  # (n, k_max) float32 mixing weights


def dense_from_neighbors(nl: NeighborList, n: int) -> jnp.ndarray:
    """Densify: P[i, idx[i, l]] += wgt[i, l] — the matrix the sparse gather
    is equivalent to (duplicate slots accumulate, pads add 0)."""
    rows = jnp.arange(n)[:, None]
    return jnp.zeros((n, n), jnp.float32).at[rows, nl.idx].add(nl.wgt)


def neighbors_ring(n: int) -> NeighborList:
    """Static directed ring in neighbor form: i receives from i-1 and
    itself, weight 1/2 each — exactly :func:`directed_ring`."""
    i = jnp.arange(n, dtype=jnp.int32)
    idx = jnp.stack([i, (i - 1) % n], axis=1)
    return NeighborList(idx, jnp.full((n, 2), 0.5, jnp.float32))


def neighbors_exponential(n: int, t: int = 0) -> NeighborList:
    """One-peer exponential graph in neighbor form: i receives from
    ``i - 2^(t mod log n)`` and itself — exactly
    :func:`directed_exponential`."""
    hops = max(int(np.ceil(np.log2(max(n, 2)))), 1)
    step = 2 ** (t % hops)
    i = jnp.arange(n, dtype=jnp.int32)
    idx = jnp.stack([i, (i - step) % n], axis=1)
    return NeighborList(idx, jnp.full((n, 2), 0.5, jnp.float32))


def neighbors_exponential_cycle(n: int) -> NeighborList:
    """All ``log2(n)`` exponential graphs stacked ``(hops, n, 2)`` — the
    neighbor-form twin of :func:`exponential_cycle` (round t uses
    ``jax.tree.map(lambda a: a[t % hops], cycle)``)."""
    hops = max(int(np.ceil(np.log2(max(n, 2)))), 1)
    nls = [neighbors_exponential(n, t) for t in range(hops)]
    return NeighborList(
        jnp.stack([nl.idx for nl in nls]), jnp.stack([nl.wgt for nl in nls])
    )


def _kin_weights(picks: jnp.ndarray, n: int) -> NeighborList:
    """Column-stochastic weights for receiver-side picks.

    ``picks[i]`` are the k distinct senders chosen by receiver i.  Sender
    j's out-degree (receivers counting it, plus its self-loop) is computed
    by one scatter-count, and every edge from j carries weight
    ``1 / (out_degree(j) + 1)`` — columns sum to 1 exactly, matching the
    paper's sender-normalized convention.
    """
    i = jnp.arange(n, dtype=jnp.int32)
    outdeg = jnp.zeros((n,), jnp.float32).at[picks.reshape(-1)].add(1.0) + 1.0
    idx = jnp.concatenate([i[:, None], picks.astype(jnp.int32)], axis=1)
    return NeighborList(idx, 1.0 / outdeg[idx])


@partial(jax.jit, static_argnums=(1, 2))
def sample_kout_neighbors(key: jax.Array, n: int, k: int) -> NeighborList:
    """Sparse twin of :func:`sample_kout`: fixed-shape ``(n, k+1)`` lists.

    The dense sampler fixes each sender's out-degree (k-out); a fixed-shape
    *gather* list must instead fix each receiver's in-degree, so this is the
    k-in orientation of the same asymmetric sparse family — every receiver
    picks k distinct in-neighbors uniformly and senders still normalize by
    their (now variable) out-degree, keeping ``P`` exactly
    column-stochastic.  Both satisfy Assumption 1 the same way.
    """
    scores = jax.random.uniform(key, (n, n))
    scores = scores - 2.0 * jnp.eye(n)  # self rides in slot 0, not the picks
    _, picks = jax.lax.top_k(scores, k)  # (n, k) senders per receiver
    return _kin_weights(picks, n)


@partial(jax.jit, static_argnums=(2, 3))
def sample_kout_selective_neighbors(
    key: jax.Array, losses: jnp.ndarray, n: int, k: int, temp: float = 1.0
) -> NeighborList:
    """Sparse twin of :func:`sample_kout_selective` (paper Eq. 2): the
    selection score ``|f_i - f_j|`` is symmetric in (i, j), so the receiver
    picks its k most loss-divergent in-neighbors via Gumbel-top-k —
    the same criterion, gather-form fixed shape."""
    diff = jnp.abs(losses[:, None] - losses[None, :]) / temp
    logits = diff - 1e9 * jnp.eye(n)
    gumbel = jax.random.gumbel(key, (n, n))
    _, picks = jax.lax.top_k(logits + gumbel, k)
    return _kin_weights(picks, n)


@partial(jax.jit, static_argnums=(1, 2))
def sample_symmetric_neighbors(key: jax.Array, n: int, k: int) -> NeighborList:
    """Random undirected ~k-regular graph with Metropolis weights, degree
    bounded by construction: the union of ``k`` random permutation
    matchings (node i links to ``pi_t(i)`` and ``pi_t^{-1}(i)``), so every
    node has at most 2k neighbors and the list shape is ``(n, 2k+1)``.

    The dense :func:`sample_symmetric_k_regular` symmetrizes per-row top-k
    picks, whose degree is unbounded in the tail — fine for a dense matrix,
    unrepresentable in fixed-shape lists.  Weights are Metropolis with
    multiplicity (``pi_t(i) = pi_s(i)`` duplicates accumulate on both
    endpoints symmetrically), so the densified matrix is exactly doubly
    stochastic; ``pi_t(i) = i`` self-hits are zero-weight pads.
    """
    perms = jnp.stack(
        [jax.random.permutation(kk, n) for kk in jax.random.split(key, k)]
    )  # (k, n): pi_t
    invs = jnp.argsort(perms, axis=1)  # pi_t^{-1}
    nbrs = jnp.concatenate([perms.T, invs.T], axis=1).astype(jnp.int32)
    i = jnp.arange(n, dtype=jnp.int32)
    nonself = nbrs != i[:, None]
    deg = nonself.sum(axis=1).astype(jnp.float32)  # with multiplicity
    w = nonself / (1.0 + jnp.maximum(deg[:, None], deg[nbrs]))
    idx = jnp.concatenate([i[:, None], nbrs], axis=1)
    wgt = jnp.concatenate([1.0 - w.sum(axis=1, keepdims=True), w], axis=1)
    return NeighborList(idx, wgt.astype(jnp.float32))


def family_k_in(cfg: TopologyConfig, mixer_kind: str = "directed") -> int:
    """THE per-family static in-degree table: the maximum number of
    *distinct non-self* senders any receiver reads under a topology family.

    This is the single source of truth every in-degree consumer derives
    from — :func:`neighbor_k_max` (the neighbor-list slot count is always
    ``k_in + 1``: slot 0 self + the in-edges), :func:`active_k_in` (the
    paged fault-in closure bound), and ``repro.comm.plan.CommPlan`` (the
    halo-exchange row sets) — so the sharded mix and the store's fault-in
    planner can never disagree about which rows an edge set touches.

    A symmetric mixer samples the undirected matching family regardless of
    ``cfg.kind`` (mirroring ``RoundProgram.mixing_matrix``), whose degree
    bound is ``2 * k_out`` with multiplicity.
    """
    if mixer_kind == "symmetric" or cfg.kind == "symmetric":
        return 2 * cfg.k_out
    if cfg.kind == "two_tier":
        return cfg.n_clients // cfg.n_pods - 1 + cfg.k_out
    if cfg.kind in ("ring", "exponential"):
        return 1
    if cfg.kind == "full":
        return cfg.n_clients - 1
    if cfg.kind == "kout":
        return cfg.k_out
    raise ValueError(f"unknown topology kind: {cfg.kind}")


def neighbor_k_max(cfg: TopologyConfig, mixer_kind: str = "directed") -> int:
    """Static ``k_max`` of the neighbor-list form for a topology family —
    the number the density dispatch rule reasons about.  Always
    ``family_k_in + 1``: the conventional slot-0 self-loop plus the
    family's in-edges (``full`` has no sparse form, so its k_max is n)."""
    return family_k_in(cfg, mixer_kind) + 1


def sample_neighbors(
    key: jax.Array,
    cfg: TopologyConfig,
    t: int = 0,
    losses: jnp.ndarray | None = None,
) -> NeighborList:
    """Sample the round-t mixing operator in neighbor-list form — the
    sparse twin of :func:`sample_mixing` (a :class:`TwoTierOp` for the
    hierarchical family)."""
    n, k = cfg.n_clients, cfg.k_out
    if cfg.kind == "ring":
        return neighbors_ring(n)
    if cfg.kind == "exponential":
        return neighbors_exponential(n, t if cfg.time_varying else 0)
    if cfg.kind == "full":
        raise ValueError("the full graph has no sparse neighbor-list form")
    if cfg.kind == "symmetric":
        return sample_symmetric_neighbors(key, n, k)
    if cfg.kind == "two_tier":
        return sample_two_tier(key, n, cfg.n_pods, k)
    if cfg.kind == "kout":
        if losses is not None:
            return sample_kout_selective_neighbors(key, losses, n, k)
        return sample_kout_neighbors(key, n, k)
    raise ValueError(f"unknown topology kind: {cfg.kind}")


# ---------------------------------------------------------------------------
# Active-set (partial participation) in-neighbor sampling: the paged round.
# ---------------------------------------------------------------------------

def active_k_in(cfg: TopologyConfig) -> int:
    """Static per-receiver in-degree of :func:`sample_active_picks` —
    the fault-in closure of a paged round is at most
    ``k_active * (active_k_in + 1)`` rows (each sampled client plus its
    in-neighbors), which sizes the compact resident bank.  The value is
    :func:`family_k_in` (the shared table); only the family restriction
    is paging-specific."""
    if cfg.kind in ("ring", "exponential", "kout", "two_tier"):
        return family_k_in(cfg)
    raise ValueError(
        f"topology kind {cfg.kind!r} has no active-set (paged) form: the "
        "symmetric family needs consistent masks on both endpoints and "
        "the full graph faults in everything"
    )


def sample_active_picks(
    key: jax.Array, active: jnp.ndarray, cfg: TopologyConfig, t: int = 0
) -> jnp.ndarray:
    """In-neighbors of the round's active receivers, as **global** row ids.

    ``active`` is the ``(k_active,)`` sampled client set; the return is the
    fixed-shape ``(k_active, active_k_in(cfg))`` senders each active client
    gathers from this round — exactly the rows the pager must fault in
    beyond the active set itself (self-loops are implicit and never listed).
    The sampled families draw the *same distribution* as their full-n
    neighbor-list twins restricted to the active receivers: ring /
    exponential are deterministic hops, ``kout`` picks k distinct uniform
    in-neighbors per receiver, ``two_tier`` receives from its whole pod
    plus k cross-pod picks.  ``t`` drives the time-varying exponential
    hop (``2^(t mod log2 n)``), matching ``neighbors_exponential_cycle``.
    """
    n, k = cfg.n_clients, cfg.k_out
    a = jnp.asarray(active, jnp.int32)
    m = a.shape[0]
    if cfg.kind == "ring":
        return ((a - 1) % n)[:, None]
    if cfg.kind == "exponential":
        hops = max(int(np.ceil(np.log2(max(n, 2)))), 1)
        step = 2 ** (t % hops) if cfg.time_varying else 1
        return ((a - step) % n)[:, None]
    if cfg.kind == "kout":
        # Receiver-side k-in picks, scores masked at self — the restriction
        # of sample_kout_neighbors to the active rows.
        scores = jax.random.uniform(key, (m, n))
        scores = scores.at[jnp.arange(m), a].add(-2.0)
        _, picks = jax.lax.top_k(scores, k)
        return picks.astype(jnp.int32)
    if cfg.kind == "two_tier":
        ps = n // cfg.n_pods
        pod = a // ps
        # All pod-mates except self, fixed shape (m, ps-1): rotate the
        # in-pod offset so the self slot drops out.
        off = (a % ps)[:, None] + 1 + jnp.arange(ps - 1)[None, :]
        mates = pod[:, None] * ps + off % ps
        scores = jax.random.uniform(key, (m, n))
        scores = scores - 2.0 * (
            pod[:, None] == (jnp.arange(n) // ps)[None, :]
        )
        _, cross = jax.lax.top_k(scores, k)
        return jnp.concatenate(
            [mates.astype(jnp.int32), cross.astype(jnp.int32)], axis=1
        )
    raise ValueError(
        f"topology kind {cfg.kind!r} has no active-set (paged) form"
    )


# ---------------------------------------------------------------------------
# Hierarchical two-tier family: dense push-sum gossip inside each pod,
# sparse directed k_out edges between pods.
# ---------------------------------------------------------------------------

class TwoTierOp(NamedTuple):
    """Structured mixing operator of the hierarchical two-tier family.

    ``intra`` holds the ``(n_pods, pod_size, pod_size)`` dense
    column-stochastic-within-the-full-matrix pod blocks — block p mixes the
    contiguous row slice ``[p*ps, (p+1)*ps)`` of the bank, so under a
    row-sharded layout whose shards align with pods the intra mixing is a
    purely shard-local batched matmul.  ``inter`` is a
    :class:`NeighborList` carrying each receiver's ``k_out`` cross-pod
    in-edges (slot 0 is the conventional self slot at weight 0 — the self
    contribution lives on the intra diagonal); the inter gather is the
    only communication that crosses shards.  Columns of the densified sum
    (:func:`dense_from_two_tier`) each total exactly 1: a sender j with
    ``c_j`` external receivers has out-degree ``pod_size + c_j`` and every
    one of its edges carries ``1 / (pod_size + c_j)``.
    """

    intra: jnp.ndarray  # (n_pods, ps, ps) float32 pod-block weights
    inter: NeighborList  # (n, k_out + 1) cross-pod edges


@partial(jax.jit, static_argnums=(1, 2, 3))
def sample_two_tier(key: jax.Array, n: int, n_pods: int, k: int) -> TwoTierOp:
    """Sample the two-tier operator: every client receives from its whole
    pod (dense intra-pod gossip) plus ``k`` distinct uniformly-chosen
    senders from *other* pods.  Sender normalization is global — one
    scatter-count of external picks gives each sender's true out-degree —
    so the operator is exactly column-stochastic and push-sum mass is
    conserved across the pod boundary."""
    ps = n // n_pods
    i = jnp.arange(n, dtype=jnp.int32)
    pod = i // ps
    scores = jax.random.uniform(key, (n, n))
    # Same-pod senders (self included) never appear among the cross picks.
    scores = scores - 2.0 * (pod[:, None] == pod[None, :])
    _, picks = jax.lax.top_k(scores, k)  # (n, k) external senders per receiver
    # Sender out-degree: its whole pod (self-loop included) + external picks.
    outdeg = ps + jnp.zeros((n,), jnp.float32).at[picks.reshape(-1)].add(1.0)
    idx = jnp.concatenate([i[:, None], picks.astype(jnp.int32)], axis=1)
    wgt = jnp.concatenate(
        [jnp.zeros((n, 1), jnp.float32), 1.0 / outdeg[picks]], axis=1
    )
    intra = jnp.broadcast_to(
        (1.0 / outdeg).reshape(n_pods, 1, ps), (n_pods, ps, ps)
    ).astype(jnp.float32)
    return TwoTierOp(intra, NeighborList(idx, wgt.astype(jnp.float32)))


def dense_from_two_tier(op: TwoTierOp) -> jnp.ndarray:
    """Densify: block-diagonal intra weights + scattered inter edges — the
    (n, n) matrix the structured operator is exactly equivalent to."""
    from jax.scipy.linalg import block_diag

    n_pods, ps, _ = op.intra.shape
    n = n_pods * ps
    return block_diag(*op.intra) + dense_from_neighbors(op.inter, n)


# ---------------------------------------------------------------------------
# Verification helpers (used by tests & theory checks).
# ---------------------------------------------------------------------------

def is_column_stochastic(P, atol: float = 1e-5) -> bool:
    P = np.asarray(P)
    return bool(
        np.all(P >= -atol) and np.allclose(P.sum(axis=0), 1.0, atol=atol)
    )


def union_strongly_connected(mats) -> bool:
    """Check the union graph of a window of mixing matrices is strongly
    connected (Assumption 1, B-bounded strong connectivity)."""
    adj = np.zeros_like(np.asarray(mats[0]))
    for m in mats:
        adj = np.maximum(adj, (np.asarray(m) > 0).astype(np.float32))
    n = adj.shape[0]
    reach = adj > 0
    # transitive closure by repeated squaring
    for _ in range(int(np.ceil(np.log2(n))) + 1):
        reach = reach | (reach @ reach)
    return bool(reach.all())
