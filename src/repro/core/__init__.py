"""Core contribution of the paper: asymmetric decentralized FL (DFedSGPSM).

Public surface:
  - topology: directed / symmetric, time-varying mixing-matrix samplers.
  - pushsum: gossip + push-sum de-biasing primitives.
  - sam: SAM perturbation & local-momentum transforms (Algorithm 1 inner loop).
  - stages: composable LocalSolver / Compressor / Mixer round stages.
  - program: the pure ``init``/``step`` round-program core over stages.
  - engine: AlgoConfig registry + the thin stateful FLTrainer wrapper.
"""
from repro.core.engine import (
    ALGORITHMS,
    AlgoConfig,
    FLState,
    FLTrainer,
    RoundProgram,
    make_algo,
    make_program,
)
from repro.core.flat import (
    BankSpec,
    BoundDeltaSpec,
    DeltaBankSpec,
    DeltaConfig,
    bind_delta_spec,
    make_delta_spec,
    make_spec,
)
from repro.core.stages import (
    COMPRESSORS,
    MIXERS,
    SOLVERS,
    ChurnState,
    LinkState,
    make_stages,
)
from repro.core.topology import ChurnModel, LinkModel, TopologyConfig

__all__ = [
    "ALGORITHMS",
    "AlgoConfig",
    "BankSpec",
    "BoundDeltaSpec",
    "COMPRESSORS",
    "ChurnModel",
    "ChurnState",
    "DeltaBankSpec",
    "DeltaConfig",
    "FLState",
    "FLTrainer",
    "LinkModel",
    "LinkState",
    "MIXERS",
    "RoundProgram",
    "SOLVERS",
    "TopologyConfig",
    "bind_delta_spec",
    "make_algo",
    "make_delta_spec",
    "make_program",
    "make_spec",
    "make_stages",
]
