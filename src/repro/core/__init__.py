"""Core contribution of the paper: asymmetric decentralized FL (DFedSGPSM).

Public surface:
  - topology: directed / symmetric, time-varying mixing-matrix samplers.
  - pushsum: gossip + push-sum de-biasing primitives.
  - sam: SAM perturbation & local-momentum transforms (Algorithm 1 inner loop).
  - engine: stacked-client simulation engine + the 10-algorithm registry.
"""
from repro.core.engine import ALGORITHMS, AlgoConfig, FLState, FLTrainer, make_algo
from repro.core.flat import BankSpec, make_spec
from repro.core.topology import TopologyConfig

__all__ = [
    "ALGORITHMS",
    "AlgoConfig",
    "BankSpec",
    "FLState",
    "FLTrainer",
    "TopologyConfig",
    "make_algo",
    "make_spec",
]
