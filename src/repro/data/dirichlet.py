"""Dirichlet non-IID partitioner (Hsu et al. 2019), as used by the paper."""
from __future__ import annotations

import numpy as np

__all__ = ["dirichlet_partition", "stack_client_data", "partition_summary"]


def dirichlet_partition(
    labels: np.ndarray,
    n_clients: int,
    alpha: float,
    seed: int = 0,
    min_per_client: int = 2,
):
    """Split sample indices across clients with Dir(alpha) label skew.

    alpha -> 0 gives extreme non-IID (each client few labels); alpha -> inf
    gives IID.  ``alpha <= 0`` is treated as IID (uniform shuffle).
    Returns a list of n_clients index arrays that *partition* the dataset.
    """
    rng = np.random.default_rng(seed)
    n = len(labels)
    if alpha <= 0 or np.isinf(alpha):
        perm = rng.permutation(n)
        return [np.sort(s) for s in np.array_split(perm, n_clients)]

    classes = np.unique(labels)
    client_idx = [[] for _ in range(n_clients)]
    for c in classes:
        idx_c = rng.permutation(np.where(labels == c)[0])
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props)[:-1] * len(idx_c)).astype(int)
        for cid, shard in enumerate(np.split(idx_c, cuts)):
            client_idx[cid].extend(shard.tolist())

    # Re-balance clients that received too few samples.
    sizes = np.array([len(ci) for ci in client_idx])
    for cid in np.where(sizes < min_per_client)[0]:
        donor = int(np.argmax([len(ci) for ci in client_idx]))
        need = min_per_client - len(client_idx[cid])
        client_idx[cid].extend(client_idx[donor][-need:])
        del client_idx[donor][-need:]
    return [np.sort(np.array(ci, dtype=np.int64)) for ci in client_idx]


def stack_client_data(data: dict, parts, pad_to: int | None = None):
    """Materialize per-client shards as stacked fixed-size arrays
    (n_clients, m, ...) — ragged shards are wrapped (resampled) to length m,
    which matches with-replacement minibatch sampling semantics."""
    m = pad_to or max(len(p) for p in parts)
    out = {}
    for k, v in data.items():
        rows = []
        for p in parts:
            reps = np.resize(p, m)  # wrap-around fill
            rows.append(np.asarray(v)[reps])
        out[k] = np.stack(rows)
    return out


def partition_summary(labels: np.ndarray, parts) -> dict:
    """Diagnostics: per-client size and label-distribution skew."""
    sizes = [len(p) for p in parts]
    n_classes = int(labels.max()) + 1
    hists = np.stack(
        [np.bincount(labels[p], minlength=n_classes) for p in parts]
    ).astype(np.float64)
    probs = hists / np.maximum(hists.sum(1, keepdims=True), 1)
    uniform = np.full(n_classes, 1.0 / n_classes)
    tv = 0.5 * np.abs(probs - uniform).sum(1)
    return {
        "sizes": sizes,
        "mean_tv_from_uniform": float(tv.mean()),
        "max_tv_from_uniform": float(tv.max()),
    }
