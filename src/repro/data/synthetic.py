"""Deterministic synthetic datasets shaped like the paper's benchmarks.

The container is offline, so MNIST/CIFAR cannot be downloaded.  We generate
learnable Gaussian-mixture classification problems with matching shapes so
every algorithmic claim (optimizer ordering, ablation trends, convergence)
can be validated end-to-end.  Class signal strength is controlled by
``margin``; intra-class variation by per-sample noise and random per-class
covariance directions, which makes the task non-trivially non-convex for
conv nets while staying CPU-sized.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DatasetSpec", "SPECS", "make_dataset", "make_lm_stream"]


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    shape: tuple  # per-example feature shape
    n_classes: int
    margin: float = 3.0


SPECS = {
    "mnist": DatasetSpec("mnist", (784,), 10, margin=4.0),
    "cifar10": DatasetSpec("cifar10", (32, 32, 3), 10, margin=3.0),
    "cifar100": DatasetSpec("cifar100", (32, 32, 3), 100, margin=2.5),
}


def make_dataset(spec: DatasetSpec | str, n_train: int, n_test: int, seed: int = 0):
    """Returns (train, test) dicts with 'x' float32 and 'y' int32 arrays."""
    if isinstance(spec, str):
        spec = SPECS[spec]
    rng = np.random.default_rng(seed)
    dim = int(np.prod(spec.shape))
    # Class means on a random low-dimensional subspace, scaled by margin.
    basis = rng.standard_normal((spec.n_classes, dim)).astype(np.float32)
    basis /= np.linalg.norm(basis, axis=1, keepdims=True)
    means = spec.margin * basis
    # Per-class anisotropic wobble directions (adds non-convex structure).
    wobble = rng.standard_normal((spec.n_classes, dim)).astype(np.float32)
    wobble /= np.linalg.norm(wobble, axis=1, keepdims=True)

    def sample(n, s):
        r = np.random.default_rng(s)
        y = r.integers(0, spec.n_classes, size=n).astype(np.int32)
        coef = r.standard_normal((n, 1)).astype(np.float32)
        x = (
            means[y]
            + 1.5 * coef * wobble[y]
            + r.standard_normal((n, dim)).astype(np.float32)
        )
        x = np.tanh(x)  # bounded, image-like range
        return {"x": x.reshape((n,) + spec.shape), "y": y}

    return sample(n_train, seed + 1), sample(n_test, seed + 2)


def make_lm_stream(
    vocab_size: int, seq_len: int, n_seqs: int, seed: int = 0, order: int = 2
):
    """Synthetic token stream with learnable Markov structure for LM training.

    A fixed random ``order``-gram transition table generates sequences, so a
    language model can reduce loss well below uniform entropy.
    """
    rng = np.random.default_rng(seed)
    ctx = min(vocab_size, 512)
    table = rng.dirichlet(np.ones(ctx) * 0.1, size=ctx).astype(np.float32)
    toks = np.empty((n_seqs, seq_len), dtype=np.int32)
    state = rng.integers(0, ctx, size=n_seqs)
    for t in range(seq_len):
        u = rng.random((n_seqs, 1))
        cdf = np.cumsum(table[state], axis=1)
        nxt = (u < cdf).argmax(axis=1)
        toks[:, t] = nxt
        state = nxt
    return jnp.asarray(toks % vocab_size)
