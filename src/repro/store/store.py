"""Row-granular chunked on-disk client store.

``ClientStore`` keeps one row per client for every registered field
(params / momentum / EF residual / push-sum weight / last loss) in
``rows_per_chunk``-row chunk files, each written atomically with fsync.
Reads and writes take arbitrary global row-id sets and touch only the
chunks those ids fall into; chunks that were never written are synthesized
from the field defaults / init templates, so store creation is O(1) in n.

Durability is generational copy-on-write (format 2, see
:mod:`repro.store.layout`): every chunk rewrite lands in a fresh
``rows_<start>.g<gen>.npz`` file whose checksum and dirty-row set are
recorded in the manifest at the next :meth:`ClientStore.update_meta`
commit.  Fault-in verifies the checksum: a mismatching chunk is moved to
``quarantine/`` and either rebuilt from the templates (when none of its
rows ever held trained data) or surfaced as a loud
:class:`~repro.store.faults.StoreCorruptionError` naming the chunk, the
file, the committed round, and the rows at stake — flipped bits are never
silently consumed.  Transient read/write ``OSError`` is retried with
bounded exponential backoff (:func:`~repro.store.faults.retry_transient`),
and every injected-fault hook of an attached
:class:`~repro.store.faults.FaultInjector` wraps the real file ops.

This is a host-side subsystem — numpy only, no jax — the paging layer
(:mod:`repro.store.paging`) owns device placement.
"""
from __future__ import annotations

import io
import json
import os
import threading

import numpy as np

from repro.store.faults import StoreCorruptionError, retry_transient
from repro.store.layout import (
    CHECKSUM_ALGO,
    MANIFEST_NAME,
    QUARANTINE_DIR,
    STORE_FORMAT,
    FieldSpec,
    blob_filename,
    checksum,
    gen_filename,
    parse_chunk_filename,
    template_filename,
    write_bytes_atomic,
    write_json_atomic,
    npy_bytes,
    npz_bytes,
)

__all__ = ["ClientStore"]


def _seal_manifest(manifest: dict) -> dict:
    """Embed a self-checksum over the manifest's canonical JSON form.

    The manifest is the recovery root: every chunk and blob checksum
    lives inside it, so a flipped bit in the manifest itself would
    otherwise be the one corruption the store could not detect.  The
    seal is computed over ``json.dumps(..., sort_keys=True)`` of the
    manifest minus the seal field, which round-trips bit-stable through
    ``json.load``."""
    body = {k: v for k, v in manifest.items() if k != "manifest_crc"}
    manifest["manifest_crc"] = checksum(
        json.dumps(body, sort_keys=True).encode()
    )
    return manifest


def _check_manifest_seal(manifest: dict, mpath: str):
    crc = manifest.get("manifest_crc")
    if crc is None:
        return  # pre-seal manifest (format 1, or an older format 2)
    body = {k: v for k, v in manifest.items() if k != "manifest_crc"}
    if checksum(json.dumps(body, sort_keys=True).encode()) != int(crc):
        raise StoreCorruptionError(
            f"store manifest {mpath} fails its self-checksum — the commit "
            "record itself is corrupt and there is no older commit to "
            "roll back to; restore the directory from a replica",
            path=mpath,
        )


class ClientStore:
    """A directory of chunked per-client rows behind a manifest.

    Use :meth:`create` / :meth:`open`; the constructor takes a parsed
    manifest.  All row ids are global ``[0, n)`` ints; ``read_rows`` /
    ``write_rows`` move ``{field: (k, *field.shape)}`` stacks.

    ``faults`` (optional :class:`~repro.store.faults.FaultInjector`) sits
    behind every real file operation; the self-healing counters
    ``io_retries`` / ``backoff_seconds`` / ``corrupt_chunks`` /
    ``rebuilt_rows`` account what the store absorbed.
    """

    def __init__(self, path: str, manifest: dict, faults=None):
        self.path = os.path.abspath(path)
        if manifest.get("format", 0) > STORE_FORMAT:
            raise ValueError(
                f"store {path} has format {manifest['format']} > supported "
                f"{STORE_FORMAT}; upgrade the reader"
            )
        algo = manifest.get("checksum_algo")
        if algo is not None and algo != CHECKSUM_ALGO:
            raise ValueError(
                f"store {path} records checksums under {algo!r} but this "
                f"build verifies {CHECKSUM_ALGO!r}; refusing to mis-verify "
                "(re-create the store or install a matching crc32c wheel)"
            )
        self.n = int(manifest["n"])
        self.rows_per_chunk = int(manifest["rows_per_chunk"])
        self.fields = {
            name: FieldSpec.from_json(name, d)
            for name, d in manifest["fields"].items()
        }
        self._meta = dict(manifest.get("meta", {}))
        self._templates: dict[str, np.ndarray | None] = {}
        # Current generation map: chunk start -> {"file", "crc", "dirty"}.
        # ``crc`` None means an adopted legacy (format-1) chunk whose bytes
        # were written before checksums existed — verification is skipped
        # until the first rewrite records one.  ``dirty`` is the set of
        # global row ids that ever held real (non-template) data.
        self._chunks: dict[int, dict] = {}
        for key, ent in (manifest.get("chunks") or {}).items():
            start = int(key)
            dirty = ent.get("dirty", [])
            if dirty == "all":
                end = min(start + self.rows_per_chunk, self.n)
                dirty = range(start, end)
            self._chunks[start] = {
                "file": ent["file"],
                "crc": None if ent.get("crc") is None else int(ent["crc"]),
                "dirty": set(int(r) for r in dirty),
            }
        self._blobs: dict[str, dict] = {
            name: {"file": ent["file"], "crc": int(ent["crc"])}
            for name, ent in (manifest.get("blobs") or {}).items()
        }
        gens = [0]
        for ent in self._chunks.values():
            parsed = parse_chunk_filename(ent["file"])
            if parsed is not None:
                gens.append(parsed[1])
        for ent in self._blobs.values():
            tail = ent["file"].rsplit(".g", 1)[-1]
            if tail.endswith(".npy"):
                try:
                    gens.append(int(tail[: -len(".npy")]))
                except ValueError:
                    pass
        self._gen = max(gens)
        # Files superseded since the last manifest commit; GC'd only AFTER
        # the next commit publishes their replacements, so the committed
        # state stays intact on disk at every instant.
        self._replaced: set[str] = set()
        self._lock = threading.Lock()
        self.faults = faults
        self._retry_rng = np.random.default_rng(0xFA017)
        # Bytes actually written to chunk files (lazy chunks excluded) —
        # the allocation-accounting tests read this.
        self.bytes_written = 0
        self.chunks_written = 0
        # Self-healing accounting.
        self.io_retries = 0
        self.backoff_seconds = 0.0
        self.corrupt_chunks = 0
        self.rebuilt_rows = 0

    # -- construction ---------------------------------------------------------

    @classmethod
    def create(
        cls,
        path: str,
        n: int,
        fields: dict[str, FieldSpec],
        rows_per_chunk: int = 256,
        templates: dict[str, np.ndarray] | None = None,
        meta: dict | None = None,
        faults=None,
    ) -> "ClientStore":
        """Initialize a fresh store directory (refuses to clobber one)."""
        if n <= 0:
            raise ValueError("n must be positive")
        if rows_per_chunk <= 0:
            raise ValueError("rows_per_chunk must be positive")
        os.makedirs(path, exist_ok=True)
        mpath = os.path.join(path, MANIFEST_NAME)
        if os.path.exists(mpath):
            raise FileExistsError(
                f"{path} already holds a client store; open() it instead"
            )
        manifest = {
            "format": STORE_FORMAT,
            "checksum_algo": CHECKSUM_ALGO,
            "n": int(n),
            "rows_per_chunk": int(rows_per_chunk),
            "fields": {name: f.to_json() for name, f in fields.items()},
            "chunks": {},
            "blobs": {},
            "meta": dict(meta or {}),
        }
        for name, row in (templates or {}).items():
            spec = fields[name]
            row = np.asarray(row, dtype=spec.dtype)
            if row.shape != spec.shape:
                raise ValueError(
                    f"template for {name!r} has shape {row.shape}, "
                    f"field expects {spec.shape}"
                )
            with open(os.path.join(path, template_filename(name)), "wb") as f:
                np.save(f, row)
                f.flush()
                os.fsync(f.fileno())
        write_json_atomic(mpath, _seal_manifest(manifest))
        return cls(path, manifest, faults=faults)

    @classmethod
    def open(cls, path: str, faults=None) -> "ClientStore":
        """Open an existing store, rolling the directory back to its last
        committed state: stale ``*.tmp`` droppings and chunk/blob
        generations the manifest does not reference (writes that landed
        after the last commit, or died mid-flight) are deleted, so a
        reopen after any crash is bit-identical to the last commit.
        Format-1 stores are adopted in place (legacy chunks become
        generation 0, unverified until rewritten)."""
        mpath = os.path.join(path, MANIFEST_NAME)
        with open(mpath) as f:
            try:
                manifest = json.load(f)
            except ValueError as e:
                raise StoreCorruptionError(
                    f"store manifest {mpath} is not parseable JSON — the "
                    "commit record itself is corrupt; restore the "
                    f"directory from a replica ({e})",
                    path=mpath,
                ) from e
        _check_manifest_seal(manifest, mpath)
        if "chunks" not in manifest:
            # Format-1 adoption: every legacy chunk file on disk was
            # written with real data, so its whole row range is dirty —
            # corruption of adopted chunks must raise, never rebuild.
            chunks = {}
            for name in os.listdir(path):
                parsed = parse_chunk_filename(name)
                if parsed is not None and parsed[1] == 0:
                    chunks[str(parsed[0])] = {
                        "file": name, "crc": None, "dirty": "all",
                    }
            manifest["chunks"] = chunks
        referenced = {ent["file"] for ent in manifest["chunks"].values()}
        referenced |= {
            ent["file"] for ent in (manifest.get("blobs") or {}).values()
        }
        for name in os.listdir(path):
            full = os.path.join(path, name)
            if not os.path.isfile(full):
                continue
            stale = name.endswith(".tmp")
            if not stale and name not in referenced:
                stale = (parse_chunk_filename(name) is not None
                         or name.startswith("blob_"))
            if stale:
                os.remove(full)
        return cls(path, manifest, faults=faults)

    @staticmethod
    def exists(path: str) -> bool:
        return os.path.exists(os.path.join(path, MANIFEST_NAME))

    # -- metadata -------------------------------------------------------------

    @property
    def meta(self) -> dict:
        return dict(self._meta)

    def update_meta(self, **kv):
        """Merge scalar metadata (round counter, PRNG key words, config
        fingerprints) into the manifest, atomically and durably — this is
        the store's checkpoint commit point.  The manifest publishes the
        current chunk/blob generation map (file + checksum + dirty rows);
        only after it is durable are the superseded generations GC'd."""
        self._meta.update(kv)
        with self._lock:
            chunks = {}
            for start, ent in self._chunks.items():
                end = min(start + self.rows_per_chunk, self.n)
                dirty = (
                    "all" if len(ent["dirty"]) == end - start
                    else sorted(ent["dirty"])
                )
                chunks[str(start)] = {
                    "file": ent["file"], "crc": ent["crc"], "dirty": dirty,
                }
            blobs = {
                name: {"file": ent["file"], "crc": ent["crc"]}
                for name, ent in self._blobs.items()
            }
            replaced, self._replaced = self._replaced, set()
        manifest = {
            "format": STORE_FORMAT,
            "checksum_algo": CHECKSUM_ALGO,
            "n": self.n,
            "rows_per_chunk": self.rows_per_chunk,
            "fields": {k: f.to_json() for k, f in self.fields.items()},
            "chunks": chunks,
            "blobs": blobs,
            "meta": self._meta,
        }
        try:
            self._retrying_write(
                os.path.join(self.path, MANIFEST_NAME),
                lambda p: write_json_atomic(
                    p, _seal_manifest(manifest), faults=self.faults
                ),
            )
        except BaseException:
            # Commit did not land: keep the superseded files — the old
            # manifest still references them.
            with self._lock:
                self._replaced |= replaced
            raise
        for name in replaced:
            try:
                os.remove(os.path.join(self.path, name))
            except FileNotFoundError:
                pass

    def template(self, field: str) -> np.ndarray | None:
        if field not in self._templates:
            p = os.path.join(self.path, template_filename(field))
            self._templates[field] = np.load(p) if os.path.exists(p) else None
        return self._templates[field]

    @property
    def row_nbytes(self) -> int:
        return sum(f.row_nbytes for f in self.fields.values())

    # -- fault-aware file IO ---------------------------------------------------

    def _count_retry(self, delay: float):
        with self._lock:
            self.io_retries += 1
            self.backoff_seconds += float(delay)

    def _read_file(self, path: str) -> bytes:
        """Read a file's bytes, retrying transient (injected or real)
        ``OSError`` with bounded backoff."""

        def attempt():
            if self.faults is not None:
                self.faults.on_read(path)
            with open(path, "rb") as f:
                return f.read()

        return retry_transient(
            attempt, rng=self._retry_rng, on_retry=self._count_retry
        )

    def _retrying_write(self, path: str, write):
        return retry_transient(
            lambda: write(path), rng=self._retry_rng,
            on_retry=self._count_retry,
        )

    def _quarantine(self, filename: str) -> str:
        qdir = os.path.join(self.path, QUARANTINE_DIR)
        os.makedirs(qdir, exist_ok=True)
        dst = os.path.join(qdir, filename)
        os.replace(os.path.join(self.path, filename), dst)
        return dst

    # -- chunk materialization -------------------------------------------------

    def _default_chunk(self, start: int) -> dict:
        rows = min(self.rows_per_chunk, self.n - start)
        out = {}
        for name, spec in self.fields.items():
            tpl = self.template(name)
            if tpl is not None:
                out[name] = np.broadcast_to(
                    tpl, (rows,) + spec.shape
                ).copy()
            else:
                out[name] = np.full(
                    (rows,) + spec.shape, spec.default, dtype=spec.dtype
                )
        return out

    def _load_chunk(self, start: int) -> dict:
        with self._lock:
            ent = self._chunks.get(start)
            ent = None if ent is None else dict(ent)
        if ent is None:
            return self._default_chunk(start)
        path = os.path.join(self.path, ent["file"])
        data = self._read_file(path)
        if ent["crc"] is not None and checksum(data) != ent["crc"]:
            qpath = self._quarantine(ent["file"])
            with self._lock:
                self.corrupt_chunks += 1
                dirty = sorted(ent["dirty"])
                if not dirty:
                    # No row of this chunk ever held trained data: the
                    # bytes are reproducible from the templates.  Drop the
                    # generation and rebuild.
                    self._chunks.pop(start, None)
                    rows = min(self.rows_per_chunk, self.n - start)
                    self.rebuilt_rows += rows
            if dirty:
                raise StoreCorruptionError(
                    f"chunk rows[{start}:{start + self.rows_per_chunk}) of "
                    f"store {self.path} failed checksum verification "
                    f"(file {ent['file']}, committed round "
                    f"{self._meta.get('round')}); {len(dirty)} dirty rows "
                    f"at stake, quarantined to {qpath}",
                    chunk_start=start, path=qpath,
                    round_no=self._meta.get("round"), dirty_rows=dirty,
                )
            return self._default_chunk(start)
        with np.load(io.BytesIO(data)) as loaded:
            return {name: loaded[name] for name in self.fields}

    def _write_chunk(self, start: int, chunk: dict, dirty_ids):
        data = npz_bytes(chunk)
        crc = checksum(data)
        with self._lock:
            self._gen += 1
            fname = gen_filename(start, self._gen)
        self._retrying_write(
            os.path.join(self.path, fname),
            lambda p: write_bytes_atomic(p, data, faults=self.faults),
        )
        with self._lock:
            old = self._chunks.get(start)
            dirty = set(old["dirty"]) if old is not None else set()
            dirty.update(int(i) for i in dirty_ids)
            if old is not None:
                self._replaced.add(old["file"])
            self._chunks[start] = {"file": fname, "crc": crc, "dirty": dirty}
            self.chunks_written += 1
            self.bytes_written += sum(a.nbytes for a in chunk.values())

    def _chunk_groups(self, ids: np.ndarray):
        """Group sorted positions of ``ids`` by owning chunk."""
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size and (ids.min() < 0 or ids.max() >= self.n):
            raise IndexError(f"row ids out of range [0, {self.n})")
        starts = (ids // self.rows_per_chunk) * self.rows_per_chunk
        order = np.argsort(starts, kind="stable")
        groups = []
        i = 0
        while i < len(order):
            j = i
            s = starts[order[i]]
            while j < len(order) and starts[order[j]] == s:
                j += 1
            groups.append((int(s), order[i:j]))
            i = j
        return ids, groups

    # -- row I/O ---------------------------------------------------------------

    def read_rows(self, ids, fields=None) -> dict:
        """Gather rows ``ids`` (any order, duplicates allowed) into
        ``{field: (len(ids), *shape)}`` stacks, in the order given."""
        names = list(fields) if fields is not None else list(self.fields)
        ids, groups = self._chunk_groups(ids)
        out = {
            name: np.empty(
                (len(ids),) + self.fields[name].shape,
                dtype=self.fields[name].dtype,
            )
            for name in names
        }
        for start, pos in groups:
            chunk = self._load_chunk(start)
            local = ids[pos] - start
            for name in names:
                out[name][pos] = chunk[name][local]
        return out

    def write_rows(self, ids, values: dict):
        """Scatter row stacks back, read-modify-writing each touched chunk
        into a fresh generation.  ``values`` may cover any subset of the
        fields; ids must be unique.  Written ids join the chunk's dirty
        set (recorded at the next commit)."""
        ids, groups = self._chunk_groups(ids)
        if len(np.unique(ids)) != len(ids):
            raise ValueError("write_rows ids must be unique")
        unknown = set(values) - set(self.fields)
        if unknown:
            raise KeyError(f"unknown store fields: {sorted(unknown)}")
        for start, pos in groups:
            chunk = self._load_chunk(start)
            local = ids[pos] - start
            for name, stacked in values.items():
                chunk[name][local] = np.asarray(
                    stacked, dtype=self.fields[name].dtype
                )[pos]
            self._write_chunk(start, chunk, ids[pos])

    def iter_chunks(self, fields=None):
        """Stream ``(start, {field: slab})`` over the whole population in
        row order — lazy chunks synthesized — without ever holding more
        than one chunk in memory.  The paged trainer's full-bank reductions
        (consensus mean, total push-sum mass) are built on this."""
        names = list(fields) if fields is not None else list(self.fields)
        for start in range(0, self.n, self.rows_per_chunk):
            chunk = self._load_chunk(start)
            yield start, {name: chunk[name] for name in names}

    def field_sum(self, field: str, dtype=np.float64):
        """Exact streaming sum of one scalar/vector field over all n rows."""
        spec = self.fields[field]
        total = np.zeros(spec.shape, dtype=dtype)
        for _, chunk in self.iter_chunks(fields=[field]):
            total += chunk[field].astype(dtype).sum(axis=0)
        return total

    # -- sidecar blobs ---------------------------------------------------------

    def write_blob(self, name: str, arr):
        """Write a small named sidecar array (e.g. the churn liveness
        vector) with the same generational + checksummed discipline as
        chunks; committed by the next :meth:`update_meta`."""
        data = npy_bytes(np.asarray(arr))
        crc = checksum(data)
        with self._lock:
            self._gen += 1
            fname = blob_filename(name, self._gen)
        self._retrying_write(
            os.path.join(self.path, fname),
            lambda p: write_bytes_atomic(p, data, faults=self.faults),
        )
        with self._lock:
            old = self._blobs.get(name)
            if old is not None:
                self._replaced.add(old["file"])
            self._blobs[name] = {"file": fname, "crc": crc}

    def read_blob(self, name: str):
        """Read a committed sidecar blob; ``None`` if it was never
        written.  Blobs always hold real state, so a checksum mismatch is
        unconditionally a :class:`StoreCorruptionError`."""
        with self._lock:
            ent = self._blobs.get(name)
            ent = None if ent is None else dict(ent)
        if ent is None:
            return None
        data = self._read_file(os.path.join(self.path, ent["file"]))
        if checksum(data) != ent["crc"]:
            qpath = self._quarantine(ent["file"])
            with self._lock:
                self.corrupt_chunks += 1
            raise StoreCorruptionError(
                f"blob {name!r} of store {self.path} failed checksum "
                f"verification (file {ent['file']}, committed round "
                f"{self._meta.get('round')}); quarantined to {qpath}",
                path=qpath, round_no=self._meta.get("round"),
            )
        return np.load(io.BytesIO(data))

    # -- integrity -------------------------------------------------------------

    def verify_chunks(self) -> dict:
        """Re-read and checksum every materialized chunk and blob, plus
        the committed manifest's self-seal.

        Returns ``{"verified": k, "skipped": j, "bytes": b}`` (skipped =
        adopted legacy chunks with no recorded checksum).  Raises
        :class:`StoreCorruptionError` on the first mismatch — verification
        is read-only and does not quarantine."""
        with self._lock:
            chunk_ents = {s: dict(e) for s, e in self._chunks.items()}
            blob_ents = {n: dict(e) for n, e in self._blobs.items()}
        verified = skipped = nbytes = 0
        for start, ent in sorted(chunk_ents.items()):
            if ent["crc"] is None:
                skipped += 1
                continue
            data = self._read_file(os.path.join(self.path, ent["file"]))
            nbytes += len(data)
            if checksum(data) != ent["crc"]:
                raise StoreCorruptionError(
                    f"verify_chunks: chunk rows[{start}:"
                    f"{start + self.rows_per_chunk}) of store {self.path} "
                    f"failed checksum (file {ent['file']})",
                    chunk_start=start,
                    path=os.path.join(self.path, ent["file"]),
                    round_no=self._meta.get("round"),
                    dirty_rows=sorted(ent["dirty"]),
                )
            verified += 1
        for name, ent in sorted(blob_ents.items()):
            data = self._read_file(os.path.join(self.path, ent["file"]))
            nbytes += len(data)
            if checksum(data) != ent["crc"]:
                raise StoreCorruptionError(
                    f"verify_chunks: blob {name!r} of store {self.path} "
                    f"failed checksum (file {ent['file']})",
                    path=os.path.join(self.path, ent["file"]),
                    round_no=self._meta.get("round"),
                )
            verified += 1
        mpath = os.path.join(self.path, MANIFEST_NAME)
        if os.path.exists(mpath):
            data = self._read_file(mpath)
            nbytes += len(data)
            try:
                on_disk = json.loads(data)
            except ValueError as e:
                raise StoreCorruptionError(
                    f"verify_chunks: committed manifest {mpath} is not "
                    f"parseable JSON ({e})",
                    path=mpath, round_no=self._meta.get("round"),
                ) from e
            _check_manifest_seal(on_disk, mpath)
            verified += 1
        return {"verified": verified, "skipped": skipped, "bytes": nbytes}
