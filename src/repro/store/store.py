"""Row-granular chunked on-disk client store.

``ClientStore`` keeps one row per client for every registered field
(params / momentum / EF residual / push-sum weight / last loss) in
``rows_per_chunk``-row chunk files, each written atomically with fsync.
Reads and writes take arbitrary global row-id sets and touch only the
chunks those ids fall into; chunks that were never written are synthesized
from the field defaults / init templates, so store creation is O(1) in n.

This is a host-side subsystem — numpy only, no jax — the paging layer
(:mod:`repro.store.paging`) owns device placement.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.store.layout import (
    MANIFEST_NAME,
    STORE_FORMAT,
    FieldSpec,
    chunk_filename,
    template_filename,
    write_json_atomic,
    write_npz_atomic,
)

__all__ = ["ClientStore"]


class ClientStore:
    """A directory of chunked per-client rows behind a manifest.

    Use :meth:`create` / :meth:`open`; the constructor takes a parsed
    manifest.  All row ids are global ``[0, n)`` ints; ``read_rows`` /
    ``write_rows`` move ``{field: (k, *field.shape)}`` stacks.
    """

    def __init__(self, path: str, manifest: dict):
        self.path = os.path.abspath(path)
        if manifest.get("format", 0) > STORE_FORMAT:
            raise ValueError(
                f"store {path} has format {manifest['format']} > supported "
                f"{STORE_FORMAT}; upgrade the reader"
            )
        self.n = int(manifest["n"])
        self.rows_per_chunk = int(manifest["rows_per_chunk"])
        self.fields = {
            name: FieldSpec.from_json(name, d)
            for name, d in manifest["fields"].items()
        }
        self._meta = dict(manifest.get("meta", {}))
        self._templates: dict[str, np.ndarray | None] = {}
        # Bytes actually written to chunk files (lazy chunks excluded) —
        # the allocation-accounting tests read this.
        self.bytes_written = 0
        self.chunks_written = 0

    # -- construction ---------------------------------------------------------

    @classmethod
    def create(
        cls,
        path: str,
        n: int,
        fields: dict[str, FieldSpec],
        rows_per_chunk: int = 256,
        templates: dict[str, np.ndarray] | None = None,
        meta: dict | None = None,
    ) -> "ClientStore":
        """Initialize a fresh store directory (refuses to clobber one)."""
        if n <= 0:
            raise ValueError("n must be positive")
        if rows_per_chunk <= 0:
            raise ValueError("rows_per_chunk must be positive")
        os.makedirs(path, exist_ok=True)
        mpath = os.path.join(path, MANIFEST_NAME)
        if os.path.exists(mpath):
            raise FileExistsError(
                f"{path} already holds a client store; open() it instead"
            )
        manifest = {
            "format": STORE_FORMAT,
            "n": int(n),
            "rows_per_chunk": int(rows_per_chunk),
            "fields": {name: f.to_json() for name, f in fields.items()},
            "meta": dict(meta or {}),
        }
        for name, row in (templates or {}).items():
            spec = fields[name]
            row = np.asarray(row, dtype=spec.dtype)
            if row.shape != spec.shape:
                raise ValueError(
                    f"template for {name!r} has shape {row.shape}, "
                    f"field expects {spec.shape}"
                )
            with open(os.path.join(path, template_filename(name)), "wb") as f:
                np.save(f, row)
                f.flush()
                os.fsync(f.fileno())
        write_json_atomic(mpath, manifest)
        return cls(path, manifest)

    @classmethod
    def open(cls, path: str) -> "ClientStore":
        mpath = os.path.join(path, MANIFEST_NAME)
        with open(mpath) as f:
            return cls(path, json.load(f))

    @staticmethod
    def exists(path: str) -> bool:
        return os.path.exists(os.path.join(path, MANIFEST_NAME))

    # -- metadata -------------------------------------------------------------

    @property
    def meta(self) -> dict:
        return dict(self._meta)

    def update_meta(self, **kv):
        """Merge scalar metadata (round counter, PRNG key words, config
        fingerprints) into the manifest, atomically and durably — this is
        the store's checkpoint commit point."""
        self._meta.update(kv)
        write_json_atomic(
            os.path.join(self.path, MANIFEST_NAME),
            {
                "format": STORE_FORMAT,
                "n": self.n,
                "rows_per_chunk": self.rows_per_chunk,
                "fields": {k: f.to_json() for k, f in self.fields.items()},
                "meta": self._meta,
            },
        )

    def template(self, field: str) -> np.ndarray | None:
        if field not in self._templates:
            p = os.path.join(self.path, template_filename(field))
            self._templates[field] = np.load(p) if os.path.exists(p) else None
        return self._templates[field]

    @property
    def row_nbytes(self) -> int:
        return sum(f.row_nbytes for f in self.fields.values())

    # -- chunk materialization -------------------------------------------------

    def _default_chunk(self, start: int) -> dict:
        rows = min(self.rows_per_chunk, self.n - start)
        out = {}
        for name, spec in self.fields.items():
            tpl = self.template(name)
            if tpl is not None:
                out[name] = np.broadcast_to(
                    tpl, (rows,) + spec.shape
                ).copy()
            else:
                out[name] = np.full(
                    (rows,) + spec.shape, spec.default, dtype=spec.dtype
                )
        return out

    def _load_chunk(self, start: int) -> dict:
        p = os.path.join(self.path, chunk_filename(start))
        if not os.path.exists(p):
            return self._default_chunk(start)
        with np.load(p) as data:
            return {name: data[name] for name in self.fields}

    def _chunk_groups(self, ids: np.ndarray):
        """Group sorted positions of ``ids`` by owning chunk."""
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size and (ids.min() < 0 or ids.max() >= self.n):
            raise IndexError(f"row ids out of range [0, {self.n})")
        starts = (ids // self.rows_per_chunk) * self.rows_per_chunk
        order = np.argsort(starts, kind="stable")
        groups = []
        i = 0
        while i < len(order):
            j = i
            s = starts[order[i]]
            while j < len(order) and starts[order[j]] == s:
                j += 1
            groups.append((int(s), order[i:j]))
            i = j
        return ids, groups

    # -- row I/O ---------------------------------------------------------------

    def read_rows(self, ids, fields=None) -> dict:
        """Gather rows ``ids`` (any order, duplicates allowed) into
        ``{field: (len(ids), *shape)}`` stacks, in the order given."""
        names = list(fields) if fields is not None else list(self.fields)
        ids, groups = self._chunk_groups(ids)
        out = {
            name: np.empty(
                (len(ids),) + self.fields[name].shape,
                dtype=self.fields[name].dtype,
            )
            for name in names
        }
        for start, pos in groups:
            chunk = self._load_chunk(start)
            local = ids[pos] - start
            for name in names:
                out[name][pos] = chunk[name][local]
        return out

    def write_rows(self, ids, values: dict):
        """Scatter row stacks back, read-modify-writing each touched chunk
        atomically.  ``values`` may cover any subset of the fields; ids
        must be unique."""
        ids, groups = self._chunk_groups(ids)
        if len(np.unique(ids)) != len(ids):
            raise ValueError("write_rows ids must be unique")
        unknown = set(values) - set(self.fields)
        if unknown:
            raise KeyError(f"unknown store fields: {sorted(unknown)}")
        for start, pos in groups:
            chunk = self._load_chunk(start)
            local = ids[pos] - start
            for name, stacked in values.items():
                chunk[name][local] = np.asarray(
                    stacked, dtype=self.fields[name].dtype
                )[pos]
            path = os.path.join(self.path, chunk_filename(start))
            write_npz_atomic(path, chunk)
            self.chunks_written += 1
            self.bytes_written += sum(a.nbytes for a in chunk.values())

    def iter_chunks(self, fields=None):
        """Stream ``(start, {field: slab})`` over the whole population in
        row order — lazy chunks synthesized — without ever holding more
        than one chunk in memory.  The paged trainer's full-bank reductions
        (consensus mean, total push-sum mass) are built on this."""
        names = list(fields) if fields is not None else list(self.fields)
        for start in range(0, self.n, self.rows_per_chunk):
            chunk = self._load_chunk(start)
            yield start, {name: chunk[name] for name in names}

    def field_sum(self, field: str, dtype=np.float64):
        """Exact streaming sum of one scalar/vector field over all n rows."""
        spec = self.fields[field]
        total = np.zeros(spec.shape, dtype=dtype)
        for _, chunk in self.iter_chunks(fields=[field]):
            total += chunk[field].astype(dtype).sum(axis=0)
        return total
