"""Fault injection and fault taxonomy for the disk-backed client store.

The store's durability story is only as good as its behavior under the
failures real disks and real processes produce.  This module provides:

- :class:`FaultInjector` — a *seeded* chaos source wired behind the
  store's real file operations (``ClientStore`` routes every chunk /
  manifest / blob read and write through it when attached).  It models
  the four failure shapes the chaos harness exercises: transient ``EIO``
  on read, slow-read stragglers, torn chunk writes (a writer that dies
  mid-``.tmp``, leaving a partial temp file and never renaming), and
  post-write bit-flip corruption (the failure checksums exist to catch).
- :class:`StoreCorruptionError` — checksum mismatch on fault-in.  Raised
  with the chunk id, file path, committed round, and the dirty rows at
  stake, so a corrupted store fails loudly and diagnosably, never
  silently consuming flipped bits.
- :class:`StoreIOError` — the paged pipeline's context wrapper: a
  background prefetch / write-back failure re-raises at ``wait()``
  wrapped with the round number, chunk path, and operation.
- :class:`InjectedCrash` — the simulated process kill the crash-point
  tests throw mid-chunk-write / mid-manifest-commit.  It derives from
  ``BaseException`` so ordinary ``except Exception`` recovery paths do
  not swallow a "kill".
- :func:`retry_transient` — bounded exponential backoff + jitter around
  a transient-faulting IO callable (the policy the store's chunk reads
  and the write-back use).

Everything here is host-side stdlib + numpy; determinism comes from the
injector's own ``numpy.random.Generator`` seeded at construction.
"""
from __future__ import annotations

import dataclasses
import errno
import os
import time

import numpy as np

__all__ = [
    "FaultInjector",
    "InjectedCrash",
    "StoreCorruptionError",
    "StoreIOError",
    "retry_transient",
]


class StoreCorruptionError(RuntimeError):
    """A chunk's bytes no longer match its recorded checksum.

    Carries everything needed to act on the failure: which chunk
    (``chunk_start`` / ``path``), the store round it was committed at
    (``round_no``), and which rows actually held trained data
    (``dirty_rows`` — when empty the chunk was rebuilt from the template
    and this error is not raised at all).
    """

    def __init__(self, message: str, *, chunk_start: int | None = None,
                 path: str | None = None, round_no=None, dirty_rows=None):
        super().__init__(message)
        self.chunk_start = chunk_start
        self.path = path
        self.round_no = round_no
        self.dirty_rows = dirty_rows


class StoreIOError(RuntimeError):
    """A paged-pipeline IO failure, annotated with its context.

    Background prefetch / write-back threads capture exceptions and
    re-raise them on the caller's thread at ``wait()`` — wrapped in this
    type so the message names the round, the operation (read /
    write-back), and the chunk path instead of surfacing a bare
    ``OSError``.  The original failure rides as ``__cause__``.
    """

    def __init__(self, message: str, *, round_no=None, path: str | None = None,
                 op: str | None = None):
        super().__init__(message)
        self.round_no = round_no
        self.path = path
        self.op = op


class InjectedCrash(BaseException):
    """Simulated process kill at an injected crash point.

    A ``BaseException`` on purpose: recovery code that catches
    ``Exception`` (retry loops, error-context wrappers) must not be able
    to "survive" a kill — only the test harness, which expects it,
    catches this.
    """


@dataclasses.dataclass
class FaultInjector:
    """Seeded chaos source for the store's file operations.

    Probabilities are per-operation and drawn from the injector's own
    PRNG, so a given ``seed`` yields one reproducible fault schedule.

    ``eio_prob`` / ``eio_max_per_path``: reads fail with transient
    ``OSError(EIO)``, at most ``eio_max_per_path`` consecutive times per
    file — so bounded retries always eventually succeed (a model of
    transient controller hiccups, not dead media).

    ``slow_prob`` / ``slow_seconds``: reads sleep (straggler IO).

    ``torn_write_prob`` / ``torn_max_per_path``: a write dumps a partial
    ``*.crashed.tmp`` next to its target and fails with ``EIO`` before
    the atomic rename — the classic died-mid-write shape.  Also bounded
    per path so retried writes land.

    ``corrupt_prob``: after a successful write, flip one random bit of
    the file on disk.  The paths hit are recorded in ``corrupted`` (the
    chaos harness asserts every one was *detected* by checksum, never
    silently consumed).

    ``crash_on``: ``"chunk-write"`` or ``"manifest-commit"`` arms a
    one-shot :class:`InjectedCrash` raised mid-write of the next matching
    file (after the partial tmp is dumped, before the rename) — the
    crash-point recovery tests drive this.
    """

    seed: int = 0
    eio_prob: float = 0.0
    eio_max_per_path: int = 2
    slow_prob: float = 0.0
    slow_seconds: float = 0.002
    torn_write_prob: float = 0.0
    torn_max_per_path: int = 1
    corrupt_prob: float = 0.0
    crash_on: str | None = None

    def __post_init__(self):
        for f in ("eio_prob", "slow_prob", "torn_write_prob",
                  "corrupt_prob"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(
                    f"FaultInjector.{f} must be a probability in [0, 1], "
                    f"got {v!r}"
                )
        if self.crash_on not in (None, "chunk-write", "manifest-commit"):
            raise ValueError(
                "FaultInjector.crash_on must be None, 'chunk-write' or "
                f"'manifest-commit', got {self.crash_on!r}"
            )
        self._rng = np.random.default_rng(self.seed)
        self._eio_counts: dict[str, int] = {}
        self._torn_counts: dict[str, int] = {}
        self.corrupted: list[str] = []
        self.faults_injected = 0

    # -- read-side faults ---------------------------------------------------

    def on_read(self, path: str):
        """Called before a file read; may sleep or raise transient EIO."""
        if self.slow_prob and self._rng.random() < self.slow_prob:
            self.faults_injected += 1
            time.sleep(self.slow_seconds)
        if self.eio_prob and self._rng.random() < self.eio_prob:
            c = self._eio_counts.get(path, 0)
            if c < self.eio_max_per_path:
                self._eio_counts[path] = c + 1
                self.faults_injected += 1
                raise OSError(
                    errno.EIO, "injected transient read fault", path
                )
        self._eio_counts.pop(path, None)

    # -- write-side faults --------------------------------------------------

    def _is_manifest(self, path: str) -> bool:
        return os.path.basename(path).startswith("manifest")

    def on_write(self, path: str, data: bytes):
        """Called before an atomic write; may tear the write (partial tmp
        dumped, no rename) or raise the armed one-shot crash."""
        crash = self.crash_on is not None and (
            (self.crash_on == "manifest-commit") == self._is_manifest(path)
        )
        torn = bool(
            self.torn_write_prob
            and self._rng.random() < self.torn_write_prob
            and self._torn_counts.get(path, 0) < self.torn_max_per_path
        )
        if not (crash or torn):
            return
        # The died-mid-write residue: a partial foreign tmp next to the
        # target; the real file (old version) is untouched.
        tmp = path + ".crashed.tmp"
        with open(tmp, "wb") as f:
            f.write(data[: max(1, len(data) // 3)])
        self.faults_injected += 1
        if crash:
            self.crash_on = None  # one-shot
            raise InjectedCrash(
                f"injected kill mid-write of {os.path.basename(path)}"
            )
        self._torn_counts[path] = self._torn_counts.get(path, 0) + 1
        raise OSError(errno.EIO, "injected torn write", path)

    def post_write(self, path: str):
        """Called after a durable write; may flip one bit on disk."""
        if not self.corrupt_prob or self._rng.random() >= self.corrupt_prob:
            return
        size = os.path.getsize(path)
        if size == 0:
            return
        off = int(self._rng.integers(size))
        with open(path, "r+b") as f:
            f.seek(off)
            byte = f.read(1)
            f.seek(off)
            f.write(bytes([byte[0] ^ (1 << int(self._rng.integers(8)))]))
        self.corrupted.append(path)
        self.faults_injected += 1


def retry_transient(fn, *, retries: int = 4, backoff_base: float = 0.01,
                    backoff_cap: float = 0.25, rng=None, on_retry=None):
    """Run ``fn()`` retrying transient ``OSError`` with bounded
    exponential backoff + jitter.

    Sleeps ``min(cap, base * 2**attempt) * (0.5 + u)`` with ``u`` uniform
    in [0, 1) from ``rng`` (seeded by the caller for determinism of the
    *schedule*; the sleep itself is wall-clock).  ``on_retry(seconds)``
    is invoked per retry so the caller can account
    retries / backoff_seconds into its stats.  Non-``OSError`` failures
    (checksum corruption, injected crashes) propagate immediately — only
    transient IO is retried.
    """
    rng = rng or np.random.default_rng(0)
    last = None
    for attempt in range(retries + 1):
        try:
            return fn()
        except FileNotFoundError:
            raise
        except OSError as e:
            last = e
            if attempt == retries:
                break
            delay = min(backoff_cap, backoff_base * (2.0 ** attempt))
            delay *= 0.5 + float(rng.random())
            if on_retry is not None:
                on_retry(delay)
            time.sleep(delay)
    raise last
