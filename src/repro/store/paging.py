"""The paging layer: fault-in closure planning + the hot-row cache.

One paged round resides on a **compact bank** of ``c_max`` rows, where
``c_max = min(n, k_active * (k_in + 1))`` is the static upper bound of the
round's fault-in closure:

    closure(t) = active(t)  ∪  in_neighbors(active(t))

``build_plan`` samples the round's active set and its in-neighbor picks
(:func:`repro.core.topology.sample_active_picks`), lays the closure out as
``[active | cold | pads]``, and remaps the picks into a compact
:class:`~repro.core.topology.NeighborList` over resident *slots*:

  * slot 0 is the self-loop; each real row's weight is ``1 / outdeg`` where
    ``outdeg(j) = 1 + #active receivers that picked j`` — exactly the
    column-stochastic sender normalization of
    ``column_stochastic_from_adjacency`` on the active-receiver-masked
    adjacency, so push-sum mass over the closure is conserved and every
    non-closure row (whose column is the identity) is simply *not paged in*.
  * cold rows (faulted in only as senders) keep a pure self-loop at weight
    ``1/outdeg``: their mass share to active receivers leaves through the
    picks, the rest stays home — the de-biased ratio z = x/w of a cold row
    is unchanged because x and w scale identically.
  * pad rows are identity self-loops at weight 1 over zero params / unit
    weight, inert by construction.

The plan is pure host numpy off a fixed PRNG chain
(:func:`repro.core.program.plan_keys`), so the fully-resident reference
driver can replay the identical stream — the equivalence the tests pin.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict

import numpy as np

__all__ = [
    "RoundPlan",
    "closure_bound",
    "build_closure",
    "build_plan",
    "dense_partial_operator",
    "RowCache",
    "PagerStats",
]


def closure_bound(n: int, k_active: int, k_in: int) -> int:
    """Static resident-bank row bound: every active row plus its (at most)
    ``k_in`` distinct in-neighbors, never more than the population."""
    return int(min(n, k_active * (k_in + 1)))


@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """Everything round t needs before any device work happens."""

    t: int
    key: object        # FLState.key at round start (jax PRNG key)
    key_next: object   # the next round's key (the chain the twin replays)
    ckey_base: object  # per-client keys are fold_in(ckey_base, global_id)
    active: np.ndarray   # (k_active,) sampled global ids
    picks: np.ndarray    # (k_active, k_in) global in-neighbor ids
    closure: np.ndarray  # (c,) global ids, [active | cold]
    c: int               # real closure size (<= c_max)
    ids: np.ndarray      # (c_max,) global ids, pads repeat closure[0]
    idx: np.ndarray      # (c_max, 1 + k_in) compact in-neighbor slots
    wgt: np.ndarray      # (c_max, 1 + k_in) mixing weights


def build_closure(active: np.ndarray, picks: np.ndarray):
    """``(closure, c)`` with the active rows first and the cold senders
    (picked but not sampled) after, each id exactly once."""
    active = np.asarray(active, dtype=np.int64)
    uniq = np.unique(picks)
    cold = np.setdiff1d(uniq, active)
    closure = np.concatenate([active, cold])
    return closure, int(closure.size)


def build_plan(
    t: int,
    key,
    key_next,
    ckey_base,
    active,
    picks,
    c_max: int,
) -> RoundPlan:
    """Lay the closure out over ``c_max`` resident slots and remap the
    picks into the compact column-stochastic NeighborList (see module
    docstring for the operator's exact semantics)."""
    active = np.asarray(active, dtype=np.int64)
    picks = np.asarray(picks, dtype=np.int64)
    k_active, k_in = picks.shape
    closure, c = build_closure(active, picks)
    if c > c_max:
        raise ValueError(f"closure size {c} exceeds the static bound "
                         f"{c_max}")
    # Global id -> resident slot, vectorized via searchsorted over the
    # sorted closure (every pick is in the closure by construction).
    order = np.argsort(closure, kind="stable")
    slot_of_sorted = order[
        np.searchsorted(closure[order], picks.reshape(-1))
    ]
    slot_picks = slot_of_sorted.reshape(k_active, k_in).astype(np.int32)
    # A pick equal to the receiver's own id (how churn voids a dead
    # sender's edge without changing the pick shape) is INERT: the dense
    # operator forces self-loops on idempotently, so the edge must add
    # nothing beyond the implicit slot-0 self-loop — excluded from the
    # out-degree count and carried at weight 0.
    self_pick = slot_picks == np.arange(k_active, dtype=np.int32)[:, None]
    # Sender out-degree over the masked adjacency: self-loop + the number
    # of active receivers that picked it.
    outdeg = np.ones((c_max,), np.float32)
    np.add.at(outdeg, slot_picks[~self_pick], 1.0)

    slots = np.arange(c_max, dtype=np.int32)
    idx = np.repeat(slots[:, None], 1 + k_in, axis=1)
    idx[:k_active, 1:] = slot_picks
    wgt = np.zeros((c_max, 1 + k_in), np.float32)
    wgt[:, 0] = 1.0 / outdeg          # real rows: the self share
    wgt[c:, 0] = 1.0                  # pads: inert identity
    wgt[:k_active, 1:] = np.where(
        self_pick, 0.0, 1.0 / outdeg[slot_picks]
    )

    ids = np.full((c_max,), closure[0] if c else 0, dtype=np.int64)
    ids[:c] = closure
    return RoundPlan(
        t=t, key=key, key_next=key_next, ckey_base=ckey_base,
        active=active, picks=picks, closure=closure, c=c,
        ids=ids, idx=idx, wgt=wgt,
    )


def dense_partial_operator(active, picks, n: int):
    """The full ``(n, n)`` matrix the compact operator embeds into: the
    active-receiver-masked adjacency, sender-normalized — identity columns
    for every row outside the closure.  The fully-resident reference
    driver mixes with this; ``build_plan``'s weights are the same
    ``1/outdeg`` values, so the two agree to accumulation order."""
    from repro.core import topology

    adj = np.zeros((n, n), np.float32)
    active = np.asarray(active, dtype=np.int64)
    picks = np.asarray(picks, dtype=np.int64)
    adj[np.repeat(active, picks.shape[1]), picks.reshape(-1)] = 1.0
    return topology.column_stochastic_from_adjacency(adj)


@dataclasses.dataclass
class PagerStats:
    """Per-run paging counters — the bench JSON reads these, so cache
    thrash is visible, not just wall-clock."""

    rounds: int = 0
    rows_needed: int = 0        # closure rows assembled across rounds
    rows_carried: int = 0       # served from the previous round's output
    rows_prefetched: int = 0    # served by the background prefetcher
    rows_cache_hit: int = 0     # served from the write-back/LRU cache
    rows_faulted: int = 0       # synchronous store reads on the round path
    chunks_written: int = 0
    prefetch_wait_s: float = 0.0   # time the round path blocked on fetches
    prefetch_busy_s: float = 0.0   # background time spent loading
    writeback_rows: int = 0
    # Self-healing IO counters, mirrored from the store (see
    # ClientStore.io_retries etc.) so the bench JSON shows what the run
    # absorbed: transient-fault retries + their total backoff sleep,
    # checksum failures quarantined, and template-rebuilt rows.
    io_retries: int = 0
    backoff_seconds: float = 0.0
    corrupt_chunks: int = 0
    rebuilt_rows: int = 0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        rounds = max(self.rounds, 1)
        d["rows_faulted_per_round"] = self.rows_faulted / rounds
        d["rows_needed_per_round"] = self.rows_needed / rounds
        hit = (self.rows_carried + self.rows_prefetched
               + self.rows_cache_hit)
        d["prefetch_hit_rate"] = hit / max(self.rows_needed, 1)
        # Background load time that did NOT stall the round path — the
        # overlap the async prefetcher buys.
        d["prefetch_overlap_s"] = max(
            self.prefetch_busy_s - self.prefetch_wait_s, 0.0
        )
        return d


class RowCache:
    """Write-back row cache in front of the store.

    Rows live in one of two tiers: **pending** (dirtied by a round, queued
    for the write-back thread — never evicted until durable) and **LRU**
    (clean copies of recently used rows, bounded by ``capacity``).  Lookup
    order pending -> LRU mirrors the consistency rule: the freshest value
    of a dirty row is always in pending until the store write completes,
    at which point it atomically moves to the LRU tier — a concurrent
    prefetch therefore reads either the pending copy or the durable chunk,
    never a stale intermediate.
    """

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._pending: dict[int, dict] = {}
        self._lru: OrderedDict[int, dict] = OrderedDict()
        self._lock = threading.Lock()

    def __len__(self):
        with self._lock:
            return len(self._pending) + len(self._lru)

    def get(self, gid: int):
        with self._lock:
            row = self._pending.get(gid)
            if row is not None:
                return row
            row = self._lru.get(gid)
            if row is not None:
                self._lru.move_to_end(gid)
            return row

    def put_pending(self, gid: int, row: dict):
        with self._lock:
            self._pending[gid] = row
            self._lru.pop(gid, None)

    def settle(self, gid: int):
        """Move a row pending -> LRU after its chunk write became durable
        (keeps serving hot rows without touching disk)."""
        with self._lock:
            row = self._pending.pop(gid, None)
            if row is not None:
                self._lru[gid] = row
                self._lru.move_to_end(gid)
                while len(self._lru) > self.capacity:
                    self._lru.popitem(last=False)

    def put_clean(self, gid: int, row: dict):
        with self._lock:
            if gid in self._pending:
                return  # a dirtier copy is already queued
            self._lru[gid] = row
            self._lru.move_to_end(gid)
            while len(self._lru) > self.capacity:
                self._lru.popitem(last=False)

    @property
    def pending_count(self) -> int:
        with self._lock:
            return len(self._pending)
