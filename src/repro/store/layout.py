"""On-disk layout of the virtual client population store.

A :class:`~repro.store.store.ClientStore` is one directory:

    store/
      manifest.json            # format version, n, rows_per_chunk, fields,
                               # per-chunk {file, checksum, dirty rows},
                               # free-form scalar meta (round, PRNG key, ...)
      template_params.npy      # one-row init template (broadcast init row)
      rows_00000000.g000001.npz  # chunk generation: rows [0, rows_per_chunk)
      rows_00000256.g000003.npz  # chunk: rows [256, 512), ...
      quarantine/              # checksum-failed chunk files, moved aside

Every *field* is one per-client array (``params`` ``(D,)``, ``mom`` ``(D,)``,
``ef`` ``(D,)``, ``w`` scalar, ``losses`` scalar); a chunk file stores the
row-group slab of every field, so faulting one client touches exactly one
file.  Chunks are **lazy**: a chunk file that was never written simply does
not exist, and reads synthesize its rows from the field defaults / the
one-row templates — creating a 1M-client store writes the manifest plus one
template row, not 1M rows.

Durability (format 2) is generational copy-on-write: a chunk rewrite goes
to a FRESH ``rows_<start>.g<gen>.npz`` file (atomic tmp + fsync + rename),
never in place, and the manifest maps each chunk start to its current
generation file, its CRC32C checksum (CRC32 when no crc32c impl is
baked in — the manifest records which), and the row ids ever written with
real data.  ``update_meta`` — the checkpoint commit point — publishes the
map atomically and only then garbage-collects superseded generations, so
at every instant the last *committed* state is intact on disk:
``ClientStore.open`` deletes unreferenced generations and stale ``*.tmp``
files, recovering bit-identically to the last commit after any crash,
torn write, or post-commit corruption.
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
import tempfile
import zlib

import numpy as np

__all__ = [
    "STORE_FORMAT",
    "MANIFEST_NAME",
    "CHECKSUM_ALGO",
    "checksum",
    "FieldSpec",
    "chunk_start",
    "chunk_filename",
    "gen_filename",
    "parse_chunk_filename",
    "blob_filename",
    "template_filename",
    "npz_bytes",
    "npy_bytes",
    "write_json_atomic",
    "write_npz_atomic",
    "write_bytes_atomic",
    "fsync_dir",
]

# Bumped whenever the directory layout changes incompatibly.  Format 2
# (generational chunks + checksums) still READS format-1 stores: legacy
# un-suffixed chunk files are adopted as generation 0 with no recorded
# checksum, and the first commit rewrites the manifest as format 2.
STORE_FORMAT = 2
MANIFEST_NAME = "manifest.json"
QUARANTINE_DIR = "quarantine"

# CRC32C (Castagnoli) when a native implementation is available; the
# stdlib's zlib.crc32 otherwise.  A pure-Python CRC32C would be orders of
# magnitude too slow on multi-MB chunks, so the fallback trades the
# polynomial, not the speed — the manifest records which algorithm wrote
# each store and the reader refuses a mismatch instead of mis-verifying.
try:  # pragma: no cover - depends on the environment's wheels
    import google_crc32c as _crc32c_mod

    def _checksum(data: bytes) -> int:
        return int(_crc32c_mod.value(data))

    CHECKSUM_ALGO = "crc32c"
except Exception:  # pragma: no cover
    try:
        import crc32c as _crc32c_mod

        def _checksum(data: bytes) -> int:
            return int(_crc32c_mod.crc32c(data))

        CHECKSUM_ALGO = "crc32c"
    except Exception:
        def _checksum(data: bytes) -> int:
            return zlib.crc32(data) & 0xFFFFFFFF

        CHECKSUM_ALGO = "crc32"


def checksum(data: bytes) -> int:
    """Checksum of a file's exact bytes under :data:`CHECKSUM_ALGO`."""
    return _checksum(data)


@dataclasses.dataclass(frozen=True)
class FieldSpec:
    """One per-client array of the store.

    ``shape`` is the per-row trailing shape (``()`` for scalars).
    ``default`` fills rows of chunks that were never written; a field may
    instead carry a one-row template file (``template_<name>.npy``) — the
    broadcast-init params row — which takes precedence over the scalar.
    """

    name: str
    shape: tuple
    dtype: str
    default: float = 0.0

    def to_json(self) -> dict:
        return {
            "shape": list(self.shape),
            "dtype": self.dtype,
            "default": self.default,
        }

    @classmethod
    def from_json(cls, name: str, d: dict) -> "FieldSpec":
        return cls(name, tuple(d["shape"]), str(d["dtype"]),
                   float(d["default"]))

    @property
    def row_nbytes(self) -> int:
        return int(np.dtype(self.dtype).itemsize * np.prod(self.shape,
                                                           dtype=np.int64))


def chunk_start(row: int, rows_per_chunk: int) -> int:
    return (row // rows_per_chunk) * rows_per_chunk


def chunk_filename(start: int) -> str:
    """Legacy (format-1) un-generational chunk name."""
    return f"rows_{start:08d}.npz"


def gen_filename(start: int, gen: int) -> str:
    """Generational chunk name: ``rows_<start>.g<gen>.npz``."""
    return f"rows_{start:08d}.g{gen:06d}.npz"


def parse_chunk_filename(name: str) -> tuple[int, int] | None:
    """``(start, gen)`` of a chunk file name, or None if not one.
    Legacy names parse as generation 0."""
    if not (name.startswith("rows_") and name.endswith(".npz")):
        return None
    body = name[len("rows_"):-len(".npz")]
    if "." in body:
        start_s, gen_s = body.split(".", 1)
        if not gen_s.startswith("g"):
            return None
        try:
            return int(start_s), int(gen_s[1:])
        except ValueError:
            return None
    try:
        return int(body), 0
    except ValueError:
        return None


def blob_filename(name: str, gen: int) -> str:
    """Generational sidecar blob (e.g. the churn liveness vector)."""
    return f"blob_{name}.g{gen:06d}.npy"


def template_filename(field: str) -> str:
    return f"template_{field}.npy"


def npz_bytes(arrays: dict) -> bytes:
    """Serialize an npz archive to bytes (checksummed before hitting
    disk, so the recorded CRC covers exactly the written file)."""
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    return buf.getvalue()


def npy_bytes(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, np.asarray(arr))
    return buf.getvalue()


def fsync_dir(path: str):
    """Make a rename in ``path`` durable (POSIX: fsync the directory fd)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _atomic_write(path: str, writer):
    """Write via tmp file + fsync + rename + dir fsync — a crashed writer
    leaves either the old file or the new one, never a torn chunk."""
    directory = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            writer(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise
    fsync_dir(directory)


def write_bytes_atomic(path: str, data: bytes, faults=None):
    """Atomic durable write of pre-serialized bytes, with the fault
    injector's hooks around the real file ops: ``on_write`` may tear the
    write (partial foreign tmp, no rename) or raise an injected kill;
    ``post_write`` may flip a bit of the landed file."""
    if faults is not None:
        faults.on_write(path, data)
    _atomic_write(path, lambda f: f.write(data))
    if faults is not None:
        faults.post_write(path)


def write_json_atomic(path: str, obj: dict, faults=None):
    write_bytes_atomic(
        path, json.dumps(obj, indent=1, sort_keys=True).encode(),
        faults=faults,
    )


def write_npz_atomic(path: str, arrays: dict):
    def writer(f):
        np.savez(f, **arrays)

    _atomic_write(path, writer)
