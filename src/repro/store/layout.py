"""On-disk layout of the virtual client population store.

A :class:`~repro.store.store.ClientStore` is one directory:

    store/
      manifest.json            # format version, n, rows_per_chunk, fields,
                               # free-form scalar meta (round, PRNG key, ...)
      template_params.npy      # one-row init template (broadcast init row)
      rows_00000000.npz        # chunk: rows [0, rows_per_chunk)
      rows_00000256.npz        # chunk: rows [256, 512), ...

Every *field* is one per-client array (``params`` ``(D,)``, ``mom`` ``(D,)``,
``ef`` ``(D,)``, ``w`` scalar, ``losses`` scalar); a chunk file stores the
row-group slab of every field, so faulting one client touches exactly one
file.  Chunks are **lazy**: a chunk file that was never written simply does
not exist, and reads synthesize its rows from the field defaults / the
one-row templates — creating a 1M-client store writes the manifest plus one
template row, not 1M rows.  All writes are atomic (tmp + fsync + rename +
directory fsync), so a checkpoint *is* the store manifest: whatever round
the manifest names, every chunk on disk is consistent with it or older only
through rows the round never dirtied.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile

import numpy as np

__all__ = [
    "STORE_FORMAT",
    "MANIFEST_NAME",
    "FieldSpec",
    "chunk_start",
    "chunk_filename",
    "template_filename",
    "write_json_atomic",
    "write_npz_atomic",
    "fsync_dir",
]

# Bumped whenever the directory layout changes incompatibly.
STORE_FORMAT = 1
MANIFEST_NAME = "manifest.json"


@dataclasses.dataclass(frozen=True)
class FieldSpec:
    """One per-client array of the store.

    ``shape`` is the per-row trailing shape (``()`` for scalars).
    ``default`` fills rows of chunks that were never written; a field may
    instead carry a one-row template file (``template_<name>.npy``) — the
    broadcast-init params row — which takes precedence over the scalar.
    """

    name: str
    shape: tuple
    dtype: str
    default: float = 0.0

    def to_json(self) -> dict:
        return {
            "shape": list(self.shape),
            "dtype": self.dtype,
            "default": self.default,
        }

    @classmethod
    def from_json(cls, name: str, d: dict) -> "FieldSpec":
        return cls(name, tuple(d["shape"]), str(d["dtype"]),
                   float(d["default"]))

    @property
    def row_nbytes(self) -> int:
        return int(np.dtype(self.dtype).itemsize * np.prod(self.shape,
                                                           dtype=np.int64))


def chunk_start(row: int, rows_per_chunk: int) -> int:
    return (row // rows_per_chunk) * rows_per_chunk


def chunk_filename(start: int) -> str:
    return f"rows_{start:08d}.npz"


def template_filename(field: str) -> str:
    return f"template_{field}.npy"


def fsync_dir(path: str):
    """Make a rename in ``path`` durable (POSIX: fsync the directory fd)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _atomic_write(path: str, writer):
    """Write via tmp file + fsync + rename + dir fsync — a crashed writer
    leaves either the old file or the new one, never a torn chunk."""
    directory = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            writer(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise
    fsync_dir(directory)


def write_json_atomic(path: str, obj: dict):
    _atomic_write(path, lambda f: f.write(
        json.dumps(obj, indent=1, sort_keys=True).encode()))


def write_npz_atomic(path: str, arrays: dict):
    def writer(f):
        np.savez(f, **arrays)

    _atomic_write(path, writer)
