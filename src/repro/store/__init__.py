"""Virtual client population: disk-backed client store + paged training.

The store keeps the full population's per-client state (params, momentum,
EF residual, push-sum weight, last loss) in fsync'd row-chunk files behind
a manifest; the paging layer keeps only each round's fault-in closure
resident and overlaps next-round prefetch with this round's jitted compute.
See :mod:`repro.store.paging` for the closure/operator semantics and
:mod:`repro.store.paged` for the drivers.
"""
from repro.store.faults import (
    FaultInjector,
    InjectedCrash,
    StoreCorruptionError,
    StoreIOError,
    retry_transient,
)
from repro.store.layout import CHECKSUM_ALGO, STORE_FORMAT, FieldSpec
from repro.store.paged import (
    PagedRunner,
    ResidentDriver,
    bank_fields,
    make_plan,
)
from repro.store.paging import (
    PagerStats,
    RoundPlan,
    RowCache,
    build_closure,
    build_plan,
    closure_bound,
    dense_partial_operator,
)
from repro.store.prefetch import Prefetcher, Writeback
from repro.store.store import ClientStore

__all__ = [
    "CHECKSUM_ALGO",
    "STORE_FORMAT",
    "FieldSpec",
    "FaultInjector",
    "InjectedCrash",
    "StoreCorruptionError",
    "StoreIOError",
    "retry_transient",
    "ClientStore",
    "PagedRunner",
    "ResidentDriver",
    "bank_fields",
    "make_plan",
    "PagerStats",
    "RoundPlan",
    "RowCache",
    "build_closure",
    "build_plan",
    "closure_bound",
    "dense_partial_operator",
    "Prefetcher",
    "Writeback",
]
