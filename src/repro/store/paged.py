"""Paged training: the virtual client population driver.

``PagedRunner`` drives :meth:`repro.core.program.RoundProgram.step_active`
over a disk-backed :class:`~repro.store.store.ClientStore`: per round it
plans the fault-in closure (sampled active set ∪ their in-neighbors),
assembles the compact ``(c_max, D)`` resident bank from carried rows /
prefetched rows / the write-back cache / synchronous store faults, runs the
jitted compact round, and while the device computes it already plans round
t+1 and prefetches its new rows on a background thread; dirty rows write
back asynchronously after the mix.  Device and host bank buffers are
proportional to the closure bound, never to n — n is bounded by disk.

``ResidentDriver`` is the fully-resident reference: the identical PRNG
chain (:func:`repro.core.program.plan_keys`) and the identical
closure-masked mixing operator, executed on a full ``(n, D)`` bank with a
dense matrix.  Paged == resident to float tolerance is the subsystem's
correctness contract, pinned by ``tests/test_store.py``.

A checkpoint *is* the store: ``save()`` flushes the write-back queue and
commits ``(round, key)`` into the manifest; re-opening the directory
resumes bit-identically.
"""
from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pushsum, topology
from repro.core.program import ActiveSlots, FLState, plan_keys
from repro.core.stages import IdentityCompressor, _selfloop_correction
from repro.store import paging
from repro.store.layout import FieldSpec
from repro.store.paging import PagerStats, RowCache, RoundPlan
from repro.store.prefetch import Prefetcher, Writeback
from repro.store.store import ClientStore

__all__ = ["PagedRunner", "ResidentDriver", "make_plan", "bank_fields"]

_PAGED_KINDS = ("ring", "exponential", "kout", "two_tier")


def _check_paged_program(program):
    if program.mixer.kind != "directed" or program.linked:
        raise ValueError(
            "paged training is directed push-sum only (no link scenarios: "
            "delayed/event mixers carry full-population state)"
        )
    if program.selection:
        raise ValueError(
            "loss-selective neighbor sampling reads every client's loss — "
            "it has no paged form"
        )
    if program.mesh is not None:
        raise ValueError("paged training is single-host; drop the mesh")
    if getattr(program, "churned", False):
        raise ValueError(
            "pass churn= to PagedRunner / ResidentDriver, not to "
            "make_program(...): the paged path drives liveness host-side "
            "(dead rows must leave the sampling pool, not ride the bank)"
        )
    if program.topo.kind not in _PAGED_KINDS:
        raise ValueError(
            f"topology kind {program.topo.kind!r} has no paged form "
            f"(supported: {_PAGED_KINDS})"
        )


def bank_fields(program) -> dict:
    """The store schema of one client row under ``program``'s composition:
    params (+ the broadcast init template), momentum, push-sum weight,
    last loss, and the EF residual iff the compressor is stateful."""
    D = program.spec.dim
    fields = {
        "params": FieldSpec("params", (D,), str(program.spec.dtype)),
        "mom": FieldSpec("mom", (D,), "float32"),
        "w": FieldSpec("w", (), "float32", default=1.0),
        "losses": FieldSpec("losses", (), "float32"),
    }
    if program.compressor.stateful:
        fields["ef"] = FieldSpec("ef", (D,), "float32")
    return fields


def _key_words(key) -> list:
    kd = np.asarray(jax.random.key_data(key)) if jnp.issubdtype(
        key.dtype, jax.dtypes.prng_key) else np.asarray(key)
    return [int(x) for x in kd.ravel()]


def _key_from_words(words) -> jax.Array:
    return jnp.asarray(np.asarray(words, dtype=np.uint32))


def make_plan(topo, k_active: int, c_max: int, key, t: int,
              live=None) -> RoundPlan:
    """One round's host-side plan off the shared PRNG chain: sample the
    active set, its in-neighbor picks, and build the compact operator.

    ``topo`` is a :class:`~repro.core.topology.TopologyConfig` or a
    prebuilt :class:`~repro.comm.plan.CommPlan` — the pager is a thin
    consumer of the same communication plan the sharded halo mix ships
    rows from, so "which rows does a consumer read" has exactly one
    definition.

    With a churn liveness vector ``live`` (codes of
    :data:`repro.core.topology.LIVE` etc.), dead clients leave the pool:
    the active set is the first ``k_active`` *live* ids of the same
    permutation (so zero churn reproduces the un-churned stream bit-for-
    bit), and a pick landing on a dead sender is remapped to the
    receiver's own id — an inert edge ``build_plan`` voids, leaving the
    dead row's identity column (and its mass) untouched on disk."""
    from repro.comm.plan import CommPlan

    comm = topo if isinstance(topo, CommPlan) else CommPlan.build(topo)
    topo = comm.topo
    key_next, akey, tkey, ckey_base = plan_keys(key)
    perm = np.asarray(jax.random.permutation(akey, topo.n_clients))
    if live is not None:
        alive = perm[live[perm] == topology.LIVE]
        if alive.size < k_active:
            raise ValueError(
                f"round {t}: only {alive.size} live clients remain, "
                f"cannot sample k_active={k_active} — lower k_active or "
                "the churn fail_prob / permanent_frac"
            )
        active = alive[:k_active]
    else:
        active = perm[:k_active]
    picks = np.asarray(comm.in_neighbors(
        tkey, jnp.asarray(active, jnp.int32), t=t
    ))
    if live is not None:
        picks = np.where(live[picks] == topology.LIVE,
                         picks, active[:, None])
    return paging.build_plan(
        t, key, key_next, ckey_base, active, picks, c_max
    )


class PagedRunner:
    """Disk-backed partial-participation training (see module docstring).

    Args:
      program: a :class:`~repro.core.program.RoundProgram` (directed
        push-sum, link-free, unmeshed).  Its client data must be host
        (numpy) addressable — only the active rows are ever device_put.
      store_dir: the store directory; created if absent, resumed from its
        manifest if it already holds a store.
      k_active: sampled clients per round (static — sizes the jit).
      rows_per_chunk: chunk-file row granularity for fresh stores.
      prefetch: overlap round t+1's closure loads with round t's compute.
      lru_rows: clean-row cache capacity (default ``4 * c_max``).
      churn: optional :class:`~repro.core.topology.ChurnModel` — the
        runner drives liveness host-side: dead clients leave the active
        sampling pool (their rows stay frozen on disk, mass intact), a
        warm resurrection resumes the stored row, a cold one rewrites it
        to ``w * template``.  Liveness persists as a checksummed store
        blob at every ``save()``; the per-round churn key is derived from
        the round index, so resume is stateless.
      faults: optional :class:`~repro.store.faults.FaultInjector` wired
        behind the store's file operations (the chaos harness's hook).
    """

    def __init__(
        self,
        program,
        store_dir: str,
        k_active: int,
        *,
        seed: int = 0,
        rows_per_chunk: int = 256,
        prefetch: bool = True,
        lru_rows: int | None = None,
        churn: topology.ChurnModel | None = None,
        faults=None,
    ):
        _check_paged_program(program)
        if not 1 <= k_active <= program.n:
            raise ValueError(
                f"k_active must be in [1, n={program.n}], got {k_active}"
            )
        self.program = program
        self.topo = program.topo
        self.n = program.n
        self.k_active = int(k_active)
        from repro.comm.plan import CommPlan

        self.comm = CommPlan.build(self.topo)
        self.k_in = self.comm.k_in
        self.c_max = self.comm.closure_bound(k_active)
        self.prefetch_enabled = bool(prefetch)
        self.stats = PagerStats()
        self._fields = bank_fields(program)
        self._spec_meta = _spec_fingerprint(program.spec)
        self._churn = churn if churn is not None and churn.active else None

        # The same key chain as program.init: pkey initializes the model
        # row, skey seeds the round chain.  The churn chain is folded off
        # the root key under its own tag (the same isolation the full-bank
        # trainer uses), keyed per ROUND INDEX so a restored run replays
        # the identical fail/recover schedule with no extra key state.
        # The root is COMMITTED to the store meta on first use: resume
        # ignores the constructor seed for the round chain, so it must
        # ignore it for the churn chain too.
        key = jax.random.PRNGKey(seed)
        pkey, skey = jax.random.split(key)
        self._churn_key0 = jax.random.fold_in(key, 0x0C4B)
        if ClientStore.exists(store_dir):
            self.store = ClientStore.open(store_dir, faults=faults)
            self._validate_store()
            meta = self.store.meta
            self._key = _key_from_words(meta["key"])
            self._round = int(meta["round"])
        else:
            row = np.asarray(program.init_row(pkey))
            self.store = ClientStore.create(
                store_dir, self.n, self._fields,
                rows_per_chunk=rows_per_chunk,
                templates={"params": row},
                meta={
                    "round": 0,
                    "key": _key_words(skey),
                    "spec": self._spec_meta,
                },
                faults=faults,
            )
            self._key = skey
            self._round = 0
        if self._churn is not None:
            words = self.store.meta.get("churn_key0")
            if words is None:
                # First churned run on this store: pin the chain root so
                # any resume (whose seed argument is ignored) replays the
                # identical fail/recover schedule.
                self.store.update_meta(
                    churn_key0=_key_words(self._churn_key0)
                )
            else:
                self._churn_key0 = _key_from_words(words)
        self._load_liveness()

        # Client data stays on the host; only active slices reach the
        # device (k_active rows per round, not n).
        self._data = jax.tree.map(np.asarray, program.data)

        self.cache = RowCache(lru_rows if lru_rows is not None
                              else 4 * self.c_max)
        self.writeback = Writeback(self.store, self.cache)
        self.prefetcher = (
            Prefetcher(self.store, self.cache)
            if self.prefetch_enabled else None
        )
        # Double-buffered host staging: round t+1 assembles into the other
        # buffer while round t's arrays may still back in-flight transfers.
        self._staging = [self._alloc_staging(), self._alloc_staging()]
        self._buf_i = 0
        self._carry: dict | None = None   # closure(t-1) output rows
        self._next_plan: RoundPlan | None = None
        self._next_fetch = None
        self._step = jax.jit(
            functools.partial(
                self.program.step_active, k_active=self.k_active
            ),
            donate_argnums=(0,),
        )

    # -- accounting hooks the acceptance tests read ---------------------------

    @property
    def resident_rows(self) -> int:
        """Rows per device bank buffer — the closure bound, not n."""
        return self.c_max

    @property
    def staging_rows(self) -> int:
        """Host staging rows (double buffer)."""
        return 2 * self.c_max

    @property
    def round_index(self) -> int:
        return self._round

    def _alloc_staging(self) -> dict:
        return {
            name: np.zeros((self.c_max,) + f.shape, dtype=f.dtype)
            for name, f in self._fields.items()
        }

    def _validate_store(self):
        if self.store.n != self.n:
            raise ValueError(
                f"store holds n={self.store.n} clients, program has "
                f"{self.n}"
            )
        if set(self.store.fields) != set(self._fields):
            raise ValueError(
                f"store fields {sorted(self.store.fields)} do not match "
                f"the program composition {sorted(self._fields)} — it was "
                "created from a different stage composition"
            )
        meta = self.store.meta
        if meta.get("spec") != self._spec_meta:
            raise ValueError("store model structure mismatch")

    # -- churn: host-side liveness ---------------------------------------------

    def _load_liveness(self):
        """Sync ``_live`` with the store's committed liveness blob.

        ``_live_round`` is the round index whose transition has already
        been applied — a committed blob corresponds to the committed
        round's state (``_round``); absent one, liveness starts all-LIVE
        with the first transition due at the next round."""
        blob = self.store.read_blob("churn_live")
        if blob is not None and self._churn is None:
            raise ValueError(
                f"store {self.store.path} records churn liveness; "
                "construct the PagedRunner with the same churn= model"
            )
        if blob is not None:
            self._live = np.asarray(blob, np.int8).copy()
            self._live_round = self._round
        else:
            self._live = np.full((self.n,), topology.LIVE, np.int8)
            self._live_round = self._round - 1

    def _ensure_live(self, t: int):
        """Apply churn transitions up to (and including) round ``t``."""
        if self._churn is None:
            return
        while self._live_round < t:
            self._live_round += 1
            key = jax.random.fold_in(self._churn_key0, self._live_round)
            live_new = np.asarray(topology.churn_transition(
                key, jnp.asarray(self._live), self._churn
            ), np.int8)
            if self._churn.resurrect == "cold":
                reborn = np.nonzero(
                    (self._live == topology.DOWN)
                    & (live_new == topology.LIVE)
                )[0]
                if reborn.size:
                    self._cold_reset(reborn)
            self._live = live_new

    def _cold_reset(self, ids: np.ndarray):
        """Rewrite resurrected rows to the cold-start contract: params
        ``w * template`` (de-biased model == template, frozen mass kept
        bit-for-bit), momentum / EF residual zeroed, loss kept.  Routed
        through the pending cache + write-back so every tier stays
        consistent."""
        tpl = self.store.template("params")
        rows, misses = {}, []
        for gid in (int(g) for g in ids):
            row = self.cache.get(gid)
            if row is None:
                misses.append(gid)
            else:
                rows[gid] = row
        if misses:
            stacked = self.store.read_rows(
                np.asarray(misses, dtype=np.int64)
            )
            for i, gid in enumerate(misses):
                rows[gid] = {k: v[i] for k, v in stacked.items()}
        out = {
            name: np.zeros((len(ids),) + f.shape, dtype=f.dtype)
            for name, f in self._fields.items()
        }
        for i, gid in enumerate(int(g) for g in ids):
            w = np.float32(rows[gid]["w"])
            out["params"][i] = (w * tpl).astype(out["params"].dtype)
            out["w"][i] = w
            out["losses"][i] = rows[gid]["losses"]
        gids = np.asarray(ids, dtype=np.int64)
        for i, gid in enumerate(int(g) for g in gids):
            row = {k: v[i] for k, v in out.items()}
            self.cache.put_pending(gid, row)
            if self._carry is not None and gid in self._carry:
                self._carry[gid] = row
        self.writeback.enqueue(gids, out, round_no=self._live_round)

    # -- the paged round -------------------------------------------------------

    def _lookup(self, gid: int, carried: dict, fetched: dict):
        if carried is not None:
            row = carried.get(gid)
            if row is not None:
                self.stats.rows_carried += 1
                return row
        row = fetched.get(gid)
        if row is not None:
            self.stats.rows_prefetched += 1
            return row
        row = self.cache.get(gid)
        if row is not None:
            self.stats.rows_cache_hit += 1
        return row

    def _assemble(self, plan: RoundPlan) -> dict:
        """Fill one staging buffer with the closure rows (pads already
        zero/default from allocation and the post-fill reset below)."""
        buf = self._staging[self._buf_i]
        self._buf_i ^= 1
        fetched: dict = {}
        if self._next_fetch is not None:
            t0 = time.perf_counter()
            fetched = self._next_fetch.wait()
            self.stats.prefetch_wait_s += time.perf_counter() - t0
            self.stats.prefetch_busy_s += self._next_fetch.busy_s
            self._next_fetch = None
        carried = self._carry
        misses = []
        self.stats.rows_needed += plan.c
        for s in range(plan.c):
            gid = int(plan.closure[s])
            row = self._lookup(gid, carried, fetched)
            if row is None:
                misses.append((s, gid))
                continue
            for name in self._fields:
                buf[name][s] = row[name]
        if misses:
            self.stats.rows_faulted += len(misses)
            stacked = self.store.read_rows(
                np.asarray([g for _, g in misses], dtype=np.int64)
            )
            for i, (s, gid) in enumerate(misses):
                row = {k: v[i] for k, v in stacked.items()}
                self.cache.put_clean(gid, row)
                for name in self._fields:
                    buf[name][s] = row[name]
        # Pad slots: inert identity rows (zero params/mom/ef/losses, unit
        # push-sum weight).
        for name, f in self._fields.items():
            buf[name][plan.c:] = 1.0 if name == "w" else 0.0
        return buf

    def _device_state(self, plan: RoundPlan, buf: dict) -> FLState:
        comp = (
            jnp.array(buf["ef"])
            if self.program.compressor.stateful else ()
        )
        return FLState(
            params=jnp.array(buf["params"]),
            mom=jnp.array(buf["mom"]),
            w=jnp.array(buf["w"]),
            key=plan.ckey_base,
            round=jnp.int32(plan.t),
            losses=jnp.array(buf["losses"]),
            comp=comp,
            link=(),
        )

    def run_round(self) -> dict:
        if self._next_plan is not None:
            plan = self._next_plan
        else:
            self._ensure_live(self._round)
            plan = make_plan(
                self.comm, self.k_active, self.c_max, self._key,
                self._round,
                live=self._live if self._churn is not None else None,
            )
        self._next_plan = None
        live_frac = (
            float((self._live == topology.LIVE).mean())
            if self._churn is not None else 1.0
        )
        buf = self._assemble(plan)
        state = self._device_state(plan, buf)
        slots = ActiveSlots(
            ids=jnp.asarray(plan.ids, jnp.int32),
            idx=jnp.asarray(plan.idx),
            wgt=jnp.asarray(plan.wgt),
        )
        data_active = jax.tree.map(
            lambda d: jnp.asarray(d[plan.active]), self._data
        )
        w_in_sum = float(np.asarray(buf["w"][:plan.c], np.float64).sum())
        out_state, metrics = self._step(state, slots, data_active)

        # While the device computes: advance churn to round t+1, plan it,
        # and prefetch the rows its closure adds over this round's (the
        # rest ride the carry).  The churn chain is keyed by round index,
        # so planning ahead sees exactly the liveness round t+1 will.
        self._ensure_live(plan.t + 1)
        next_plan = make_plan(
            self.comm, self.k_active, self.c_max, plan.key_next,
            plan.t + 1,
            live=self._live if self._churn is not None else None,
        )
        if self.prefetcher is not None:
            new_ids = np.setdiff1d(next_plan.closure, plan.closure)
            self._next_fetch = self.prefetcher.submit(
                new_ids, round_no=plan.t + 1
            )
        self._next_plan = next_plan

        # Block on the round's outputs; one transfer of the compact bank.
        host_state, host_metrics = jax.device_get((out_state, metrics))
        c = plan.c
        out_rows = {
            "params": np.asarray(host_state.params[:c]),
            "mom": np.asarray(host_state.mom[:c]),
            "w": np.asarray(host_state.w[:c]),
            "losses": np.asarray(host_state.losses[:c]),
        }
        if self.program.compressor.stateful:
            out_rows["ef"] = np.asarray(host_state.comp[:c])
        carried = {}
        for s in range(c):
            gid = int(plan.closure[s])
            row = {k: v[s] for k, v in out_rows.items()}
            carried[gid] = row
            self.cache.put_pending(gid, row)
        self.writeback.enqueue(plan.closure, out_rows, round_no=plan.t)
        self.stats.writeback_rows += c
        self.stats.chunks_written = self.store.chunks_written
        self.stats.io_retries = self.store.io_retries
        self.stats.backoff_seconds = self.store.backoff_seconds
        self.stats.corrupt_chunks = self.store.corrupt_chunks
        self.stats.rebuilt_rows = self.store.rebuilt_rows
        self._carry = carried
        self._key = plan.key_next
        self._round = plan.t + 1
        self.stats.rounds += 1

        w_out_sum = float(np.asarray(out_rows["w"], np.float64).sum())
        rec = {k: float(v) for k, v in host_metrics.items()}
        # The compact operator keeps all closure mass inside the closure
        # (non-closure columns are identity), so in == out up to the
        # gather's float accumulation — the per-round conservation check.
        rec["w_mass_closure_err"] = abs(w_out_sum - w_in_sum)
        rec["w_sum"] = w_out_sum + float(self.c_max - c) * 0.0  # closure only
        rec["rows_resident"] = c
        if self._churn is not None:
            rec["live_frac"] = live_frac
        return rec

    def fit(self, rounds: int, log=None) -> list:
        history = []
        for _ in range(rounds):
            rec = {"round": self._round, **self.run_round()}
            history.append(rec)
            if log:
                log(rec)
        return history

    # -- whole-population reductions (streamed over chunks) --------------------

    def flush(self):
        """Drain the write-back queue (every dirty row durable)."""
        self.writeback.flush()

    def total_mass(self) -> float:
        """Exact streaming sum of push-sum weights over all n rows —
        the ``sum_i w_i == n`` invariant, cold population included."""
        self.flush()
        return float(self.store.field_sum("w"))

    def mean_params(self) -> np.ndarray:
        """Consensus model row: the population mean of the params bank,
        streamed chunk-by-chunk (never materializes (n, D))."""
        self.flush()
        return (self.store.field_sum("params") / self.n).astype(
            self.store.fields["params"].dtype
        )

    def consensus_error(self) -> float:
        """Mean squared distance of de-biased rows from the bank mean —
        the paged twin of ``pushsum.consensus_error_bank``, two streaming
        passes over the store."""
        self.flush()
        mean = self.store.field_sum("params") / self.n
        total = 0.0
        for _, chunk in self.store.iter_chunks(fields=["params", "w"]):
            z = chunk["params"].astype(np.float64) / chunk["w"].astype(
                np.float64)[:, None]
            total += float(((z - mean[None, :]) ** 2).sum())
        return total / self.n

    def eval_population(self, closure_loss: float | None = None) -> dict:
        """Full-population metrics at an eval cadence, streamed through
        cold chunks via ``store.iter_chunks`` — eval otherwise sees only
        each round's fault-in closure (ROADMAP item 2b).

        One streaming pass accumulates the population view of the stored
        per-client state: the mean/max of every client's last local loss
        (stale for cold clients — that staleness is exactly what the
        population view exposes), the exact total push-sum mass, and the
        de-biased consensus error over all n rows.  With ``closure_loss``
        (the last round's active-mean loss) the record also carries the
        population-vs-closure delta ``pop_loss_delta`` — how far the hot
        closure's view drifts from the whole population's.
        """
        self.flush()
        mean = self.store.field_sum("params") / self.n
        loss_sum = 0.0
        loss_max = -np.inf
        mass = 0.0
        cons = 0.0
        for _, chunk in self.store.iter_chunks(
            fields=["params", "w", "losses"]
        ):
            losses = chunk["losses"].astype(np.float64)
            loss_sum += float(losses.sum())
            loss_max = max(loss_max, float(losses.max()))
            mass += float(chunk["w"].astype(np.float64).sum())
            z = chunk["params"].astype(np.float64) / chunk["w"].astype(
                np.float64)[:, None]
            cons += float(((z - mean[None, :]) ** 2).sum())
        rec = {
            "pop_loss": loss_sum / self.n,
            "pop_loss_max": loss_max,
            "pop_mass": mass,
            "pop_consensus_error": cons / self.n,
        }
        if closure_loss is not None:
            rec["pop_loss_delta"] = rec["pop_loss"] - float(closure_loss)
        return rec

    def read_rows(self, ids) -> dict:
        """Durable values of ``ids`` (flushes the write-back queue first)."""
        self.flush()
        return self.store.read_rows(np.asarray(ids, dtype=np.int64))

    # -- checkpointing: the checkpoint IS the store ----------------------------

    def save(self) -> str:
        """Commit: flush dirty rows, persist the churn liveness blob, then
        atomically stamp ``(round, key)`` into the manifest — the commit
        point that publishes every chunk/blob generation + checksum
        written since the last one.  Returns the store path."""
        if self._churn is not None:
            # _live is kept advanced to _round by the plan-ahead, so the
            # committed blob is exactly the state round _round samples
            # from (a cold reset this may trigger lands before the flush).
            self._ensure_live(self._round)
        self.flush()
        if self._churn is not None:
            self.store.write_blob("churn_live", self._live)
        self.store.update_meta(
            round=self._round, key=_key_words(self._key)
        )
        return self.store.path

    def restore(self, path: str | None = None):
        """Roll back to the last committed manifest: re-reads
        ``(round, key)`` and the liveness blob, drops carried/cached rows,
        and (format 2) deletes every chunk generation written since the
        last ``save()`` — the reopened state is bit-identical to the last
        commit, which is how the chaos harness recovers from a corrupted
        or crashed round."""
        if path is not None and os.path.abspath(path) != self.store.path:
            raise ValueError(
                "a paged trainer restores from its own store directory; "
                f"got {path!r}, store is {self.store.path!r}"
            )
        self.flush()
        self.store = ClientStore.open(
            self.store.path, faults=self.store.faults
        )
        self._validate_store()
        meta = self.store.meta
        self._key = _key_from_words(meta["key"])
        self._round = int(meta["round"])
        self.cache = RowCache(self.cache.capacity)
        self.writeback.close()
        self.writeback = Writeback(self.store, self.cache)
        if self.prefetcher is not None:
            self.prefetcher.close()
            self.prefetcher = Prefetcher(self.store, self.cache)
        self._carry = None
        self._next_plan = None
        self._next_fetch = None
        self._load_liveness()

    def close(self):
        self.writeback.flush()
        self.writeback.close()
        if self.prefetcher is not None:
            self.prefetcher.close()


def _spec_fingerprint(spec) -> dict:
    from repro.checkpoint.io import _spec_meta

    m = _spec_meta(spec)
    out = {k: m[k] for k in ("offsets", "shapes", "dtypes", "dim", "dtype")}
    if "delta" in m:
        # Delta banks: rows are adapter payloads, so the per-leaf mode/rank
        # layout is part of the row's meaning — a store written at rank 8
        # must not silently open under rank 16 (or dense).
        out["delta"] = {
            k: m["delta"][k] for k in ("modes", "ranks")
        }
    return out


class ResidentDriver:
    """Fully-resident reference for the paged round: identical PRNG chain
    and closure-masked operator, full ``(n, D)`` bank, dense mixing.
    Exists for the paged == resident equivalence tests and benches; it
    deliberately materializes everything the pager avoids."""

    def __init__(self, program, k_active: int, *, seed: int = 0,
                 churn: topology.ChurnModel | None = None):
        _check_paged_program(program)
        self.program = program
        self.topo = program.topo
        self.n = program.n
        self.k_active = int(k_active)
        from repro.comm.plan import CommPlan

        self.comm = CommPlan.build(self.topo)
        self.k_in = self.comm.k_in
        self.c_max = self.comm.closure_bound(k_active)
        self._churn = churn if churn is not None and churn.active else None

        key = jax.random.PRNGKey(seed)
        pkey, skey = jax.random.split(key)
        self._churn_key0 = jax.random.fold_in(key, 0x0C4B)
        self._live = np.full((self.n,), topology.LIVE, np.int8)
        row = program.init_row(pkey)
        self._tpl = np.asarray(row)
        bank = jnp.broadcast_to(row, (self.n, program.spec.dim))
        self.state = FLState(
            params=bank,
            mom=jnp.zeros((self.n, program.spec.dim), jnp.float32),
            w=jnp.ones((self.n,), jnp.float32),
            key=skey,
            round=jnp.int32(0),
            losses=jnp.zeros((self.n,), jnp.float32),
            comp=program.compressor.init_state(self.n, program.spec.dim),
            link=(),
        )
        self._key = skey
        self._round = 0
        # Device-resident client data: the traced active gather needs jnp.
        self._data = jax.tree.map(jnp.asarray, program.data)
        self._step = jax.jit(self._step_impl, donate_argnums=0)

    def _step_impl(self, state, P, mask, active, ckey_base):
        prog = self.program
        lr = prog.lr * prog.lr_decay ** state.round.astype(jnp.float32)
        ckeys = jax.vmap(
            lambda i: jax.random.fold_in(ckey_base, i)
        )(active)
        data_a = jax.tree.map(lambda d: d[active], self._data)
        Xa, Va, losses, accs = prog.solver.update(
            prog.loss_fn, prog.spec, state.params[active], state.w[active],
            ckeys, data_a, lr,
        )
        X = state.params.at[active].set(Xa)
        mom = state.mom.at[active].set(Va)
        # Closure-restricted compression: only transmitting rows compress
        # (and, for EF, commit residuals) — rows outside the closure have
        # identity columns and never touch the network this round.
        if isinstance(prog.compressor, IdentityCompressor):
            comp, Xc = state.comp, X
        else:
            comp_new, Xc_all = prog.compressor.apply(state.comp, X)
            Xc = jnp.where(mask[:, None], Xc_all, X)
            comp = (
                jnp.where(mask[:, None], comp_new, state.comp)
                if prog.compressor.stateful else state.comp
            )
        mixed = pushsum.gossip_bank(P, Xc, prog.mixer.backend)
        mixed = _selfloop_correction(P, Xc, X, mixed)
        w_new = pushsum.gossip_weights(P, state.w)
        losses_n = state.losses.at[active].set(losses)
        new_state = FLState(
            mixed, mom, w_new, state.key, state.round + 1, losses_n,
            comp, (),
        )
        metrics = {
            "loss": losses.mean(), "acc": accs.mean(),
            "w_sum": w_new.sum(),
        }
        return new_state, metrics

    def _advance_churn(self, t: int):
        """The paged runner's churn twin: identical key chain (round-index
        folds off the same tagged root), identical cold-reset contract,
        applied to the resident bank in place."""
        key = jax.random.fold_in(self._churn_key0, t)
        live_new = np.asarray(topology.churn_transition(
            key, jnp.asarray(self._live), self._churn
        ), np.int8)
        if self._churn.resurrect == "cold":
            reborn = np.nonzero(
                (self._live == topology.DOWN)
                & (live_new == topology.LIVE)
            )[0]
            if reborn.size:
                idx = jnp.asarray(reborn, jnp.int32)
                w = self.state.w[idx]
                params = self.state.params.at[idx].set(
                    (w[:, None] * jnp.asarray(self._tpl)).astype(
                        self.state.params.dtype
                    )
                )
                mom = self.state.mom.at[idx].set(0.0)
                comp = (
                    self.state.comp.at[idx].set(0.0)
                    if self.program.compressor.stateful else self.state.comp
                )
                self.state = self.state._replace(
                    params=params, mom=mom, comp=comp
                )
        self._live = live_new

    def run_round(self) -> dict:
        if self._churn is not None:
            self._advance_churn(self._round)
        plan = make_plan(
            self.comm, self.k_active, self.c_max, self._key, self._round,
            live=self._live if self._churn is not None else None,
        )
        P = paging.dense_partial_operator(plan.active, plan.picks, self.n)
        mask = np.zeros((self.n,), bool)
        mask[plan.closure] = True
        self.state, metrics = self._step(
            self.state, P, jnp.asarray(mask),
            jnp.asarray(plan.active, jnp.int32), plan.ckey_base,
        )
        self._key = plan.key_next
        self._round = plan.t + 1
        rec = {k: float(v) for k, v in metrics.items()}
        if self._churn is not None:
            rec["live_frac"] = float((self._live == topology.LIVE).mean())
        return rec

    def total_mass(self) -> float:
        return float(np.asarray(self.state.w, np.float64).sum())
