"""Async row movement: background prefetch + write-back threads.

The paged round overlaps three timelines:

    device   : [ jitted round t (dispatched async)            ]
    prefetch :    [ load closure(t+1) \\ closure(t) from disk ]
    writeback:                       [ persist round t-1 dirty rows ]

``Prefetcher`` runs a daemon thread draining fetch requests; each request
resolves rows through the :class:`~repro.store.paging.RowCache` first
(pending > LRU) and batch-reads the misses from the store, so a row dirtied
two rounds ago but not yet durable is served from its pending copy, never a
stale chunk.  ``Writeback`` serializes dirty-row persistence on its own
thread; rows are marked pending in the cache *before* enqueue and settled
into the LRU tier only after their chunk write is durable.  Both threads
surface exceptions on the caller's next interaction rather than dying
silently — wrapped in :class:`~repro.store.faults.StoreIOError` naming the
round, the operation, and the file at fault (the original exception rides
as ``__cause__``); :class:`~repro.store.faults.StoreCorruptionError` and
``BaseException`` kills propagate untouched.
"""
from __future__ import annotations

import queue
import threading
import time

import numpy as np

from repro.store.faults import StoreCorruptionError, StoreIOError

__all__ = ["Fetch", "Prefetcher", "Writeback"]

_STOP = object()


def _wrap_background_error(e: BaseException, *, op: str, round_no,
                           detail: str) -> BaseException:
    """Annotate a background-thread failure with its IO context.  Already
    self-describing errors (corruption carries chunk/round/rows; a
    BaseException kill must never be converted to a catchable
    Exception) pass through unchanged."""
    if not isinstance(e, Exception) or isinstance(
            e, (StoreCorruptionError, StoreIOError)):
        return e
    path = getattr(e, "filename", None)
    where = f" of {path}" if path else ""
    err = StoreIOError(
        f"background {op} failed at round {round_no}{where} ({detail}): "
        f"{type(e).__name__}: {e}",
        round_no=round_no, path=path, op=op,
    )
    err.__cause__ = e
    return err


class Fetch:
    """Handle for one in-flight prefetch; ``wait()`` blocks until the rows
    are staged and returns ``{gid: {field: row}}``."""

    def __init__(self, gids, round_no=None):
        self.gids = np.asarray(gids, dtype=np.int64)
        self.round_no = round_no
        self.rows: dict = {}
        self.busy_s = 0.0       # background time spent resolving
        self.from_cache = 0     # rows served without a store read
        self.from_store = 0
        self._done = threading.Event()
        self._error: BaseException | None = None

    def _finish(self, error=None):
        self._error = error
        self._done.set()

    def wait(self) -> dict:
        self._done.wait()
        if self._error is not None:
            raise _wrap_background_error(
                self._error, op="prefetch", round_no=self.round_no,
                detail=f"{len(self.gids)} rows requested",
            )
        return self.rows


def resolve_rows(store, cache, gids, fetch: Fetch):
    """Fill ``fetch.rows`` for ``gids``: cache first, then one batched
    store read for the misses (which also warms the LRU tier)."""
    misses = []
    for gid in gids:
        row = cache.get(int(gid)) if cache is not None else None
        if row is not None:
            fetch.rows[int(gid)] = row
            fetch.from_cache += 1
        else:
            misses.append(int(gid))
    if misses:
        stacked = store.read_rows(np.asarray(misses, dtype=np.int64))
        for i, gid in enumerate(misses):
            row = {k: v[i] for k, v in stacked.items()}
            fetch.rows[gid] = row
            if cache is not None:
                cache.put_clean(gid, row)
        fetch.from_store += len(misses)
    return fetch


class Prefetcher:
    def __init__(self, store, cache):
        self.store = store
        self.cache = cache
        self._q: queue.Queue = queue.Queue()
        self._thread = threading.Thread(
            target=self._run, name="store-prefetch", daemon=True
        )
        self._thread.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is _STOP:
                return
            fetch = item
            t0 = time.perf_counter()
            try:
                resolve_rows(self.store, self.cache, fetch.gids, fetch)
            except BaseException as e:  # surfaced at wait()
                fetch.busy_s = time.perf_counter() - t0
                fetch._finish(e)
            else:
                fetch.busy_s = time.perf_counter() - t0
                fetch._finish()

    def submit(self, gids, round_no=None) -> Fetch:
        fetch = Fetch(gids, round_no=round_no)
        self._q.put(fetch)
        return fetch

    def close(self):
        self._q.put(_STOP)
        self._thread.join(timeout=30)


class Writeback:
    """Single persistence thread: dirty rows (already pending in the
    cache) are written back chunk-atomically in submission order, then
    settled into the LRU tier."""

    def __init__(self, store, cache):
        self.store = store
        self.cache = cache
        self._q: queue.Queue = queue.Queue()
        self._error: BaseException | None = None
        self._thread = threading.Thread(
            target=self._run, name="store-writeback", daemon=True
        )
        self._thread.start()

    def _run(self):
        while True:
            item = self._q.get()
            try:
                if item is _STOP:
                    return
                ids, values, round_no = item
                try:
                    self.store.write_rows(ids, values)
                except BaseException as e:
                    raise _wrap_background_error(
                        e, op="write-back", round_no=round_no,
                        detail=f"{len(ids)} dirty rows",
                    )
                for gid in ids:
                    self.cache.settle(int(gid))
            except BaseException as e:
                self._error = e
            finally:
                self._q.task_done()

    def _raise_pending(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def enqueue(self, ids, values: dict, round_no=None):
        """``values`` are field-stacked arrays aligned with ``ids``; the
        caller must have ``put_pending`` every row first so reads stay
        consistent while the write is in flight."""
        self._raise_pending()
        self._q.put((np.asarray(ids, dtype=np.int64), values, round_no))

    def flush(self):
        self._q.join()
        self._raise_pending()

    def close(self):
        self._q.put(_STOP)
        self._thread.join(timeout=30)
        self._raise_pending()
