"""Pallas TPU kernels for the framework's compute hot spots.

  gossip_matmul   — push-sum mixing P @ X (MXU-tiled; the paper's comm step)
  gossip_gather   — sparse neighbor-indexed mixing, O(n * k_max * D)
  fused_update    — Algorithm-1 inner loop (de-bias + momentum + descent)
  flash_attention — VMEM-tiled online-softmax attention (causal/SW/GQA)

``ops`` holds the jit'd wrappers (interpret mode on CPU), ``ref`` the
pure-jnp oracles every kernel is validated against.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
