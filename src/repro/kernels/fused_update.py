"""Pallas TPU kernel: fused DFedSGPSM inner-loop update (Algorithm 1, 9-11 + 5).

    v' = alpha * v + g          (momentum)
    x' = x  - eta * v'          (descent)
    z' = x' / w                 (push-sum de-bias for the next iteration)

Unfused, these are 3 elementwise passes = 5 HBM reads + 3 writes of the full
model; fused it is 3 reads + 3 writes in a single pass — the update becomes
strictly HBM-bandwidth-bound at its floor.  Scalars (alpha, eta, 1/w) ride in
as a tiny (3,) operand broadcast to every grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_update_pallas"]


def _kernel(s_ref, x_ref, v_ref, g_ref, xo_ref, vo_ref, zo_ref):
    alpha, eta, w_inv = s_ref[0], s_ref[1], s_ref[2]
    v_new = alpha * v_ref[...] + g_ref[...].astype(jnp.float32)
    x_new = x_ref[...].astype(jnp.float32) - eta * v_new
    vo_ref[...] = v_new
    xo_ref[...] = x_new.astype(xo_ref.dtype)
    zo_ref[...] = (x_new * w_inv).astype(zo_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_update_pallas(
    x: jax.Array,  # (D,) current client params (flat)
    v: jax.Array,  # (D,) momentum buffer, float32
    g: jax.Array,  # (D,) perturbed gradient
    alpha,
    eta,
    w,
    block: int = 65536,
    interpret: bool = False,
):
    (d,) = x.shape
    d_pad = max(((d + block - 1) // block) * block, block)

    def pad(t, dt):
        return jnp.zeros((d_pad,), dt).at[:d].set(t.astype(dt))

    scalars = jnp.stack(
        [jnp.float32(alpha), jnp.float32(eta), 1.0 / jnp.float32(w)])
    x_new, v_new, z_new = pl.pallas_call(
        _kernel,
        grid=(d_pad // block,),
        in_specs=[
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d_pad,), x.dtype),
            jax.ShapeDtypeStruct((d_pad,), jnp.float32),
            jax.ShapeDtypeStruct((d_pad,), x.dtype),
        ],
        interpret=interpret,
    )(scalars, pad(x, x.dtype), pad(v, jnp.float32), pad(g, x.dtype))
    return x_new[:d], v_new[:d], z_new[:d]
