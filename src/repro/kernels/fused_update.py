"""Pallas TPU kernel: fused DFedSGPSM inner-loop update (Algorithm 1, 9-11 + 5).

    v' = alpha * v + g          (momentum)
    x' = x  - eta * v'          (descent)
    z' = x' / w                 (push-sum de-bias for the next iteration)

Unfused, these are 3 elementwise passes = 5 HBM reads + 3 writes of the full
model; fused it is 3 reads + 3 writes in a single pass — the update becomes
strictly HBM-bandwidth-bound at its floor.  Scalars (alpha, eta, 1/w) ride in
as a tiny (3,) operand broadcast to every grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_update_pallas", "fused_update_bank_pallas"]


def _kernel(s_ref, x_ref, v_ref, g_ref, xo_ref, vo_ref, zo_ref):
    alpha, eta, w_inv = s_ref[0], s_ref[1], s_ref[2]
    v_new = alpha * v_ref[...] + g_ref[...].astype(jnp.float32)
    x_new = x_ref[...].astype(jnp.float32) - eta * v_new
    vo_ref[...] = v_new
    xo_ref[...] = x_new.astype(xo_ref.dtype)
    zo_ref[...] = (x_new * w_inv).astype(zo_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_update_pallas(
    x: jax.Array,  # (D,) current client params (flat)
    v: jax.Array,  # (D,) momentum buffer, float32
    g: jax.Array,  # (D,) perturbed gradient
    alpha,
    eta,
    w,
    block: int = 65536,
    interpret: bool = False,
):
    (d,) = x.shape
    d_pad = max(((d + block - 1) // block) * block, block)

    def pad(t, dt):
        if d_pad == d:
            return t.astype(dt)
        return jnp.zeros((d_pad,), dt).at[:d].set(t.astype(dt))

    scalars = jnp.stack(
        [jnp.float32(alpha), jnp.float32(eta), 1.0 / jnp.float32(w)])
    if interpret and d_pad == d == block:
        from repro.kernels.interpret import run_single_block

        return run_single_block(
            _kernel, [scalars, x, v.astype(jnp.float32), g],
            [x.dtype, jnp.float32, x.dtype])
    x_new, v_new, z_new = pl.pallas_call(
        _kernel,
        grid=(d_pad // block,),
        in_specs=[
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d_pad,), x.dtype),
            jax.ShapeDtypeStruct((d_pad,), jnp.float32),
            jax.ShapeDtypeStruct((d_pad,), x.dtype),
        ],
        interpret=interpret,
    )(scalars, pad(x, x.dtype), pad(v, jnp.float32), pad(g, x.dtype))
    return x_new[:d], v_new[:d], z_new[:d]


# ---------------------------------------------------------------------------
# Row-banked variant: the whole (n_clients, D) flat parameter bank in one
# call, with a per-client push-sum weight column.  Same fused arithmetic,
# one grid step per (block_n, block_d) tile.
# ---------------------------------------------------------------------------

def _bank_kernel(s_ref, wi_ref, x_ref, v_ref, g_ref, xo_ref, vo_ref, zo_ref):
    alpha, eta = s_ref[0], s_ref[1]
    v_new = alpha * v_ref[...] + g_ref[...].astype(jnp.float32)
    x_new = x_ref[...].astype(jnp.float32) - eta * v_new
    vo_ref[...] = v_new
    xo_ref[...] = x_new.astype(xo_ref.dtype)
    zo_ref[...] = (x_new * wi_ref[...]).astype(zo_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_d", "interpret"))
def fused_update_bank_pallas(
    X: jax.Array,  # (n, D) flat client-parameter bank
    V: jax.Array,  # (n, D) momentum bank, float32
    G: jax.Array,  # (n, D) per-client (perturbed) gradients
    alpha,
    eta,
    w: jax.Array,  # (n,) per-client push-sum weights
    block_n: int = 8,
    block_d: int = 512,
    interpret: bool = False,
):
    n, d = X.shape
    n_pad = max(((n + block_n - 1) // block_n) * block_n, block_n)
    d_pad = max(((d + block_d - 1) // block_d) * block_d, block_d)
    aligned = (n_pad, d_pad) == (n, d)

    def pad(t, dt):
        if aligned:
            return t.astype(dt)
        return jnp.zeros((n_pad, d_pad), dt).at[:n, :d].set(t.astype(dt))

    scalars = jnp.stack([jnp.float32(alpha), jnp.float32(eta)])
    # Padded rows carry weight 1 so the de-bias never divides by zero.
    w_inv = jnp.ones((n_pad, 1), jnp.float32).at[:n, 0].set(
        1.0 / w.astype(jnp.float32))
    if interpret and aligned and (block_n, block_d) == (n, d):
        from repro.kernels.interpret import run_single_block

        return run_single_block(
            _bank_kernel,
            [scalars, w_inv, X, V.astype(jnp.float32), G],
            [X.dtype, jnp.float32, X.dtype])
    x_new, v_new, z_new = pl.pallas_call(
        _bank_kernel,
        grid=(n_pad // block_n, d_pad // block_d),
        in_specs=[
            pl.BlockSpec((2,), lambda i, j: (0,)),
            pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, block_d), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((block_n, block_d), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, d_pad), X.dtype),
            jax.ShapeDtypeStruct((n_pad, d_pad), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, d_pad), X.dtype),
        ],
        interpret=interpret,
    )(scalars, w_inv, pad(X, X.dtype), pad(V, jnp.float32), pad(G, X.dtype))
    if aligned:
        return x_new, v_new, z_new
    return x_new[:n, :d], v_new[:n, :d], z_new[:n, :d]
