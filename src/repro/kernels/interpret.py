"""Single-block fast path for interpret mode.

``pl.pallas_call(interpret=True)`` emulates the grid with per-block dynamic
slices and output updates — correct, but on CPU those materialize an extra
copy of every operand per call, and the call is an XLA fusion barrier.  When
the launch collapses to a single block covering the whole (unpadded) array
— which is how ``ops`` configures every off-TPU call — the same kernel body
can run directly on whole-array stand-in refs: identical traced-jnp
semantics, zero slicing, and the result inlines into the surrounding jit so
XLA fuses it with its neighbors.  Multi-block launches and explicit block
sizes still go through ``pl.pallas_call``.
"""
from __future__ import annotations

__all__ = ["run_single_block"]


class _BlockRef:
    """Whole-array stand-in for a Pallas Ref (single-block launches only)."""

    __slots__ = ("array", "_dtype")

    def __init__(self, array=None, dtype=None):
        self.array = array
        self._dtype = dtype if dtype is not None else array.dtype

    @property
    def dtype(self):
        return self._dtype

    def __getitem__(self, idx):
        return self.array if idx is Ellipsis else self.array[idx]

    def __setitem__(self, idx, value):
        if idx is not Ellipsis:
            raise NotImplementedError(
                "single-block fast path only supports whole-block writes")
        self.array = value


def run_single_block(kernel, ins, out_dtypes):
    """Run a Pallas kernel body once over whole-array refs.

    Args:
      kernel: the kernel function (positional refs: inputs then outputs).
      ins: input arrays, one per input ref.
      out_dtypes: dtypes of the output refs (shapes come from the writes).

    Returns the output array (or tuple of arrays).
    """
    in_refs = [_BlockRef(a) for a in ins]
    out_refs = [_BlockRef(dtype=dt) for dt in out_dtypes]
    kernel(*in_refs, *out_refs)
    outs = tuple(r.array for r in out_refs)
    return outs[0] if len(outs) == 1 else outs
