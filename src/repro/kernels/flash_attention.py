"""Pallas TPU kernel: flash attention (online softmax), causal + sliding
window + GQA, VMEM-tiled.

Grid is (batch, q_heads, q_blocks, k_blocks); the output block is indexed by
(b, h, qi) only, so it stays resident in VMEM across the k_blocks sweep while
running max/denominator/accumulator live in VMEM scratch.  GQA is handled in
the k/v BlockSpec index maps (kv head = h // group) — no materialized
repeat_kv.  Block shapes default to (128, 128): MXU-aligned and a working
set of ~4 * 128 * head_dim * 4B per step, comfortably inside the ~16 MB VMEM
budget of a v5e core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale, causal, window, block_q, block_k, n_kb):
    qi, kj = pl.program_id(2), pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    ok = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        ok = k_pos <= q_pos
    if window > 0:
        ok = ok & (q_pos - k_pos < window)
    s = jnp.where(ok, s, _NEG)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1)
    v = v_ref[0, 0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kj == n_kb - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_pallas(
    q: jax.Array,  # (B, H, S, hd)
    k: jax.Array,  # (B, KV, S, hd)
    v: jax.Array,  # (B, KV, S, hd)
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
):
    b, h, s, hd = q.shape
    kv = k.shape[1]
    group = h // kv
    assert s % block_q == 0 and s % block_k == 0, "pad seq to block multiple"
    n_qb, n_kb = s // block_q, s // block_k
    scale = hd ** -0.5

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kb=n_kb)

    return pl.pallas_call(
        kernel,
        grid=(b, h, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, qi, kj: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, kj: (b, h // group, kj, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, kj: (b, h // group, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, kj: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
