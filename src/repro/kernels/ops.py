"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs as traced jnp on the host, validating the exact TPU program logic;
on a real TPU backend the same call sites compile to Mosaic.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_update import (
    fused_update_bank_pallas,
    fused_update_pallas,
)
from repro.kernels.gossip_matmul import gossip_matmul_pallas

__all__ = [
    "gossip_matmul",
    "fused_update",
    "fused_update_bank",
    "flash_attention",
    "on_tpu",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gossip_matmul(P, X, **kw):
    interpret = kw.setdefault("interpret", not on_tpu())
    if interpret:
        # Off-TPU, interpret mode executes the grid as a serial loop of
        # dynamic slices — per-step overhead dominates — and there are no
        # MXU tile-alignment constraints.  Collapse to a single pad-free
        # grid step covering the whole (n, D) bank.
        kw.setdefault("block_n", X.shape[0])
        kw.setdefault("block_d", X.shape[1])
    return gossip_matmul_pallas(P, X, **kw)


def fused_update(x, v, g, alpha, eta, w, **kw):
    interpret = kw.setdefault("interpret", not on_tpu())
    if interpret:
        kw.setdefault("block", x.shape[0])
    return fused_update_pallas(x, v, g, alpha, eta, w, **kw)


def fused_update_bank(X, V, G, alpha, eta, w, **kw):
    """Fused momentum/descent/de-bias over the whole (n, D) flat bank."""
    interpret = kw.setdefault("interpret", not on_tpu())
    if interpret:
        kw.setdefault("block_n", X.shape[0])
        kw.setdefault("block_d", X.shape[1])
    return fused_update_bank_pallas(X, V, G, alpha, eta, w, **kw)


def flash_attention(q, k, v, causal=True, window=0, **kw):
    kw.setdefault("interpret", not on_tpu())
    return flash_attention_pallas(q, k, v, causal=causal, window=window, **kw)
