"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs as traced jnp on the host, validating the exact TPU program logic;
on a real TPU backend the same call sites compile to Mosaic.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_update import (
    fused_update_bank_pallas,
    fused_update_pallas,
)
from repro.kernels.gossip_gather import gossip_gather_pallas
from repro.kernels.gossip_matmul import gossip_matmul_pallas

__all__ = [
    "gossip_matmul",
    "gossip_gather",
    "gossip_mix",
    "gossip_mix_sparse",
    "use_sparse_gossip",
    "fused_update",
    "fused_update_bank",
    "flash_attention",
    "on_tpu",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# Below this many elements the per-call overhead of the interpret-mode
# kernel dominates on CPU and the plain einsum wins; on TPU the Mosaic
# kernel is always the right choice.  One threshold, one place.
_GOSSIP_KERNEL_MIN_ELEMS = 1 << 20

# Sparse-vs-dense representation dispatch: the O(n * k_max * D) gather
# wins once the neighbor lists are materially sparser than the dense
# matrix AND n is big enough that the O(n^2 * D) matmul is the round's
# dominant cost.  Below either bound the dense path stays — which pins
# the recorded golden configs (n <= 16) to the dense samplers bit-for-bit.
# The n floor is backend-aware: on TPU the Mosaic gather kernel wins from
# n=32, but on CPU the interpret-mode gather's per-row take overhead beats
# the heavily vectorized dense einsum until well past that — measured
# gossip-phase time at k_out=10 was 0.22x dense speed at n=32 and 0.78x
# at n=64 (round_bench scaling sweep), only crossing 1x around n=128.
# One rule, one place (the sparse twin of _GOSSIP_KERNEL_MIN_ELEMS).
_SPARSE_GOSSIP_MIN_CLIENTS_TPU = 32
_SPARSE_GOSSIP_MIN_CLIENTS_CPU = 128
_SPARSE_GOSSIP_MAX_DENSITY = 0.25


def use_sparse_gossip(n: int, k_max: int) -> bool:
    """THE density rule: neighbor-list gossip iff ``n`` is at least the
    backend's ``_SPARSE_GOSSIP_MIN_CLIENTS_*`` floor and ``k_max / n`` is
    at most ``_SPARSE_GOSSIP_MAX_DENSITY``.  Static shapes in, static bool
    out — callers decide the representation at trace time."""
    floor = (
        _SPARSE_GOSSIP_MIN_CLIENTS_TPU
        if on_tpu()
        else _SPARSE_GOSSIP_MIN_CLIENTS_CPU
    )
    return n >= floor and k_max <= _SPARSE_GOSSIP_MAX_DENSITY * n


def _is_halo(use_kernel) -> bool:
    """Is this ``use_kernel`` a ``repro.comm.plan.HaloBackend``?  Lazy
    import: the kernels layer must not depend on the comm layer at module
    load (comm builds on topology, which the kernels never import)."""
    if not isinstance(use_kernel, tuple):
        return False
    from repro.comm.plan import HaloBackend

    return isinstance(use_kernel, HaloBackend)


def gossip_mix(P, M, use_kernel: bool | None = None):
    """One mixing matmul ``M' = P @ M`` with centralized backend selection.

    Every gossip call site (flat bank, per-leaf pytree, pod replicas)
    routes through here.  ``use_kernel=None`` (the default everywhere)
    resolves automatically: the Pallas kernel on TPU, and on CPU only when
    ``M`` is large enough to amortize interpret-mode overhead — instead of
    each call site hard-coding its own boolean.  ``use_kernel="xla"``
    forces the plain-XLA einsum regardless of size: under GSPMD the
    partitioner must see ordinary HLO (no interpret-mode loop/slice
    structure) to shard the mixing correctly.  A halo backend degrades to
    the einsum too — a dense operator has no sparse row set to ship.
    """
    import jax.numpy as jnp

    if use_kernel is None:
        use_kernel = on_tpu() or M.size >= _GOSSIP_KERNEL_MIN_ELEMS
    elif use_kernel == "xla" or _is_halo(use_kernel):
        use_kernel = False
    if use_kernel:
        return gossip_matmul(P.astype(jnp.float32), M)
    out = jnp.einsum(
        "ij,jd->id", P, M.astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST,
    )
    return out.astype(M.dtype)


def gossip_mix_sparse(idx, wgt, M, use_kernel: bool | None = None):
    """Sparse mixing ``M'[i] = sum_l wgt[i,l] * M[idx[i,l]]`` — the
    neighbor-list twin of :func:`gossip_mix`, same centralized backend
    rule: the Pallas gather kernel on TPU, on CPU only when ``M`` is big
    enough to amortize it (the kernel's slot-loop also avoids the
    reference path's ``(n, k_max, D)`` gather temporary, exactly when that
    temporary would hurt).  ``use_kernel="xla"`` forces
    :func:`~repro.kernels.gossip_gather.gossip_gather_xla` — the kernel
    body as plain traced jnp, same accumulation order, no loop/slice
    structure — so the GSPMD partitioner can turn the row gather into one
    full-bank all-gather.  A :class:`repro.comm.plan.HaloBackend` routes
    to :func:`~repro.kernels.gossip_gather.gossip_gather_halo` instead:
    the ``shard_map`` halo exchange shipping only the plan's remote rows
    per shard."""
    import jax.numpy as jnp

    if use_kernel is None:
        use_kernel = on_tpu() or M.size >= _GOSSIP_KERNEL_MIN_ELEMS
    elif use_kernel == "xla":
        from repro.kernels.gossip_gather import gossip_gather_xla

        return gossip_gather_xla(idx, wgt, M)
    elif _is_halo(use_kernel):
        from repro.kernels.gossip_gather import gossip_gather_halo

        return gossip_gather_halo(
            idx, wgt, M, mesh=use_kernel.mesh, axis=use_kernel.axis,
            plan=use_kernel.plan,
        )
    if use_kernel:
        return gossip_gather(idx, wgt.astype(jnp.float32), M)
    from repro.kernels.ref import gossip_gather_ref

    return gossip_gather_ref(idx, wgt, M)


def gossip_matmul(P, X, **kw):
    interpret = kw.setdefault("interpret", not on_tpu())
    if interpret:
        # Off-TPU, interpret mode executes the grid as a serial loop of
        # dynamic slices — per-step overhead dominates — and there are no
        # MXU tile-alignment constraints.  Collapse to a single pad-free
        # grid step covering the whole (n, D) bank.
        kw.setdefault("block_n", X.shape[0])
        kw.setdefault("block_d", X.shape[1])
    return gossip_matmul_pallas(P, X, **kw)


def gossip_gather(idx, wgt, X, **kw):
    interpret = kw.pop("interpret", not on_tpu())
    if interpret and "block_d" not in kw:
        # Off-TPU the same kernel body runs as a fori_loop of (n, panel)
        # column blocks: composed after the local solver, the whole-bank
        # gather makes XLA CPU materialize one fresh (n, D) temp per
        # neighbor slot (first-touch writes dominate); panel blocking
        # keeps every intermediate cache-resident and bitwise identical.
        from repro.kernels.gossip_gather import gossip_gather_panels

        return gossip_gather_panels(idx, wgt, X, **kw)
    return gossip_gather_pallas(idx, wgt, X, interpret=interpret, **kw)


def fused_update(x, v, g, alpha, eta, w, **kw):
    interpret = kw.setdefault("interpret", not on_tpu())
    if interpret:
        kw.setdefault("block", x.shape[0])
    return fused_update_pallas(x, v, g, alpha, eta, w, **kw)


def fused_update_bank(X, V, G, alpha, eta, w, **kw):
    """Fused momentum/descent/de-bias over the whole (n, D) flat bank."""
    interpret = kw.setdefault("interpret", not on_tpu())
    if interpret:
        kw.setdefault("block_n", X.shape[0])
        kw.setdefault("block_d", X.shape[1])
    return fused_update_bank_pallas(X, V, G, alpha, eta, w, **kw)


def flash_attention(q, k, v, causal=True, window=0, **kw):
    kw.setdefault("interpret", not on_tpu())
    return flash_attention_pallas(q, k, v, causal=causal, window=window, **kw)
