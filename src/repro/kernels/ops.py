"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs as traced jnp on the host, validating the exact TPU program logic;
on a real TPU backend the same call sites compile to Mosaic.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_update import (
    fused_update_bank_pallas,
    fused_update_pallas,
)
from repro.kernels.gossip_matmul import gossip_matmul_pallas

__all__ = [
    "gossip_matmul",
    "gossip_mix",
    "fused_update",
    "fused_update_bank",
    "flash_attention",
    "on_tpu",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# Below this many elements the per-call overhead of the interpret-mode
# kernel dominates on CPU and the plain einsum wins; on TPU the Mosaic
# kernel is always the right choice.  One threshold, one place.
_GOSSIP_KERNEL_MIN_ELEMS = 1 << 20


def gossip_mix(P, M, use_kernel: bool | None = None):
    """One mixing matmul ``M' = P @ M`` with centralized backend selection.

    Every gossip call site (flat bank, per-leaf pytree, pod replicas)
    routes through here.  ``use_kernel=None`` (the default everywhere)
    resolves automatically: the Pallas kernel on TPU, and on CPU only when
    ``M`` is large enough to amortize interpret-mode overhead — instead of
    each call site hard-coding its own boolean.
    """
    import jax.numpy as jnp

    if use_kernel is None:
        use_kernel = on_tpu() or M.size >= _GOSSIP_KERNEL_MIN_ELEMS
    if use_kernel:
        return gossip_matmul(P.astype(jnp.float32), M)
    out = jnp.einsum(
        "ij,jd->id", P, M.astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST,
    )
    return out.astype(M.dtype)


def gossip_matmul(P, X, **kw):
    interpret = kw.setdefault("interpret", not on_tpu())
    if interpret:
        # Off-TPU, interpret mode executes the grid as a serial loop of
        # dynamic slices — per-step overhead dominates — and there are no
        # MXU tile-alignment constraints.  Collapse to a single pad-free
        # grid step covering the whole (n, D) bank.
        kw.setdefault("block_n", X.shape[0])
        kw.setdefault("block_d", X.shape[1])
    return gossip_matmul_pallas(P, X, **kw)


def fused_update(x, v, g, alpha, eta, w, **kw):
    interpret = kw.setdefault("interpret", not on_tpu())
    if interpret:
        kw.setdefault("block", x.shape[0])
    return fused_update_pallas(x, v, g, alpha, eta, w, **kw)


def fused_update_bank(X, V, G, alpha, eta, w, **kw):
    """Fused momentum/descent/de-bias over the whole (n, D) flat bank."""
    interpret = kw.setdefault("interpret", not on_tpu())
    if interpret:
        kw.setdefault("block_n", X.shape[0])
        kw.setdefault("block_d", X.shape[1])
    return fused_update_bank_pallas(X, V, G, alpha, eta, w, **kw)


def flash_attention(q, k, v, causal=True, window=0, **kw):
    kw.setdefault("interpret", not on_tpu())
    return flash_attention_pallas(q, k, v, causal=causal, window=window, **kw)
