"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs as traced jnp on the host, validating the exact TPU program logic;
on a real TPU backend the same call sites compile to Mosaic.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_update import fused_update_pallas
from repro.kernels.gossip_matmul import gossip_matmul_pallas

__all__ = ["gossip_matmul", "fused_update", "flash_attention", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gossip_matmul(P, X, **kw):
    kw.setdefault("interpret", not on_tpu())
    return gossip_matmul_pallas(P, X, **kw)


def fused_update(x, v, g, alpha, eta, w, **kw):
    kw.setdefault("interpret", not on_tpu())
    return fused_update_pallas(x, v, g, alpha, eta, w, **kw)


def flash_attention(q, k, v, causal=True, window=0, **kw):
    kw.setdefault("interpret", not on_tpu())
    return flash_attention_pallas(q, k, v, causal=causal, window=window, **kw)
