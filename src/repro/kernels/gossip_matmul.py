"""Pallas TPU kernel: push-sum gossip mixing  Y = P @ X.

P is the (n, n) column-stochastic mixing matrix, X the client-stacked flat
parameter matrix (n, D).  n is small (#clients, padded to the 128 MXU lane
width) while D is huge (model size), so the tiling keeps the full P row-band
resident in VMEM and streams X in (n, block_d) column panels — one MXU
matmul per grid step, no accumulation loop needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gossip_matmul_pallas"]


def _kernel(p_ref, x_ref, o_ref):
    o_ref[...] = jnp.dot(
        p_ref[...], x_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_d", "interpret"))
def gossip_matmul_pallas(
    P: jax.Array,
    X: jax.Array,
    block_n: int = 128,
    block_d: int = 512,
    interpret: bool = False,
):
    n, D = X.shape
    n_pad = max(((n + block_n - 1) // block_n) * block_n, block_n)
    d_pad = max(((D + block_d - 1) // block_d) * block_d, block_d)
    if interpret and (n_pad, d_pad) == (n, D) and (block_n, block_d) == (n, D):
        # Single unpadded block: run the kernel body directly (same traced
        # jnp, no per-block slicing, fuses into the caller's jit).
        from repro.kernels.interpret import run_single_block

        return run_single_block(_kernel, [P, X], [X.dtype])
    # Skip the pad copies when already tile-aligned (always true in the
    # interpret path, which picks exact block sizes).
    Pp = P if n_pad == n else jnp.zeros(
        (n_pad, n_pad), P.dtype).at[:n, :n].set(P)
    Xp = X if (n_pad, d_pad) == (n, D) else jnp.zeros(
        (n_pad, d_pad), X.dtype).at[:n, :D].set(X)

    out = pl.pallas_call(
        _kernel,
        grid=(n_pad // block_n, d_pad // block_d),
        in_specs=[
            pl.BlockSpec((block_n, n_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((n_pad, block_d), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d_pad), X.dtype),
        interpret=interpret,
    )(Pp, Xp)
    return out if (n_pad, d_pad) == (n, D) else out[:n, :D]
