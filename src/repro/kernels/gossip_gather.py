"""Pallas TPU kernel: sparse neighbor-indexed gossip  Y[i] = sum_l w[i,l] X[idx[i,l]].

The dense ``gossip_matmul`` pays O(n^2 * D) for a mixing matrix whose
columns hold only ``k_out + 1`` nonzeros; this kernel consumes the
fixed-shape ``(n, k_max)`` neighbor lists of
``repro.core.topology.NeighborList`` directly and does O(n * k_max * D)
work: a row gather plus weighted accumulate per neighbor slot.

Tiling mirrors ``gossip_matmul``: n (#clients) is small, D (model size) is
huge, so the grid streams X in ``(n, block_d)`` column panels with the whole
index/weight block resident.  The neighbor-slot loop is a static Python
unroll (k_max is a shape), so each grid step is ``k_max`` vectorized row
gathers — Mosaic lowers ``jnp.take`` along the sublane axis; a
scalar-prefetch DMA variant is the natural next step for very large n.  Off
TPU the single-block interpret fast path runs the same body as plain traced
jnp (zero per-block slicing, fuses into the caller's jit), exactly like
``kernels/interpret.py`` documents.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gossip_gather_pallas", "gossip_gather_panels",
           "gossip_gather_xla"]


def _kernel(idx_ref, wgt_ref, x_ref, o_ref):
    x = x_ref[...]
    idx = idx_ref[...]
    wgt = wgt_ref[...].astype(jnp.float32)
    k_max = wgt.shape[1]
    # Static unroll over neighbor slots: slot l contributes one vectorized
    # row gather + axpy.  Accumulating slot-by-slot keeps the live
    # intermediate at one (n, block_d) panel instead of the (n, k_max,
    # block_d) tensor a take+einsum would materialize.
    acc = wgt[:, 0, None] * jnp.take(x, idx[:, 0], axis=0).astype(jnp.float32)
    for l in range(1, k_max):
        acc += wgt[:, l, None] * jnp.take(
            x, idx[:, l], axis=0
        ).astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def gossip_gather_pallas(
    idx: jax.Array,  # (n, k_max) int32 sender indices (receiver-side)
    wgt: jax.Array,  # (n, k_max) float32 mixing weights
    X: jax.Array,  # (n, D) client-stacked flat parameter bank
    block_d: int = 512,
    interpret: bool = False,
):
    n, D = X.shape
    d_pad = max(((D + block_d - 1) // block_d) * block_d, block_d)
    if interpret and d_pad == D == block_d:
        # Single unpadded block: run the kernel body directly (same traced
        # jnp, no per-block slicing, fuses into the caller's jit).
        from repro.kernels.interpret import run_single_block

        return run_single_block(_kernel, [idx, wgt, X], [X.dtype])
    Xp = X if d_pad == D else jnp.zeros(
        (n, d_pad), X.dtype).at[:, :D].set(X)

    out = pl.pallas_call(
        _kernel,
        grid=(d_pad // block_d,),
        in_specs=[
            pl.BlockSpec(idx.shape, lambda j: (0, 0)),
            pl.BlockSpec(wgt.shape, lambda j: (0, 0)),
            pl.BlockSpec((n, block_d), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((n, block_d), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, d_pad), X.dtype),
        interpret=interpret,
    )(idx, wgt, Xp)
    return out if d_pad == D else out[:, :D]


def gossip_gather_xla(idx: jax.Array, wgt: jax.Array, X: jax.Array):
    """GSPMD executor for the same kernel body: the whole-bank single-block
    form, i.e. plain traced jnp with no loop/slice structure.

    Under a row-sharded bank the partitioner sees ``k_max`` ordinary row
    gathers and lowers them to one all-gather of ``X`` followed by
    shard-local takes — the cross-shard edges of the neighbor list become
    exactly one collective.  The panel executor's ``fori_loop`` +
    ``dynamic_slice`` structure defeats that analysis (and the interpret
    pallas_call grid cannot be partitioned at all), so sharded callers
    route here.  The slot accumulation order is the kernel's own, so
    results are bitwise identical to the other executors.
    """
    from repro.kernels.interpret import run_single_block

    return run_single_block(
        _kernel, [idx, wgt.astype(jnp.float32), X], [X.dtype]
    )


@functools.partial(jax.jit, static_argnames=("panel",))
def gossip_gather_panels(
    idx: jax.Array, wgt: jax.Array, X: jax.Array, panel: int = 8192
):
    """CPU executor for the same kernel body: a ``fori_loop`` of
    ``(n, panel)`` column blocks, each run through ``run_single_block``.

    The whole-bank single-block form is the fast path when the gather
    reads a jit *parameter*, but composed after a producer (the local
    solver) XLA CPU materializes every per-slot gather into its own
    fresh (n, D) temp — measured ~5x slower than the gather's streaming
    floor on 2-core boxes, dominated by first-touch writes.  Blocking
    over D keeps every intermediate at ``(n, panel)`` (cache-resident,
    one reused buffer) and writes the output exactly once via in-place
    ``dynamic_update_slice``; per-element results are bitwise identical
    to the single-block form (the slot accumulation order is unchanged
    and D is not a reduction axis).  The final ragged panel is computed
    from the last ``panel`` columns — the overlap rewrites identical
    values — so no pad copy of ``X`` is ever made.
    """
    from repro.kernels.interpret import run_single_block

    n, D = X.shape
    wgt = wgt.astype(jnp.float32)

    def block(xp):
        return run_single_block(_kernel, [idx, wgt, xp], [X.dtype])

    if D <= panel:
        return block(X)

    def body(p, out):
        xp = jax.lax.dynamic_slice(X, (0, p * panel), (n, panel))
        return jax.lax.dynamic_update_slice(out, block(xp), (0, p * panel))

    out = jax.lax.fori_loop(0, D // panel, body, jnp.zeros_like(X))
    if D % panel:
        xp = jax.lax.dynamic_slice(X, (0, D - panel), (n, panel))
        out = jax.lax.dynamic_update_slice(out, block(xp), (0, D - panel))
    return out
