"""Pallas TPU kernel: sparse neighbor-indexed gossip  Y[i] = sum_l w[i,l] X[idx[i,l]].

The dense ``gossip_matmul`` pays O(n^2 * D) for a mixing matrix whose
columns hold only ``k_out + 1`` nonzeros; this kernel consumes the
fixed-shape ``(n, k_max)`` neighbor lists of
``repro.core.topology.NeighborList`` directly and does O(n * k_max * D)
work: a row gather plus weighted accumulate per neighbor slot.

Tiling mirrors ``gossip_matmul``: n (#clients) is small, D (model size) is
huge, so the grid streams X in ``(n, block_d)`` column panels with the whole
index/weight block resident.  The neighbor-slot loop is a static Python
unroll (k_max is a shape), so each grid step is ``k_max`` vectorized row
gathers — Mosaic lowers ``jnp.take`` along the sublane axis; a
scalar-prefetch DMA variant is the natural next step for very large n.  Off
TPU the single-block interpret fast path runs the same body as plain traced
jnp (zero per-block slicing, fuses into the caller's jit), exactly like
``kernels/interpret.py`` documents.

One kernel body, four executors — all sharing ``_kernel``'s slot-by-slot
f32 accumulation order, selected by ``repro.comm.plan.resolve_backend``:
``gossip_gather_pallas`` (Mosaic/TPU), ``gossip_gather_panels`` (CPU
column panels), ``gossip_gather_xla`` (partitionable whole-bank form — the
GSPMD all-gather lowering), and ``gossip_gather_halo`` (the ``shard_map``
halo exchange shipping only each shard's plan rows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

__all__ = ["gossip_gather_pallas", "gossip_gather_panels",
           "gossip_gather_xla", "gossip_gather_halo"]


def _kernel(idx_ref, wgt_ref, x_ref, o_ref):
    x = x_ref[...]
    idx = idx_ref[...]
    wgt = wgt_ref[...].astype(jnp.float32)
    k_max = wgt.shape[1]
    # Static unroll over neighbor slots: slot l contributes one vectorized
    # row gather + axpy.  Accumulating slot-by-slot keeps the live
    # intermediate at one (n, block_d) panel instead of the (n, k_max,
    # block_d) tensor a take+einsum would materialize.
    acc = wgt[:, 0, None] * jnp.take(x, idx[:, 0], axis=0).astype(jnp.float32)
    for l in range(1, k_max):
        acc += wgt[:, l, None] * jnp.take(
            x, idx[:, l], axis=0
        ).astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def gossip_gather_pallas(
    idx: jax.Array,  # (n, k_max) int32 sender indices (receiver-side)
    wgt: jax.Array,  # (n, k_max) float32 mixing weights
    X: jax.Array,  # (n, D) client-stacked flat parameter bank
    block_d: int = 512,
    interpret: bool = False,
):
    n, D = X.shape
    d_pad = max(((D + block_d - 1) // block_d) * block_d, block_d)
    if interpret and d_pad == D == block_d:
        # Single unpadded block: run the kernel body directly (same traced
        # jnp, no per-block slicing, fuses into the caller's jit).
        from repro.kernels.interpret import run_single_block

        return run_single_block(_kernel, [idx, wgt, X], [X.dtype])
    Xp = X if d_pad == D else jnp.zeros(
        (n, d_pad), X.dtype).at[:, :D].set(X)

    out = pl.pallas_call(
        _kernel,
        grid=(d_pad // block_d,),
        in_specs=[
            pl.BlockSpec(idx.shape, lambda j: (0, 0)),
            pl.BlockSpec(wgt.shape, lambda j: (0, 0)),
            pl.BlockSpec((n, block_d), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((n, block_d), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n, d_pad), X.dtype),
        interpret=interpret,
    )(idx, wgt, Xp)
    return out if d_pad == D else out[:, :D]


def gossip_gather_xla(idx: jax.Array, wgt: jax.Array, X: jax.Array):
    """GSPMD *all-gather* executor for the same kernel body: the whole-bank
    single-block form, i.e. plain traced jnp with no loop/slice structure.

    Under a row-sharded bank the partitioner sees ``k_max`` ordinary row
    gathers and lowers them to one full all-gather of ``X`` followed by
    shard-local takes — O(n · D) received per device per mix, regardless
    of how sparse the neighbor lists are.  That is the baseline the
    dispatch rule (``repro.comm.plan.resolve_backend``) falls back to for
    dense operators and for sampled families when the halo executor is not
    forced; :func:`gossip_gather_halo` is the O(k · D) replacement that
    ships only the rows the plan says each shard reads.  The panel
    executor's ``fori_loop`` + ``dynamic_slice`` structure defeats the
    partitioner's analysis (and the interpret pallas_call grid cannot be
    partitioned at all), so sharded all-gather callers route here.  The
    slot accumulation order is the kernel's own, so results are bitwise
    identical to the other executors.
    """
    from repro.kernels.interpret import run_single_block

    return run_single_block(
        _kernel, [idx, wgt.astype(jnp.float32), X], [X.dtype]
    )


def _halo_accumulate(idx_s, wgt_s, x_s, halo, pos, me, m):
    """The kernel body's slot-by-slot f32 accumulation, per shard: slot l
    reads either the shard-local row or its halo slot (``pos`` maps
    (source shard, source-local offset) -> halo row; zero-weight slots may
    resolve to an arbitrary halo row — they contribute exactly 0.0).  The
    accumulation order is ``_kernel``'s own, so per shard the result
    matches the all-gather executor's float32 sequence."""
    k_max = idx_s.shape[1]
    src = idx_s // m
    off = idx_s % m
    acc = None
    for l in range(k_max):
        local = src[:, l] == me
        v_local = jnp.take(x_s, off[:, l], axis=0)
        v_halo = jnp.take(halo, pos[src[:, l], off[:, l]], axis=0)
        v = jnp.where(local[:, None], v_local, v_halo).astype(jnp.float32)
        term = wgt_s[:, l].astype(jnp.float32)[:, None] * v
        acc = term if acc is None else acc + term
    return acc.astype(x_s.dtype)


def gossip_gather_halo(idx: jax.Array, wgt: jax.Array, X: jax.Array, *,
                       mesh, axis: str, plan):
    """Halo-exchange executor: the same mix under ``shard_map``, shipping
    only the remote rows each shard's receivers actually read (the
    ``repro.comm.plan.CommPlan``) instead of all-gathering the bank.

    Static plans (ring / exponential / exponential-cycle) run one
    ``ppermute`` per :class:`~repro.comm.plan.ShiftLeg` — exact O(k) rows
    per shard, zero index traffic.  Dynamic plans (sampled families) run a
    fixed-capacity request/response ``all_to_all`` pair: each shard
    scatters the rows it needs into a per-source bitmap, ships the padded
    request lists, serves the gathers, and ships the payload back; a
    dropped / churned / delayed-away edge has weight 0 and requests
    nothing.  Either way the per-shard accumulation is ``_kernel``'s
    slot-by-slot f32 order, so the result matches the all-gather executor
    per shard.
    """
    s, m = plan.n_shards, plan.m
    if s == 1 or mesh is None or axis not in mesh.axis_names:
        return gossip_gather_xla(idx, wgt, X)

    if plan.static:

        def body(idx_s, wgt_s, x_s):
            me = jax.lax.axis_index(axis)
            bufs = []
            # pos[(src shard, src-local offset)] -> halo row; the extra
            # column m absorbs nothing here (static offsets are exact).
            pos = jnp.zeros((s, m + 1), jnp.int32)
            base = 0
            for leg in plan.legs:
                offs = jnp.asarray(leg.offsets, jnp.int32)
                payload = jnp.take(x_s, offs, axis=0)
                bufs.append(jax.lax.ppermute(
                    payload, axis,
                    [(p, (p + leg.delta) % s) for p in range(s)],
                ))
                # The rows just received came from shard me - delta.
                pos = pos.at[(me - leg.delta) % s, offs].set(
                    base + jnp.arange(offs.shape[0], dtype=jnp.int32)
                )
                base += len(leg.offsets)
            halo = (jnp.concatenate(bufs, axis=0) if bufs
                    else jnp.zeros((1, x_s.shape[1]), x_s.dtype))
            return _halo_accumulate(idx_s, wgt_s, x_s, halo, pos, me, m)

    else:
        H = plan.capacity

        def body(idx_s, wgt_s, x_s):
            me = jax.lax.axis_index(axis)
            src = idx_s // m
            off = idx_s % m
            remote = (wgt_s != 0.0) & (src != me)
            # Which of each source shard's m rows do my receivers read?
            need = jnp.zeros((s, m), jnp.int32).at[src, off].add(
                remote.astype(jnp.int32)) > 0
            # Fixed-shape dedup: row p = the (padded) offsets I request
            # from shard p; the fill value m marks an unused request slot.
            req = jax.vmap(
                lambda row: jnp.nonzero(row, size=H, fill_value=m)[0]
            )(need).astype(jnp.int32)
            req_in = jax.lax.all_to_all(req, axis, 0, 0, tiled=True)
            payload = jnp.take(
                x_s, jnp.clip(req_in, 0, m - 1).reshape(-1), axis=0
            ).reshape(s, H, x_s.shape[1])
            halo = jax.lax.all_to_all(payload, axis, 0, 0, tiled=True)
            # Reverse map: fill-value writes land in the throwaway column
            # m, real offsets get their flat halo row s*H-indexed.
            pos = jnp.zeros((s, m + 1), jnp.int32).at[
                jnp.arange(s, dtype=jnp.int32)[:, None], req
            ].set(jnp.arange(s * H, dtype=jnp.int32).reshape(s, H))
            return _halo_accumulate(
                idx_s, wgt_s, x_s, halo.reshape(s * H, -1), pos, me, m
            )

    spec = PartitionSpec(axis)
    return shard_map(
        body, mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec,
        check_rep=False,
    )(idx, wgt, X)


@functools.partial(jax.jit, static_argnames=("panel",))
def gossip_gather_panels(
    idx: jax.Array, wgt: jax.Array, X: jax.Array, panel: int = 8192
):
    """CPU executor for the same kernel body: a ``fori_loop`` of
    ``(n, panel)`` column blocks, each run through ``run_single_block``.

    The whole-bank single-block form is the fast path when the gather
    reads a jit *parameter*, but composed after a producer (the local
    solver) XLA CPU materializes every per-slot gather into its own
    fresh (n, D) temp — measured ~5x slower than the gather's streaming
    floor on 2-core boxes, dominated by first-touch writes.  Blocking
    over D keeps every intermediate at ``(n, panel)`` (cache-resident,
    one reused buffer) and writes the output exactly once via in-place
    ``dynamic_update_slice``; per-element results are bitwise identical
    to the single-block form (the slot accumulation order is unchanged
    and D is not a reduction axis).  The final ragged panel is computed
    from the last ``panel`` columns — the overlap rewrites identical
    values — so no pad copy of ``X`` is ever made.
    """
    from repro.kernels.interpret import run_single_block

    n, D = X.shape
    wgt = wgt.astype(jnp.float32)

    def block(xp):
        return run_single_block(_kernel, [idx, wgt, xp], [X.dtype])

    if D <= panel:
        return block(X)

    def body(p, out):
        xp = jax.lax.dynamic_slice(X, (0, p * panel), (n, panel))
        return jax.lax.dynamic_update_slice(out, block(xp), (0, p * panel))

    out = jax.lax.fori_loop(0, D // panel, body, jnp.zeros_like(X))
    if D % panel:
        xp = jax.lax.dynamic_slice(X, (0, D - panel), (n, panel))
        out = jax.lax.dynamic_update_slice(out, block(xp), (0, D - panel))
    return out
