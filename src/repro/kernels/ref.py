"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["gossip_matmul_ref", "gossip_gather_ref", "fused_update_ref",
           "fused_update_bank_ref", "flash_attention_ref"]


def gossip_matmul_ref(P, X):
    return jnp.einsum(
        "ij,jd->id", P.astype(jnp.float32), X.astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST).astype(X.dtype)


def gossip_gather_ref(idx, wgt, X):
    """Sparse gossip oracle: Y[i] = sum_l wgt[i,l] * X[idx[i,l]] via one
    row gather + einsum (materializes the (n, k_max, D) gather — fine as
    ground truth, the kernel accumulates slot-by-slot instead)."""
    gathered = jnp.take(X, idx, axis=0).astype(jnp.float32)  # (n, k_max, D)
    return jnp.einsum(
        "nk,nkd->nd", wgt.astype(jnp.float32), gathered,
        precision=jax.lax.Precision.HIGHEST).astype(X.dtype)


def fused_update_ref(x, v, g, alpha, eta, w):
    v_new = jnp.float32(alpha) * v.astype(jnp.float32) + g.astype(jnp.float32)
    x_new = x.astype(jnp.float32) - jnp.float32(eta) * v_new
    z_new = x_new / jnp.float32(w)
    return x_new.astype(x.dtype), v_new, z_new.astype(x.dtype)


def fused_update_bank_ref(X, V, G, alpha, eta, w):
    """Row-banked fused update: (n, D) banks, per-client weight w (n,)."""
    v_new = jnp.float32(alpha) * V.astype(jnp.float32) + G.astype(jnp.float32)
    x_new = X.astype(jnp.float32) - jnp.float32(eta) * v_new
    z_new = x_new / w.astype(jnp.float32)[:, None]
    return x_new.astype(X.dtype), v_new, z_new.astype(X.dtype)


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0):
    """q: (B,H,S,hd), k/v: (B,KV,S,hd) -> (B,H,S,hd)."""
    b, h, s, hd = q.shape
    kv = k.shape[1]
    g = h // kv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (hd ** -0.5)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok = ki <= qi
    if window > 0:
        ok = ok & (qi - ki < window)
    scores = jnp.where(ok, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(q.dtype)
