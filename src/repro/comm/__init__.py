"""The communication-plan layer: one description of "which remote rows
does each consumer read" shared by the sharded halo-exchange mix, the
backend dispatch rule, and the store's fault-in closure planner."""
from repro.comm.plan import (
    CommPlan,
    HaloBackend,
    ShiftLeg,
    resolve_backend,
)

__all__ = ["CommPlan", "HaloBackend", "ShiftLeg", "resolve_backend"]
