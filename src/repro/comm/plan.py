"""`CommPlan`: which remote rows does each consumer shard read?

DFedSGPSM's gossip is row-sparse by construction — receiver i reads only
its ``k_in`` in-neighbors — and that one fact is consumed in three places
that used to hold three private copies of it:

  * the **sharded mix** needs, per device, the set of remote bank rows the
    shard's receivers gather (the halo a ``shard_map`` exchange ships in
    place of the full-bank all-gather);
  * the **backend dispatch rule** needs the per-family ``k_in`` to decide
    dense / sparse-kernel / xla-allgather / halo;
  * the **store's fault-in planner** needs the same in-neighbor sets to
    bound and build the paged round's closure ``active ∪ in_nbrs(active)``.

:class:`CommPlan` is the single host-side object all three derive from.
It is built once per ``(TopologyConfig, n_shards, mixer_kind)`` from the
shared in-degree table :func:`repro.core.topology.family_k_in` and is pure
static data (ints and tuples — hashable, jit-closure friendly).

Two transport shapes cover every family:

  * **static** (ring / exponential, incl. the time-varying cycle): the
    neighbor pattern is a global row shift, so the rows crossing each
    shard-pair are a fixed offset list, identical for every pair at the
    same shard distance — a :class:`ShiftLeg`.  The halo executor ships
    exactly those rows with one ``ppermute`` per leg: O(k) rows per shard
    per round, no index traffic at all.  The exponential *cycle* plan is
    the union of its per-hop legs (every hop's reads are covered by one
    static plan, so the traced round index never changes the transport).
  * **dynamic** (kout / selective / symmetric / two_tier): the edge set is
    sampled per round, so the executor ships a fixed-capacity
    request/response ``all_to_all`` pair — ``capacity`` rows per shard
    pair, sized so no sampled realization can overflow (per-pair distinct
    remote rows are at most the sender shard's ``m`` rows).  A dropped,
    delayed or churned edge has weight 0 and simply requests nothing —
    the plan shrinks with the operator.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import numpy as np

from repro.core import topology
from repro.core.topology import NeighborList, TopologyConfig, TwoTierOp

__all__ = ["CommPlan", "HaloBackend", "ShiftLeg", "resolve_backend"]


class ShiftLeg(NamedTuple):
    """One static halo transfer: every shard p sends its local rows at
    ``offsets`` to shard ``p + delta (mod n_shards)`` — the uniform
    shard-pair pattern of a shift-structured (ring / exponential)
    neighbor graph."""

    delta: int
    offsets: tuple  # sender-local row offsets, sorted


def _shift_legs(idx: np.ndarray, wgt: np.ndarray,
                n_shards: int) -> Optional[tuple]:
    """Extract the per-shard-distance legs of a concrete NeighborList, or
    ``None`` when the cross-shard pattern is not uniform over pairs at the
    same distance (then only the dynamic transport is exact)."""
    n, k = idx.shape
    m = n // n_shards
    per = [[set() for _ in range(n_shards)] for _ in range(n_shards)]
    for i in range(n):
        d = i // m
        for l in range(k):
            if wgt[i, l] == 0.0:
                continue
            j = int(idx[i, l])
            p = j // m
            if p != d:
                per[d][p].add(j % m)
    legs = []
    for delta in range(1, n_shards):
        sets = [per[d][(d - delta) % n_shards] for d in range(n_shards)]
        if all(not s for s in sets):
            continue
        if any(s != sets[0] for s in sets):
            return None
        legs.append(ShiftLeg(delta, tuple(sorted(sets[0]))))
    return tuple(legs)


def _merge_legs(leg_sets) -> tuple:
    """Union per-delta offset sets over several static plans (the
    exponential-cycle hops) into one covering plan."""
    union: dict[int, set] = {}
    for legs in leg_sets:
        for leg in legs:
            union.setdefault(leg.delta, set()).update(leg.offsets)
    return tuple(
        ShiftLeg(d, tuple(sorted(offs))) for d, offs in sorted(union.items())
    )


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """The communication plan (see module docstring).  All fields are
    static host data; ``legs`` is non-empty exactly when the family has a
    uniform shift structure and there is more than one shard."""

    topo: TopologyConfig
    mixer_kind: str
    n_shards: int
    m: int            # rows per shard
    k_in: int         # family_k_in — THE shared per-family in-degree
    k_max: int        # neighbor-list slot count, always k_in + 1
    static: bool      # True: exact ShiftLeg transport covers every round
    legs: tuple       # (ShiftLeg, ...) when static, else ()
    capacity: int     # per-pair row capacity of the dynamic transport

    @classmethod
    def build(cls, topo: TopologyConfig, n_shards: int = 1,
              mixer_kind: str = "directed") -> "CommPlan":
        n = topo.n_clients
        if n_shards < 1 or n % n_shards:
            raise ValueError(
                f"n_clients={n} must be divisible by n_shards={n_shards}"
            )
        m = n // n_shards
        k_in = topology.family_k_in(topo, mixer_kind)
        k_max = k_in + 1
        static_family = (
            mixer_kind != "symmetric"
            and topo.kind in ("ring", "exponential")
        )
        legs: tuple = ()
        if n_shards == 1:
            # Everything is shard-local: the empty static plan.
            return cls(topo, mixer_kind, 1, m, k_in, k_max, True, (), 0)
        if static_family:
            if topo.kind == "ring":
                nls = [topology.neighbors_ring(n)]
            elif topo.time_varying:
                hops = max(int(np.ceil(np.log2(max(n, 2)))), 1)
                nls = [topology.neighbors_exponential(n, t)
                       for t in range(hops)]
            else:
                nls = [topology.neighbors_exponential(n, 0)]
            per_hop = [
                _shift_legs(np.asarray(nl.idx), np.asarray(nl.wgt), n_shards)
                for nl in nls
            ]
            if all(lg is not None for lg in per_hop):
                legs = _merge_legs(per_hop)
                return cls(topo, mixer_kind, n_shards, m, k_in, k_max,
                           True, legs, 0)
        # Dynamic transport: per shard pair at most the sender's whole m
        # rows can be distinct requests, whatever the sampled realization.
        return cls(topo, mixer_kind, n_shards, m, k_in, k_max, False, (), m)

    # -- traffic accounting (per shard, per mixing application) -------------

    def halo_rows(self) -> int:
        """Remote bank rows received per shard per mix: the exact leg sizes
        on the static path, the fixed (n_shards-1) * capacity payload on
        the dynamic one (zero-padded slots included — physical traffic)."""
        if self.n_shards == 1:
            return 0
        if self.static:
            return sum(len(leg.offsets) for leg in self.legs)
        return (self.n_shards - 1) * self.capacity

    def request_ints(self) -> int:
        """int32 row-request words received per shard per mix (the dynamic
        transport's index traffic; the static plan ships none)."""
        if self.static or self.n_shards == 1:
            return 0
        return (self.n_shards - 1) * self.capacity

    def halo_bytes(self, d: int, itemsize: int = 4) -> int:
        """Bytes received per shard per mix on the halo path."""
        return self.halo_rows() * d * itemsize + self.request_ints() * 4

    def allgather_rows(self) -> int:
        """Remote rows received per shard by the full-bank all-gather the
        ``"xla"`` executor lowers to — the baseline the halo replaces."""
        return (self.n_shards - 1) * self.m

    def allgather_bytes(self, d: int, itemsize: int = 4) -> int:
        return self.allgather_rows() * d * itemsize

    # -- measured (realization-level) row sets -------------------------------

    def shard_remote_rows(self, nl: NeighborList, shard: int) -> np.ndarray:
        """Distinct remote global rows ``shard``'s receivers read under the
        concrete operator ``nl`` — the exact halo a zero-waste transport
        would ship (sorted; host numpy)."""
        idx = np.asarray(nl.idx)
        wgt = np.asarray(nl.wgt)
        lo, hi = shard * self.m, (shard + 1) * self.m
        rows = idx[lo:hi][wgt[lo:hi] != 0.0]
        return np.unique(rows[(rows < lo) | (rows >= hi)])

    def measured_rows(self, P) -> dict:
        """Mean/max distinct remote rows per shard under a concrete sampled
        operator (``NeighborList`` or ``TwoTierOp`` — only the inter list
        of the latter crosses shards when pods align with shards)."""
        nl = P.inter if isinstance(P, TwoTierOp) else P
        counts = [
            self.shard_remote_rows(nl, s).size for s in range(self.n_shards)
        ]
        return {
            "rows_mean": float(np.mean(counts)),
            "rows_max": int(np.max(counts)),
        }

    # -- the store-facing side: the fault-in closure -------------------------

    @property
    def pageable(self) -> bool:
        """Whether the family has an active-set (paged) form — the same
        restriction ``topology.active_k_in`` enforces."""
        return (
            self.mixer_kind == "directed"
            and self.topo.kind in ("ring", "exponential", "kout", "two_tier")
        )

    def closure_bound(self, k_active: int) -> int:
        """Static resident-row bound of a paged round's fault-in closure
        ``active ∪ in_neighbors(active)`` — ``k_in`` is this plan's shared
        table entry, the arithmetic lives in ``repro.store.paging``."""
        if not self.pageable:
            raise ValueError(
                f"topology kind {self.topo.kind!r} has no active-set "
                "(paged) form: the symmetric family needs consistent masks "
                "on both endpoints and the full graph faults in everything"
            )
        from repro.store import paging

        return paging.closure_bound(self.topo.n_clients, k_active, self.k_in)

    def in_neighbors(self, key, active, t: int = 0):
        """Global in-neighbor ids of the given active receivers for round
        ``t`` — the rows the pager faults in beyond the active set, drawn
        from the same per-family samplers the full-bank round uses
        (:func:`repro.core.topology.sample_active_picks`)."""
        return topology.sample_active_picks(key, active, self.topo, t=t)


class HaloBackend(NamedTuple):
    """The halo executor selection, threaded as the mixers' ``backend``
    (i.e. ``use_kernel``) down to ``kernels.gossip_gather.gossip_gather_halo``.
    Hashable static data: jit closures and frozen stage dataclasses carry
    it without tracing."""

    mesh: object       # jax.sharding.Mesh
    axis: str          # the bank-row mesh axis ("clients" / "pod")
    plan: CommPlan


def resolve_backend(gossip: str, sparse_mix: bool, topo: TopologyConfig,
                    mixer_kind: str, mesh=None, shard_axis: str = "clients"):
    """THE executor dispatch rule — dense / sparse-kernel / xla-allgather /
    halo — now mesh-aware.  Returns the mixers' ``backend`` value:

      * ``None``      — auto kernel selection (Pallas on TPU, size-gated
                        interpret kernels on CPU); only without a mesh.
      * ``"xla"``     — the whole-bank single-block traced-jnp executor;
                        under GSPMD it lowers to one full-bank all-gather.
      * ``HaloBackend`` — the ``shard_map`` halo exchange shipping only the
                        plan's rows.

    Without a mesh nothing is sharded: ``"xla"`` stays forceable (same
    math, no collective) and ``"halo"`` is rejected.  Under a mesh the
    dense representation and the explicit ``"xla"`` request keep the
    all-gather lowering; ``"halo"`` forces the halo executor for any
    family; ``"auto"`` / ``"sparse"`` select halo exactly when the plan is
    static (ring / exponential — the guaranteed O(k)-rows-per-shard win)
    and the all-gather otherwise.
    """
    if gossip not in ("auto", "sparse", "dense", "xla", "halo"):
        raise ValueError(
            f"gossip must be auto|sparse|dense|xla|halo, got {gossip!r}"
        )
    if mesh is None or shard_axis not in getattr(mesh, "axis_names", ()):
        if gossip == "halo":
            raise ValueError(
                "gossip='halo' is the sharded halo-exchange executor; it "
                "needs a mesh with the bank-row axis"
            )
        return "xla" if gossip == "xla" else None
    if not sparse_mix:
        return "xla"
    n_shards = mesh.shape[shard_axis]
    plan = CommPlan.build(topo, n_shards, mixer_kind)
    if gossip == "halo":
        return HaloBackend(mesh, shard_axis, plan)
    if gossip == "xla":
        return "xla"
    if plan.static and n_shards > 1:
        return HaloBackend(mesh, shard_axis, plan)
    return "xla"
