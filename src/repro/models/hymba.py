"""Hymba (arXiv:2411.13676): hybrid-head layers running attention heads and
Mamba-style SSM heads *in parallel* on the same input, plus learnable meta
tokens prepended to the sequence and mostly-sliding-window attention.

Per layer: y = 0.5 * (rmsnorm(attn(x)) + rmsnorm(ssm(x))), then SwiGLU MLP.
Decode state: rolling KV cache (full-length for the few global layers) +
O(1) SSM state — sub-quadratic long-context decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.layers import rms_norm, shard_act, softmax_xent
from repro.models.moe import swiglu_defs, swiglu_forward
from repro.models.pdefs import PDef
from repro.models.transformer import _layer_meta

__all__ = ["param_defs", "cache_defs", "forward", "loss", "decode_step"]


def _di(cfg: ArchConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def _ssm_defs(cfg: ArchConfig, stacked: tuple) -> dict:
    d, di, n = cfg.d_model, _di(cfg), cfg.ssm_state
    L, Lax = stacked, ("layers",) * len(stacked)
    dt = cfg.dtype
    return {
        "w_in": PDef(L + (d, 2 * di), Lax + ("embed", "ssm_inner"), dt, fan_in=d),
        "w_dt": PDef(L + (di, di), Lax + ("ssm_inner", None), dt, fan_in=di),
        "b_dt": PDef(L + (di,), Lax + (None,), jnp.float32, "zeros"),
        "A_log": PDef(L + (di,), Lax + ("ssm_inner",), jnp.float32, "zeros"),
        "w_B": PDef(L + (di, n), Lax + ("ssm_inner", None), dt, fan_in=di),
        "w_C": PDef(L + (di, n), Lax + ("ssm_inner", None), dt, fan_in=di),
        "D": PDef(L + (di,), Lax + ("ssm_inner",), jnp.float32, "ones"),
        "w_out": PDef(L + (di, d), Lax + ("ssm_inner", "embed"), dt, fan_in=di),
    }


def param_defs(cfg: ArchConfig) -> dict:
    L, d, v = (cfg.n_layers,), cfg.d_model, cfg.padded_vocab
    layers = {
        "attn": attn.gqa_defs(cfg, stacked=L),
        "ssm": _ssm_defs(cfg, L),
        "ln1": PDef(L + (d,), ("layers", None), jnp.float32, "zeros"),
        "ln2": PDef(L + (d,), ("layers", None), jnp.float32, "zeros"),
        "norm_attn": PDef(L + (d,), ("layers", None), jnp.float32, "zeros"),
        "norm_ssm": PDef(L + (d,), ("layers", None), jnp.float32, "zeros"),
        "mlp": swiglu_defs(cfg, stacked=L),
    }
    return {
        "layers": layers,
        "meta_tokens": PDef((cfg.n_meta_tokens, d), (None, "embed"), cfg.dtype, fan_in=d),
        "embed": PDef((v, d), ("vocab", "embed"), cfg.dtype, fan_in=d),
        "lm_head": PDef((d, v), ("embed", "vocab"), cfg.dtype, fan_in=d),
        "final_norm": PDef((d,), (None,), jnp.float32, "zeros"),
    }


def cache_defs(cfg: ArchConfig, batch: int, length: int) -> dict:
    """KV cache covers meta tokens + sequence; SSM state is O(1)."""
    di, n = _di(cfg), cfg.ssm_state
    kv = attn.gqa_cache_defs(cfg, batch, length + cfg.n_meta_tokens,
                             stacked=(cfg.n_layers,))
    kv["ssm_h"] = PDef((cfg.n_layers, batch, di, n),
                       ("layers", "batch", "ssm_inner", None), jnp.float32, "zeros")
    return kv


# ---------------------------------------------------------------------------
# SSM branch (diagonal selective state space, S6-style).
# ---------------------------------------------------------------------------

def _ssm_proj(pl, xn, cfg):
    di = _di(cfg)
    up = jnp.einsum("bsd,de->bse", xn, pl["w_in"])
    xm, z = up[..., :di], up[..., di:]
    dt = jax.nn.softplus(
        jnp.einsum("bse,ef->bsf", xm.astype(jnp.float32), pl["w_dt"].astype(jnp.float32))
        + pl["b_dt"])
    A = -jnp.exp(pl["A_log"])  # (di,) negative
    decay = jnp.exp(dt * A)  # (B,S,di)
    Bm = jnp.einsum("bse,en->bsn", xm.astype(jnp.float32), pl["w_B"].astype(jnp.float32))
    Cm = jnp.einsum("bse,en->bsn", xm.astype(jnp.float32), pl["w_C"].astype(jnp.float32))
    u = dt * xm.astype(jnp.float32)
    return xm, z, decay, Bm, Cm, u


def _ssm_scan(pl, xn, cfg, state=None):
    """state: (B,di,N) or None.  Returns (y (B,S,d), new_state)."""
    xm, z, decay, Bm, Cm, u = _ssm_proj(pl, xn, cfg)
    contrib = u[..., None] * Bm[:, :, None, :]  # (B,S,di,N)
    if state is None:
        a = jnp.broadcast_to(decay[..., None], contrib.shape)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        _, h = jax.lax.associative_scan(combine, (a, contrib), axis=1)
        new_state = h[:, -1]  # final state (prefill -> decode handoff)
    else:
        h = (decay[:, 0, :, None] * state + contrib[:, 0])[:, None]  # (B,1,di,N)
        new_state = h[:, 0]
    y = jnp.einsum("bsen,bsn->bse", h, Cm) + pl["D"] * xm.astype(jnp.float32)
    y = (y.astype(cfg.dtype) * jax.nn.silu(z))
    return jnp.einsum("bse,ed->bsd", y, pl["w_out"]), new_state


# ---------------------------------------------------------------------------
# Hybrid layer + stack.
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg):
    x = jnp.take(params["embed"], tokens, axis=0)
    return x * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)


def forward(params, batch, cfg: ArchConfig):
    tokens = batch["tokens"]
    b = tokens.shape[0]
    x = _embed(params, tokens, cfg)
    meta = jnp.broadcast_to(params["meta_tokens"][None],
                            (b,) + params["meta_tokens"].shape)
    x = jnp.concatenate([meta, x], axis=1)
    x = shard_act(x, ("batch", "seq", "embed"))
    s = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    windows, thetas = _layer_meta(cfg)

    def body(carry, inp):
        pl, win, th = inp
        xn = rms_norm(carry, pl["ln1"], cfg.norm_eps)
        a = attn.gqa_forward(pl["attn"], xn, cfg, window=win, theta=th,
                             positions=positions)
        s_out, _ = _ssm_scan(pl["ssm"], xn, cfg)
        mix = 0.5 * (rms_norm(a, pl["norm_attn"], cfg.norm_eps)
                     + rms_norm(s_out, pl["norm_ssm"], cfg.norm_eps))
        x1 = carry + shard_act(mix, ("batch", "seq", "embed"))
        h2 = rms_norm(x1, pl["ln2"], cfg.norm_eps)
        return x1 + swiglu_forward(pl["mlp"], h2), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, (params["layers"], windows, thetas),
                        unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x[:, cfg.n_meta_tokens:], params["lm_head"])
    return shard_act(logits, ("batch", "seq", "vocab")), {}


def loss(params, batch, cfg: ArchConfig):
    logits, _ = forward(params, batch, cfg)
    ce, acc = softmax_xent(logits[:, :-1], batch["tokens"][:, 1:])
    return ce, (ce, acc)


def prefill(params, batch, cfg: ArchConfig, cache_len: int):
    """Parallel prefill over [meta tokens + prompt]: returns (logits, cache)
    with KV padded to n_meta + cache_len and the final SSM state."""
    tokens = batch["tokens"]
    b = tokens.shape[0]
    x = _embed(params, tokens, cfg)
    meta = jnp.broadcast_to(params["meta_tokens"][None],
                            (b,) + params["meta_tokens"].shape)
    x = jnp.concatenate([meta, x], axis=1)
    s = x.shape[1]
    total = cfg.n_meta_tokens + cache_len
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    windows, thetas = _layer_meta(cfg)

    def pad(t):
        full = jnp.zeros((t.shape[0], total) + t.shape[2:], t.dtype)
        return jax.lax.dynamic_update_slice_in_dim(full, t, 0, 1)

    def body(carry, inp):
        pl, win, th = inp
        xn = rms_norm(carry, pl["ln1"], cfg.norm_eps)
        a, (k, v) = attn.gqa_forward(pl["attn"], xn, cfg, window=win, theta=th,
                                     positions=positions, return_kv=True)
        s_out, h_final = _ssm_scan(pl["ssm"], xn, cfg)
        mix = 0.5 * (rms_norm(a, pl["norm_attn"], cfg.norm_eps)
                     + rms_norm(s_out, pl["norm_ssm"], cfg.norm_eps))
        x1 = carry + mix
        h2 = rms_norm(x1, pl["ln2"], cfg.norm_eps)
        return x1 + swiglu_forward(pl["mlp"], h2), {"k": pad(k), "v": pad(v),
                                                    "ssm_h": h_final}

    x, cache = jax.lax.scan(body, x, (params["layers"], windows, thetas),
                            unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x[:, cfg.n_meta_tokens:], params["lm_head"])
    return logits, cache


def decode_step(params, cache, tokens, pos, cfg: ArchConfig):
    """pos is the position in the *token* stream; the KV cache additionally
    holds the meta-token prefix at its head."""
    x = _embed(params, tokens[:, None], cfg)
    windows, thetas = _layer_meta(cfg)
    cache_pos = pos + cfg.n_meta_tokens

    def body(carry, inp):
        pl, kv, win, th = inp
        xn = rms_norm(carry, pl["ln1"], cfg.norm_eps)
        a, new_kv = attn.gqa_decode(pl["attn"], xn, {"k": kv["k"], "v": kv["v"]},
                                    cfg, cache_pos, window=win, theta=th)
        s_out, new_h = _ssm_scan(pl["ssm"], xn, cfg, state=kv["ssm_h"])
        mix = 0.5 * (rms_norm(a, pl["norm_attn"], cfg.norm_eps)
                     + rms_norm(s_out, pl["norm_ssm"], cfg.norm_eps))
        x1 = carry + mix
        h2 = rms_norm(x1, pl["ln2"], cfg.norm_eps)
        new_kv = {"k": new_kv["k"], "v": new_kv["v"], "ssm_h": new_h}
        return x1 + swiglu_forward(pl["mlp"], h2), new_kv

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache, windows, thetas),
                                unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    return logits, new_cache
