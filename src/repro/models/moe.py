"""Mixture-of-Experts MLP: exact dense reference + GShard-style capacity
dispatch (GSPMD-friendly einsum formulation for the dry-run mesh).

Covers DBRX (softmax top-4 of 16) and DeepSeek-V3 (sigmoid gating with
normalized top-8 of 256 + 1 shared expert).  Aux load-balance loss follows
Switch/GShard: E * sum_e(frac_tokens_e * mean_prob_e).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import shard_act
from repro.models.pdefs import PDef

__all__ = ["moe_defs", "moe_forward", "swiglu_defs", "swiglu_forward"]


def swiglu_defs(cfg: ArchConfig, stacked: tuple = (), d_ff: int = 0) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    L, Lax = (stacked, ("layers",) * len(stacked)) if stacked else ((), ())
    dt = cfg.dtype
    defs = {
        "wi": PDef(L + (d, f), Lax + ("embed", "mlp"), dt, fan_in=d),
        "wo": PDef(L + (f, d), Lax + ("mlp", "embed"), dt, fan_in=f),
    }
    if cfg.mlp_act == "swiglu":
        defs["wg"] = PDef(L + (d, f), Lax + ("embed", "mlp"), dt, fan_in=d)
    return defs


def swiglu_forward(p, x):
    if "wg" in p:
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wi"]))
        h = h * jnp.einsum("bsd,df->bsf", x, p["wg"])
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wi"]))
    h = shard_act(h, ("batch", "seq", "mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


def moe_defs(cfg: ArchConfig, stacked: tuple = ()) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    L, Lax = (stacked, ("layers",) * len(stacked)) if stacked else ((), ())
    dt = cfg.dtype
    defs = {
        "router": PDef(L + (d, e), Lax + ("embed", None), jnp.float32, fan_in=d),
        "wi": PDef(L + (e, d, f), Lax + ("expert", "embed", "mlp"), dt, fan_in=d),
        "wg": PDef(L + (e, d, f), Lax + ("expert", "embed", "mlp"), dt, fan_in=d),
        "wo": PDef(L + (e, f, d), Lax + ("expert", "mlp", "embed"), dt, fan_in=f),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        defs["shared"] = swiglu_defs(cfg, stacked, d_ff=fs)
    return defs


def _router_probs(p, x, cfg: ArchConfig):
    """Returns (weights (B,S,k), sel (B,S,k), probs (B,S,E))."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    if cfg.n_shared_experts:  # deepseek: sigmoid gating, normalized top-k
        probs = jax.nn.sigmoid(logits)
        w, sel = jax.lax.top_k(probs, cfg.top_k)
        w = w / (w.sum(-1, keepdims=True) + 1e-9)
    else:  # dbrx: softmax over experts, renormalized top-k
        probs = jax.nn.softmax(logits, axis=-1)
        w, sel = jax.lax.top_k(probs, cfg.top_k)
        w = w / (w.sum(-1, keepdims=True) + 1e-9)
    return w, sel, probs


def _aux_loss(sel, probs, cfg: ArchConfig):
    e = cfg.n_experts
    frac = jnp.mean(jax.nn.one_hot(sel, e, dtype=jnp.float32), axis=(0, 1, 2))
    imp = probs.mean(axis=(0, 1))
    return e * jnp.sum(frac * imp)


def _moe_dense(p, x, w, sel, cfg: ArchConfig):
    """Exact reference: every expert on every token, mask-combined."""
    e = cfg.n_experts
    gates = jnp.zeros(x.shape[:2] + (e,), jnp.float32)
    gates = jnp.sum(jax.nn.one_hot(sel, e, dtype=jnp.float32) * w[..., None], axis=2)
    h = jnp.einsum("bsd,edf->bsef", x, p["wi"])
    g = jnp.einsum("bsd,edf->bsef", x, p["wg"])
    h = jax.nn.silu(h) * g
    out = jnp.einsum("bsef,efd->bsed", h, p["wo"])
    return jnp.einsum("bsed,bse->bsd", out.astype(jnp.float32), gates).astype(x.dtype)


def _positions_cumsum(sel, b, s, k, e):
    """One-hot cumsum over the (B, S*k, E) flat assignment tensor.  Simple,
    but materializes O(T*E) f32 — the memory hot spot at deepseek scale."""
    sel_oh = jax.nn.one_hot(sel, e, dtype=jnp.float32)  # (B,S,k,E)
    flat = sel_oh.reshape(b, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat  # exclusive cumsum
    pos = pos.reshape(b, s, k, e)
    return jnp.sum(pos * sel_oh, axis=-1).astype(jnp.int32)  # (B,S,k)


def _positions_sort(sel, b, s, k, e):
    """O(T) position-in-expert: stable argsort groups assignments by expert
    while preserving arrival order, so rank-within-group == cumsum position.
    Avoids the (B, T, E) blow-up entirely."""
    t = s * k
    flat_e = sel.reshape(b, t)
    rows = jnp.arange(b)[:, None]
    counts = jnp.zeros((b, e), jnp.int32).at[rows, flat_e].add(1)
    starts = jnp.cumsum(counts, axis=1) - counts  # exclusive, (B,E)
    order = jnp.argsort(flat_e, axis=1, stable=True)  # (B,T)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    pos_sorted = jnp.arange(t)[None, :] - jnp.take_along_axis(starts, sorted_e, axis=1)
    pos = jnp.zeros((b, t), jnp.int32).at[rows, order].set(pos_sorted.astype(jnp.int32))
    return pos.reshape(b, s, k)


def _moe_gshard(p, x, w, sel, cfg: ArchConfig):
    """Capacity-based dispatch/combine einsums (sharded: expert -> "model")."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    capacity = max(int(s * k / e * cfg.capacity_factor), k)

    pos_fn = _positions_sort if cfg.moe_pos == "sort" else _positions_cumsum
    pos_in_e = pos_fn(sel, b, s, k, e)
    ddt = jnp.bfloat16 if cfg.moe_dispatch_dtype == "bf16" else jnp.float32
    sel_oh = jax.nn.one_hot(sel, e, dtype=ddt)  # (B,S,k,E)
    keep = (pos_in_e < capacity).astype(ddt)
    pos_oh = jax.nn.one_hot(pos_in_e, capacity, dtype=ddt)  # (B,S,k,C)
    dispatch = jnp.einsum("bske,bskc->bsec", sel_oh * keep[..., None], pos_oh)
    combine = jnp.einsum("bske,bskc,bsk->bsec", sel_oh * keep[..., None],
                         pos_oh, w.astype(ddt))
    # dispatch/combine are the largest MoE temporaries (B,S,E,C); shard the
    # expert dim over "model" alongside the expert weights.
    dispatch = shard_act(dispatch, ("batch", None, "expert", None))
    combine = shard_act(combine, ("batch", None, "expert", None))

    xin = jnp.einsum("bsec,bsd->becd", dispatch.astype(x.dtype), x)
    xin = shard_act(xin, ("batch", "expert", None, None))
    h = jnp.einsum("becd,edf->becf", xin, p["wi"])
    g = jnp.einsum("becd,edf->becf", xin, p["wg"])
    h = jax.nn.silu(h) * g
    out = jnp.einsum("becf,efd->becd", h, p["wo"])
    out = shard_act(out, ("batch", "expert", None, None))
    return jnp.einsum("bsec,becd->bsd", combine.astype(x.dtype), out)


def moe_forward(p, x, cfg: ArchConfig):
    """Returns (y, aux_loss)."""
    w, sel, probs = _router_probs(p, x, cfg)
    impl = _moe_dense if cfg.moe_impl == "dense" else _moe_gshard
    y = impl(p, x, w, sel, cfg)
    if cfg.n_shared_experts:
        y = y + swiglu_forward(p["shared"], x)
    return y, _aux_loss(sel, probs, cfg)
