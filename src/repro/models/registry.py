"""Uniform model API: block_kind -> (param_defs, cache_defs, forward, loss,
decode_step).  Everything downstream (FL engine, pod runtime, dry-run,
benchmarks) goes through this."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax

from repro.configs.base import ArchConfig
from repro.models import hymba, transformer, xlstm
from repro.models.pdefs import abstract_tree, init_tree, tree_num_params

__all__ = ["ModelApi", "get_model_api"]


class ModelApi(NamedTuple):
    cfg: ArchConfig
    param_defs: Callable
    cache_defs: Callable
    forward: Callable
    loss: Callable
    decode_step: Callable
    prefill: Callable

    # -- conveniences -------------------------------------------------------
    def init(self, key: jax.Array):
        return init_tree(key, self.param_defs(self.cfg))

    def abstract_params(self, sharding_fn=None):
        return abstract_tree(self.param_defs(self.cfg), sharding_fn)

    def init_cache(self, key, batch: int, length: int):
        return init_tree(key, self.cache_defs(batch, length))

    def abstract_cache(self, batch: int, length: int, sharding_fn=None):
        return abstract_tree(self.cache_defs(batch, length), sharding_fn)

    def num_params(self) -> int:
        return tree_num_params(self.param_defs(self.cfg))


_MODULES = {
    "transformer": transformer,
    "xlstm": xlstm,
    "hymba": hymba,
}


def get_model_api(cfg: ArchConfig) -> ModelApi:
    mod = _MODULES[cfg.block_kind]

    def loss(params, batch):
        return mod.loss(params, batch, cfg)

    def forward(params, batch):
        return mod.forward(params, batch, cfg)

    def decode_step(params, cache, tokens, pos):
        return mod.decode_step(params, cache, tokens, pos, cfg)

    def prefill(params, batch, cache_len: int):
        return mod.prefill(params, batch, cfg, cache_len)

    return ModelApi(
        cfg=cfg,
        param_defs=lambda c=cfg: mod.param_defs(c),
        cache_defs=lambda batch, length, c=cfg: mod.cache_defs(c, batch, length),
        forward=forward,
        loss=loss,
        decode_step=decode_step,
        prefill=prefill,
    )
