"""Config-driven transformer stack (decoder / encoder / VLM / audio-masked-LM).

Layers are *stacked* on a leading axis and driven by ``lax.scan`` so a
61-layer model compiles one layer body; per-layer heterogeneity (gemma3's
5:1 sliding-window pattern, dual rope thetas) rides along as scanned arrays.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models.layers import rms_norm, shard_act, sinusoidal_positions, softmax_xent
from repro.models.pdefs import PDef

__all__ = [
    "param_defs",
    "cache_defs",
    "forward",
    "loss",
    "decode_step",
]


def _layer_meta(cfg: ArchConfig):
    windows = jnp.asarray(
        [cfg.window_for_layer(i) for i in range(cfg.n_layers)], jnp.int32
    )
    if cfg.global_rope_theta:
        thetas = jnp.asarray(
            [
                cfg.global_rope_theta if cfg.window_for_layer(i) == 0 else cfg.rope_theta
                for i in range(cfg.n_layers)
            ],
            jnp.float32,
        )
    else:
        thetas = jnp.full((cfg.n_layers,), cfg.rope_theta, jnp.float32)
    return windows, thetas


# ---------------------------------------------------------------------------
# Parameter / cache declarations.
# ---------------------------------------------------------------------------

def param_defs(cfg: ArchConfig) -> dict:
    L, d, v = (cfg.n_layers,), cfg.d_model, cfg.padded_vocab
    attn_defs = (
        attn.mla_defs(cfg, stacked=L)
        if cfg.attn_type == "mla"
        else attn.gqa_defs(cfg, stacked=L)
    )
    mlp_defs = (
        moe_lib.moe_defs(cfg, stacked=L)
        if cfg.n_experts
        else moe_lib.swiglu_defs(cfg, stacked=L)
    )
    layers = {
        "attn": attn_defs,
        "mlp": mlp_defs,
        "ln1": PDef(L + (d,), ("layers", None), jnp.float32, "zeros"),
        "ln2": PDef(L + (d,), ("layers", None), jnp.float32, "zeros"),
    }
    defs = {
        "layers": layers,
        "final_norm": PDef((d,), (None,), jnp.float32, "zeros"),
    }
    if cfg.task in ("lm", "vlm"):
        defs["embed"] = PDef((v, d), ("vocab", "embed"), cfg.dtype, fan_in=d)
        if not cfg.tie_embeddings:
            defs["lm_head"] = PDef((d, v), ("embed", "vocab"), cfg.dtype, fan_in=d)
    if cfg.task == "vlm":
        fd = cfg.frontend_dim
        defs["projector"] = {
            "w1": PDef((fd, d), ("frontend", "embed"), cfg.dtype, fan_in=fd),
            "w2": PDef((d, d), ("embed", "mlp"), cfg.dtype, fan_in=d),
        }
    if cfg.task == "masked_lm":
        fd = cfg.frontend_dim
        defs["in_proj"] = PDef((fd, d), ("frontend", "embed"), cfg.dtype, fan_in=fd)
        defs["mask_emb"] = PDef((d,), (None,), cfg.dtype)
        defs["lm_head"] = PDef((d, v), ("embed", "vocab"), cfg.dtype, fan_in=d)
    return defs


def cache_defs(cfg: ArchConfig, batch: int, length: int) -> dict:
    L = (cfg.n_layers,)
    if cfg.attn_type == "mla":
        return attn.mla_cache_defs(cfg, batch, length, stacked=L)
    return attn.gqa_cache_defs(cfg, batch, length, stacked=L)


# ---------------------------------------------------------------------------
# Embedding frontends per task.
# ---------------------------------------------------------------------------

def _embed_tokens(params, tokens, cfg: ArchConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    return x * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)


def embed_inputs(params, batch, cfg: ArchConfig):
    """Returns (x, loss_mask).  Batch layouts:
      lm:        {"tokens": (B,S)} — next-token LM on all positions.
      vlm:       {"tokens": (B,St), "image_feats": (B,Ni,Fd)} — image prefix.
      masked_lm: {"features": (B,S,Fd), "mask": (B,S), "targets": (B,S)}.
    """
    if cfg.task == "lm":
        x = _embed_tokens(params, batch["tokens"], cfg)
        mask = jnp.ones(batch["tokens"].shape, jnp.float32)
    elif cfg.task == "vlm":
        img = jnp.einsum("bnf,fd->bnd", batch["image_feats"].astype(cfg.dtype),
                         params["projector"]["w1"])
        img = jnp.einsum("bnd,de->bne", jax.nn.gelu(img), params["projector"]["w2"])
        txt = _embed_tokens(params, batch["tokens"], cfg)
        x = jnp.concatenate([img, txt], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros(img.shape[:2], jnp.float32),
             jnp.ones(batch["tokens"].shape, jnp.float32)], axis=1)
    elif cfg.task == "masked_lm":
        x = jnp.einsum("bsf,fd->bsd", batch["features"].astype(cfg.dtype),
                       params["in_proj"])
        m = batch["mask"].astype(cfg.dtype)[..., None]
        x = x * (1 - m) + params["mask_emb"] * m
        pos = sinusoidal_positions(jnp.arange(x.shape[1]), cfg.d_model)
        x = x + pos[None].astype(cfg.dtype)
        mask = batch["mask"].astype(jnp.float32)
    else:
        raise ValueError(cfg.task)
    return shard_act(x, ("batch", "seq", "embed")), mask


# ---------------------------------------------------------------------------
# Layer body + stack.
# ---------------------------------------------------------------------------

def _block(pl, x, cfg: ArchConfig, window, theta, positions):
    fwd = attn.mla_forward if cfg.attn_type == "mla" else attn.gqa_forward
    h = fwd(pl["attn"], rms_norm(x, pl["ln1"], cfg.norm_eps), cfg,
            window=window, theta=theta, positions=positions)
    x = x + shard_act(h, ("batch", "seq", "embed"))
    h2 = rms_norm(x, pl["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        y, aux = moe_lib.moe_forward(pl["mlp"], h2, cfg)
    else:
        y, aux = moe_lib.swiglu_forward(pl["mlp"], h2), jnp.float32(0.0)
    return x + shard_act(y, ("batch", "seq", "embed")), aux


def forward(params, batch, cfg: ArchConfig):
    """Full-sequence forward -> (logits, aux).  Used by train & prefill."""
    x, mask = embed_inputs(params, batch, cfg)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    windows, thetas = _layer_meta(cfg)

    def body(carry, inp):
        pl, win, th = inp
        y, aux = _block(pl, carry, cfg, win, th, positions)
        return y, aux

    if cfg.remat:
        body = jax.checkpoint(body)
    x, auxs = jax.lax.scan(body, x, (params["layers"], windows, thetas),
                           unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = shard_act(logits, ("batch", "seq", "vocab"))
    return logits, {"moe_aux": auxs.mean(), "loss_mask": mask}


def loss(params, batch, cfg: ArchConfig):
    """Task-appropriate loss -> (scalar, metrics). The FL/pod train target."""
    logits, aux = forward(params, batch, cfg)
    if cfg.task == "masked_lm":
        ce, acc = softmax_xent(logits, batch["targets"], aux["loss_mask"])
    else:
        labels = batch["tokens"]
        n_prefix = logits.shape[1] - labels.shape[1]  # image tokens (vlm)
        lg = logits[:, n_prefix:-1] if labels.shape[1] > 1 else logits[:, n_prefix:]
        ce, acc = softmax_xent(lg, labels[:, 1:], None)
    total = ce + cfg.router_aux_coef * aux["moe_aux"]
    return total, (ce, acc)


def prefill(params, batch, cfg: ArchConfig, cache_len: int):
    """Full-sequence forward that also materializes the KV cache (padded to
    ``cache_len``) -> (logits, cache).  Feeds decode_step for serving."""
    x, _ = embed_inputs(params, batch, cfg)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    windows, thetas = _layer_meta(cfg)
    fwd = attn.mla_forward if cfg.attn_type == "mla" else attn.gqa_forward
    names = ("ckv", "kpe") if cfg.attn_type == "mla" else ("k", "v")

    def pad(t):
        full = jnp.zeros((t.shape[0], cache_len) + t.shape[2:], t.dtype)
        return jax.lax.dynamic_update_slice_in_dim(full, t, 0, 1)

    def body(carry, inp):
        pl, win, th = inp
        h, kv = fwd(pl["attn"], rms_norm(carry, pl["ln1"], cfg.norm_eps), cfg,
                    window=win, theta=th, positions=positions, return_kv=True)
        x1 = carry + h
        h2 = rms_norm(x1, pl["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            y, _ = moe_lib.moe_forward(pl["mlp"], h2, cfg)
        else:
            y = moe_lib.swiglu_forward(pl["mlp"], h2)
        return x1 + y, tuple(pad(t) for t in kv)

    x, kvs = jax.lax.scan(body, x, (params["layers"], windows, thetas),
                          unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits, dict(zip(names, kvs))


def decode_step(params, cache, tokens, pos, cfg: ArchConfig):
    """One-token decode: tokens (B,), cache from cache_defs -> (logits, cache)."""
    x = _embed_tokens(params, tokens[:, None], cfg)
    x = shard_act(x, ("batch", None, "embed"))
    windows, thetas = _layer_meta(cfg)
    dec = attn.mla_decode if cfg.attn_type == "mla" else attn.gqa_decode

    def body(carry, inp):
        pl, cache_l, win, th = inp
        h = rms_norm(carry, pl["ln1"], cfg.norm_eps)
        h, new_c = dec(pl["attn"], h, cache_l, cfg, pos, window=win, theta=th)
        x1 = carry + h
        h2 = rms_norm(x1, pl["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            y, _ = moe_lib.moe_forward(pl["mlp"], h2, cfg)
        else:
            y = moe_lib.swiglu_forward(pl["mlp"], h2)
        return x1 + y, new_c

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache, windows, thetas),
                                unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0]
    return logits, new_cache
