"""xLSTM (arXiv:2405.04517): mLSTM (matrix memory, parallel/quadratic train
form + O(1) recurrent decode) and sLSTM (scalar memory, sequential scan with
block-diagonal recurrent gate connections).

Layer pattern: every ``cfg.slstm_every``-th layer is sLSTM, the rest mLSTM
(e.g. 24 layers, slstm_every=6 -> 4 groups of [5x mLSTM, 1x sLSTM]).  The
stack scans over *groups* so the compiled body stays small while preserving
the interleave.  Exponential gating uses the m-stabilizer from the paper; the
parallel and recurrent forms are verified equivalent in tests.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.layers import rms_norm, shard_act, softmax_xent
from repro.models.pdefs import PDef

__all__ = ["param_defs", "cache_defs", "forward", "loss", "decode_step"]

_NEG = -2.0e38


def _dims(cfg: ArchConfig):
    d = cfg.d_model
    di = 2 * d  # mLSTM projection factor 2 (paper)
    h = cfg.n_heads
    return d, di, h, di // h


def _groups(cfg: ArchConfig):
    if not cfg.slstm_every:
        return cfg.n_layers, 1, 0  # (n_m per group, groups, n_s)
    p = cfg.slstm_every
    assert cfg.n_layers % p == 0, "n_layers must divide by slstm_every"
    return p - 1, cfg.n_layers // p, 1


# ---------------------------------------------------------------------------
# Parameter definitions.
# ---------------------------------------------------------------------------

def _mlstm_defs(cfg: ArchConfig, stacked: tuple) -> dict:
    d, di, h, hd = _dims(cfg)
    L, Lax = stacked, ("layers",) * len(stacked)
    dt = cfg.dtype
    return {
        "ln": PDef(L + (d,), Lax + (None,), jnp.float32, "zeros"),
        "w_up": PDef(L + (d, 2 * di), Lax + ("embed", "mlp"), dt, fan_in=d),
        "wq": PDef(L + (di, di), Lax + ("ssm_inner", "mlp"), dt, fan_in=di),
        "wk": PDef(L + (di, di), Lax + ("ssm_inner", "mlp"), dt, fan_in=di),
        "wv": PDef(L + (di, di), Lax + ("ssm_inner", "mlp"), dt, fan_in=di),
        "w_if": PDef(L + (di, 2 * h), Lax + ("ssm_inner", None), jnp.float32, fan_in=di),
        "b_if": PDef(L + (2 * h,), Lax + (None,), jnp.float32, "zeros"),
        "out_norm": PDef(L + (hd,), Lax + (None,), jnp.float32, "zeros"),
        "w_down": PDef(L + (di, d), Lax + ("mlp", "embed"), dt, fan_in=di),
    }


def _slstm_defs(cfg: ArchConfig, stacked: tuple) -> dict:
    d, _, h, _ = _dims(cfg)
    hd = d // h
    f = int(math.ceil(4 * d / 3 / 128) * 128)  # post-FFN (pf 4/3)
    L, Lax = stacked, ("layers",) * len(stacked)
    dt = cfg.dtype
    return {
        "ln": PDef(L + (d,), Lax + (None,), jnp.float32, "zeros"),
        "wx": PDef(L + (d, 4 * d), Lax + ("embed", "mlp"), dt, fan_in=d),
        "r": PDef(L + (h, hd, 4 * hd), Lax + ("heads", None, None), dt, fan_in=hd),
        "b": PDef(L + (4 * d,), Lax + (None,), jnp.float32, "zeros"),
        "out_norm": PDef(L + (hd,), Lax + (None,), jnp.float32, "zeros"),
        "ln_ffn": PDef(L + (d,), Lax + (None,), jnp.float32, "zeros"),
        "ffn_wi": PDef(L + (d, f), Lax + ("embed", "mlp"), dt, fan_in=d),
        "ffn_wg": PDef(L + (d, f), Lax + ("embed", "mlp"), dt, fan_in=d),
        "ffn_wo": PDef(L + (f, d), Lax + ("mlp", "embed"), dt, fan_in=f),
    }


def param_defs(cfg: ArchConfig) -> dict:
    d, v = cfg.d_model, cfg.padded_vocab
    n_m, g, n_s = _groups(cfg)
    defs = {
        "mlstm": _mlstm_defs(cfg, (g, n_m)),
        "final_norm": PDef((d,), (None,), jnp.float32, "zeros"),
        "embed": PDef((v, d), ("vocab", "embed"), cfg.dtype, fan_in=d),
        "lm_head": PDef((d, v), ("embed", "vocab"), cfg.dtype, fan_in=d),
    }
    if n_s:
        defs["slstm"] = _slstm_defs(cfg, (g, n_s))
    return defs


def cache_defs(cfg: ArchConfig, batch: int, length: int) -> dict:
    """Decode state — O(1) in sequence length (the SSM long-context win)."""
    del length
    d, di, h, hd = _dims(cfg)
    n_m, g, n_s = _groups(cfg)
    f32 = jnp.float32
    defs = {
        "m_C": PDef((g, n_m, batch, h, hd, hd), ("layers", "layers", "batch", "heads", None, None), f32, "zeros"),
        "m_n": PDef((g, n_m, batch, h, hd), ("layers", "layers", "batch", "heads", None), f32, "zeros"),
        "m_m": PDef((g, n_m, batch, h), ("layers", "layers", "batch", "heads"), f32, "zeros"),
    }
    if n_s:
        shd = d // h
        defs.update(
            s_c=PDef((g, n_s, batch, h, shd), ("layers", "layers", "batch", "heads", None), f32, "zeros"),
            s_n=PDef((g, n_s, batch, h, shd), ("layers", "layers", "batch", "heads", None), f32, "zeros"),
            s_m=PDef((g, n_s, batch, h, shd), ("layers", "layers", "batch", "heads", None), f32, "zeros"),
            s_h=PDef((g, n_s, batch, h, shd), ("layers", "layers", "batch", "heads", None), f32, "zeros"),
        )
    return defs


# ---------------------------------------------------------------------------
# mLSTM core.
# ---------------------------------------------------------------------------

def _mlstm_qkvif(pl, xm, cfg):
    _, di, h, hd = _dims(cfg)
    b, s, _ = xm.shape
    q = jnp.einsum("bsd,de->bse", xm, pl["wq"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,de->bse", xm, pl["wk"]).reshape(b, s, h, hd)
    v = jnp.einsum("bsd,de->bse", xm, pl["wv"]).reshape(b, s, h, hd)
    gates = jnp.einsum("bsd,dg->bsg", xm.astype(jnp.float32), pl["w_if"]) + pl["b_if"]
    i_pre, f_pre = gates[..., :h], gates[..., h:]
    return q, k, v, i_pre, f_pre


def mlstm_parallel(q, k, v, i_pre, f_pre):
    """Stabilized quadratic form (train/prefill).  q,k,v: (B,S,H,hd)."""
    hd = q.shape[-1]
    lf = jax.nn.log_sigmoid(f_pre)  # (B,S,H)
    li = i_pre
    F = jnp.cumsum(lf, axis=1)
    # D[t, s] = F_t - F_s + li_s  (s <= t)
    D = F[:, :, None, :] - F[:, None, :, :] + li[:, None, :, :]  # (B,T,S,H)
    t_idx = jnp.arange(q.shape[1])
    causal = t_idx[:, None] >= t_idx[None, :]
    D = jnp.where(causal[None, :, :, None], D, _NEG)
    m = jnp.max(D, axis=2)  # (B,T,H)
    w = jnp.exp(D - m[:, :, None, :])  # (B,T,S,H)
    scores = jnp.einsum("bthd,bshd->btsh", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(hd)
    sw = scores * w
    num = jnp.einsum("btsh,bshd->bthd", sw, v.astype(jnp.float32))
    denom = jnp.maximum(jnp.abs(sw.sum(axis=2)), jnp.exp(-m))  # (B,T,H)
    return num / denom[..., None]


def mlstm_step(state, q, k, v, i_pre, f_pre):
    """Recurrent form (decode).  q,k,v: (B,H,hd); state (C, n, m)."""
    C, n, m = state
    hd = q.shape[-1]
    lf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))  # (B,H)
    li = i_pre.astype(jnp.float32)
    m_new = jnp.maximum(lf + m, li)
    f_eff = jnp.exp(lf + m - m_new)[..., None]
    i_eff = jnp.exp(li - m_new)[..., None]
    k32 = k.astype(jnp.float32) / np.sqrt(hd)
    v32 = v.astype(jnp.float32)
    C_new = f_eff[..., None] * C + i_eff[..., None] * k32[..., :, None] * v32[..., None, :]
    n_new = f_eff * n + i_eff * k32
    q32 = q.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", q32, C_new)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q32, n_new)),
                        jnp.exp(-m_new))
    return (C_new, n_new, m_new), num / denom[..., None]


def _mlstm_block(pl, x, cfg, state=None):
    """Full block. x: (B,S,D). With state -> recurrent single-step (S==1)."""
    d, di, h, hd = _dims(cfg)
    b, s, _ = x.shape
    xn = rms_norm(x, pl["ln"], cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", xn, pl["w_up"])
    xm, z = up[..., :di], up[..., di:]
    xm = shard_act(xm, ("batch", "seq", "mlp"))
    q, k, v, i_pre, f_pre = _mlstm_qkvif(pl, xm, cfg)
    if state is None:
        hcell = mlstm_parallel(q, k, v, i_pre, f_pre)  # (B,S,H,hd)
        new_state = None
    else:
        new_state, hcell = mlstm_step(
            state, q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0], f_pre[:, 0])
        hcell = hcell[:, None]  # (B,1,H,hd)
    hcell = rms_norm(hcell, pl["out_norm"], cfg.norm_eps)
    hflat = hcell.reshape(b, s, di).astype(cfg.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", hflat, pl["w_down"])
    return x + out, new_state


# ---------------------------------------------------------------------------
# sLSTM core.
# ---------------------------------------------------------------------------

def _slstm_cell(pre, state):
    """pre: (B,H,hd,4) gate pre-activations; state (c, n, m, h)."""
    c, n, m, _h = state
    i_pre, f_pre, z_pre, o_pre = [pre[..., j] for j in range(4)]
    lf = jax.nn.log_sigmoid(f_pre)
    li = i_pre
    m_new = jnp.maximum(lf + m, li)
    i_eff = jnp.exp(li - m_new)
    f_eff = jnp.exp(lf + m - m_new)
    c_new = f_eff * c + i_eff * jnp.tanh(z_pre)
    n_new = jnp.maximum(f_eff * n + i_eff, 1e-6)
    h_new = jax.nn.sigmoid(o_pre) * (c_new / n_new)
    return (c_new, n_new, m_new, h_new)


def _slstm_recur(pl, px, h_prev, cfg):
    """Recurrent gate contribution for one timestep given the *precomputed*
    input projection px: (B,H,hd,4); h_prev: (B,H,hd)."""
    d, _, h, _ = _dims(cfg)
    hd = d // h
    pr = jnp.einsum("bhe,heg->bhg", h_prev, pl["r"].astype(jnp.float32))
    return px + pr.reshape(px.shape[0], h, hd, 4)


def _slstm_input_proj(pl, xn, cfg):
    """Hoisted input projection over the full sequence: (B,S,H,hd,4).
    Keeping this batched matmul outside the time scan leaves only the small
    block-diagonal recurrence (h @ R) sequential."""
    d, _, h, _ = _dims(cfg)
    hd = d // h
    b, s, _ = xn.shape
    px = jnp.einsum("bsd,dg->bsg", xn.astype(jnp.float32),
                    pl["wx"].astype(jnp.float32)) + pl["b"]
    return px.reshape(b, s, h, hd, 4)


def _slstm_block(pl, x, cfg, state=None):
    d, _, h, _ = _dims(cfg)
    hd = d // h
    b, s, _ = x.shape
    xn = rms_norm(x, pl["ln"], cfg.norm_eps)
    if state is None:
        px_all = _slstm_input_proj(pl, xn, cfg)
        zeros = jnp.zeros((b, h, hd), jnp.float32)
        state0 = (zeros, zeros, jnp.full((b, h, hd), _NEG, jnp.float32), zeros)

        def step(st, px_t):
            pre = _slstm_recur(pl, px_t, st[3], cfg)
            st_new = _slstm_cell(pre, st)
            return st_new, st_new[3]

        state_f, hs = jax.lax.scan(step, state0, jnp.swapaxes(px_all, 0, 1))
        hs = jnp.swapaxes(hs, 0, 1)  # (B,S,H,hd)
        new_state = None
    else:
        px = _slstm_input_proj(pl, xn[:, :1], cfg)[:, 0]
        pre = _slstm_recur(pl, px, state[3], cfg)
        st_new = _slstm_cell(pre, state)
        hs = st_new[3][:, None]
        new_state = st_new
    hs = rms_norm(hs, pl["out_norm"], cfg.norm_eps)
    x = x + hs.reshape(b, s, d).astype(cfg.dtype)
    # post-FFN (pf 4/3)
    xn2 = rms_norm(x, pl["ln_ffn"], cfg.norm_eps)
    hmid = jax.nn.silu(jnp.einsum("bsd,df->bsf", xn2, pl["ffn_wi"]))
    hmid = hmid * jnp.einsum("bsd,df->bsf", xn2, pl["ffn_wg"])
    return x + jnp.einsum("bsf,fd->bsd", hmid, pl["ffn_wo"]), new_state


# ---------------------------------------------------------------------------
# Stack: scan over groups of (n_m x mLSTM [+ 1 sLSTM]).
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg):
    x = jnp.take(params["embed"], tokens, axis=0)
    return x * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)


def forward(params, batch, cfg: ArchConfig):
    x = _embed(params, batch["tokens"], cfg)
    x = shard_act(x, ("batch", "seq", "embed"))
    n_m, g, n_s = _groups(cfg)

    def group_body(carry, inp):
        x = carry
        pm = inp["mlstm"]

        def mbody(c, pl):
            y, _ = _mlstm_block(pl, c, cfg)
            return y, None

        x, _ = jax.lax.scan(mbody, x, pm, unroll=n_m)
        if n_s:
            def sbody(c, pl):
                y, _ = _slstm_block(pl, c, cfg)
                return y, None

            x, _ = jax.lax.scan(sbody, x, inp["slstm"], unroll=max(n_s,1))
        return x, None

    if cfg.remat:
        group_body = jax.checkpoint(group_body)
    xs = {"mlstm": params["mlstm"]}
    if n_s:
        xs["slstm"] = params["slstm"]
    x, _ = jax.lax.scan(group_body, x, xs, unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return shard_act(logits, ("batch", "seq", "vocab")), {}


def loss(params, batch, cfg: ArchConfig):
    logits, _ = forward(params, batch, cfg)
    ce, acc = softmax_xent(logits[:, :-1], batch["tokens"][:, 1:])
    return ce, (ce, acc)


def prefill(params, batch, cfg: ArchConfig, cache_len: int):
    """Recurrent prefill: thread decode_step over the prompt (the natural
    O(S) path for a recurrent model) -> (logits (B,S,V), final state)."""
    del cache_len  # state is O(1)
    from repro.models.pdefs import init_tree  # zeros-init state

    tokens = batch["tokens"]
    b = tokens.shape[0]
    cache0 = init_tree(jax.random.PRNGKey(0), cache_defs(cfg, b, 0))

    def step(cache, tok):
        logits, cache = decode_step(params, cache, tok, jnp.int32(0), cfg)
        return cache, logits

    cache, logits = jax.lax.scan(step, cache0, jnp.swapaxes(tokens, 0, 1))
    return jnp.swapaxes(logits, 0, 1), cache


def decode_step(params, cache, tokens, pos, cfg: ArchConfig):
    del pos  # recurrent state is position-free
    x = _embed(params, tokens[:, None], cfg)
    n_m, g, n_s = _groups(cfg)

    def group_body(carry, inp):
        x = carry

        def mbody(c, inp_m):
            pl, (C, n, m) = inp_m
            y, st = _mlstm_block(pl, c, cfg, state=(C, n, m))
            return y, st

        x, m_states = jax.lax.scan(
            mbody, x, (inp["p"]["mlstm"], (inp["c"]["m_C"], inp["c"]["m_n"], inp["c"]["m_m"])),
            unroll=n_m)
        new_c = {"m_C": m_states[0], "m_n": m_states[1], "m_m": m_states[2]}
        if n_s:
            def sbody(c, inp_s):
                pl, st = inp_s
                y, st_new = _slstm_block(pl, c, cfg, state=st)
                return y, st_new

            x, s_states = jax.lax.scan(
                sbody, x,
                (inp["p"]["slstm"],
                 (inp["c"]["s_c"], inp["c"]["s_n"], inp["c"]["s_m"], inp["c"]["s_h"])))
            new_c.update(s_c=s_states[0], s_n=s_states[1], s_m=s_states[2], s_h=s_states[3])
        return x, new_c

    p_groups = {"mlstm": params["mlstm"]}
    if n_s:
        p_groups["slstm"] = params["slstm"]
    x, new_cache = jax.lax.scan(group_body, x, {"p": p_groups, "c": cache},
                                unroll=cfg.scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    return logits, new_cache
