"""Attention: GQA (with sliding window / encoder modes) and MLA (DeepSeek).

All functions are cache-functional: full-sequence mode returns no cache;
decode mode takes one layer's cache slice and returns the updated slice, so
the layer stack can ``lax.scan`` over (stacked params, stacked cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, rms_norm, rope, shard_act
from repro.models.pdefs import PDef

__all__ = [
    "gqa_defs",
    "mla_defs",
    "gqa_cache_defs",
    "mla_cache_defs",
    "gqa_forward",
    "gqa_decode",
    "mla_forward",
    "mla_decode",
]

_NEG = -2.0e38


# ---------------------------------------------------------------------------
# Parameter / cache definitions.
# ---------------------------------------------------------------------------

def gqa_defs(cfg: ArchConfig, stacked: tuple = ()) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    L, Lax = (stacked, ("layers",) * len(stacked)) if stacked else ((), ())
    dt = cfg.dtype
    defs = {
        "wq": PDef(L + (d, h, hd), Lax + ("embed", "heads", "head_dim"), dt, fan_in=d),
        "wk": PDef(L + (d, kv, hd), Lax + ("embed", "kv_heads", "head_dim"), dt, fan_in=d),
        "wv": PDef(L + (d, kv, hd), Lax + ("embed", "kv_heads", "head_dim"), dt, fan_in=d),
        "wo": PDef(L + (h, hd, d), Lax + ("heads", "head_dim", "embed"), dt, fan_in=h * hd),
    }
    if cfg.qk_norm:
        defs["q_norm"] = PDef(L + (hd,), Lax + (None,), jnp.float32, "zeros")
        defs["k_norm"] = PDef(L + (hd,), Lax + (None,), jnp.float32, "zeros")
    return defs


def mla_defs(cfg: ArchConfig, stacked: tuple = ()) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    L, Lax = (stacked, ("layers",) * len(stacked)) if stacked else ((), ())
    dt = cfg.dtype
    return {
        "wq_a": PDef(L + (d, qr), Lax + ("embed", "rank"), dt, fan_in=d),
        "q_norm": PDef(L + (qr,), Lax + (None,), jnp.float32, "zeros"),
        "wq_b": PDef(L + (qr, h, dn + dr), Lax + ("rank", "heads", None), dt, fan_in=qr),
        "wkv_a": PDef(L + (d, kr + dr), Lax + ("embed", "rank"), dt, fan_in=d),
        "kv_norm": PDef(L + (kr,), Lax + (None,), jnp.float32, "zeros"),
        "wkv_b": PDef(L + (kr, h, dn + dv), Lax + ("rank", "heads", None), dt, fan_in=kr),
        "wo": PDef(L + (h, dv, d), Lax + ("heads", None, "embed"), dt, fan_in=h * dv),
    }


def gqa_cache_defs(cfg: ArchConfig, batch: int, length: int, stacked: tuple = ()) -> dict:
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    L, Lax = (stacked, ("layers",) * len(stacked)) if stacked else ((), ())
    shape = L + (batch, length, kv, hd)
    axes = Lax + ("batch", "seq", "kv_heads", "head_dim")
    return {"k": PDef(shape, axes, cfg.dtype, "zeros"),
            "v": PDef(shape, axes, cfg.dtype, "zeros")}


def mla_cache_defs(cfg: ArchConfig, batch: int, length: int, stacked: tuple = ()) -> dict:
    L, Lax = (stacked, ("layers",) * len(stacked)) if stacked else ((), ())
    return {
        "ckv": PDef(L + (batch, length, cfg.kv_lora_rank),
                    Lax + ("batch", "seq", "rank"), cfg.dtype, "zeros"),
        "kpe": PDef(L + (batch, length, cfg.qk_rope_head_dim),
                    Lax + ("batch", "seq", None), cfg.dtype, "zeros"),
    }


# ---------------------------------------------------------------------------
# Masking + core dot-product attention.
# ---------------------------------------------------------------------------

def _full_mask(q_pos, k_pos, window, causal: bool):
    """Additive bias (..., Sq, Sk). window may be a traced scalar; 0 = full."""
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(dq.shape, dk.shape), bool)
    if causal:
        ok = dk <= dq
        win = jnp.asarray(window)
        ok = ok & ((win <= 0) | (dq - dk < win))
    return jnp.where(ok, 0.0, _NEG).astype(jnp.float32)


def _dot_attn(q, k, v, bias, scale):
    """q: (B,Sq,KV,G,hd)  k,v: (B,Sk,KV,hd)  bias: (B,1,1,Sq,Sk) or None."""
    qf = (q * scale).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    if bias is not None:
        scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.astype(v.dtype)


def _split_heads(x, kv, g):
    b, s = x.shape[:2]
    return x.reshape(b, s, kv, g, -1)


# ---------------------------------------------------------------------------
# GQA.
# ---------------------------------------------------------------------------

def _gqa_qkv(p, x, cfg: ArchConfig, positions, theta):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if theta is not None:
        sin, cos = rope(positions, cfg.resolved_head_dim, theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    return q, k, v


def gqa_forward(p, x, cfg: ArchConfig, window=0, theta=None, positions=None,
                return_kv: bool = False):
    """Full-sequence attention (train / prefill / encoder)."""
    b, s, _ = x.shape
    kv, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _gqa_qkv(p, x, cfg, positions, theta)
    q = shard_act(q, ("batch", "seq", "heads", None))
    bias = None
    if cfg.causal or cfg.sliding_window:  # static: window itself may be traced
        bias = _full_mask(positions, positions, window, cfg.causal)[:, None, None]
    out = _dot_attn(_split_heads(q, kv, g), k, v, bias, hd ** -0.5)
    out = out.reshape(b, s, cfg.n_heads, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return (out, (k, v)) if return_kv else out


def gqa_decode(p, x, cache, cfg: ArchConfig, pos, window=0, theta=None):
    """One-token decode. x: (B,1,D); cache slice {"k","v"}: (B,S,kv,hd)."""
    b = x.shape[0]
    kv, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    positions = jnp.full((b, 1), pos)
    q, k_new, v_new = _gqa_qkv(p, x, cfg, positions, theta)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), pos, 1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), pos, 1)
    k_pos = jnp.broadcast_to(jnp.arange(k.shape[1]), (b, k.shape[1]))
    bias = _full_mask(positions, k_pos, window, True)[:, None, None]
    out = _dot_attn(_split_heads(q, kv, g), k, v, bias, hd ** -0.5)
    out = out.reshape(b, 1, cfg.n_heads, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V3).
# ---------------------------------------------------------------------------

def _mla_q(p, x, cfg: ArchConfig, positions):
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    sin, cos = rope(positions, dr, cfg.rope_theta)
    return q_nope, apply_rope(q_pe, sin, cos)


def _mla_kv_latent(p, x, cfg: ArchConfig, positions):
    kr, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    ckv = rms_norm(a[..., :kr], p["kv_norm"], cfg.norm_eps)
    sin, cos = rope(positions, dr, cfg.rope_theta)
    kpe = apply_rope(a[..., None, kr:], sin, cos)[..., 0, :]  # shared head
    return ckv, kpe


def _mla_attend(p, q_nope, q_pe, ckv, kpe, cfg: ArchConfig, bias):
    dn, dv = cfg.qk_nope_head_dim, cfg.v_head_dim
    kvb = jnp.einsum("bsr,rhk->bshk", ckv, p["wkv_b"])
    k_nope, v = kvb[..., :dn], kvb[..., dn:]
    scale = (dn + cfg.qk_rope_head_dim) ** -0.5
    s1 = jnp.einsum("bqhd,bshd->bhqs", (q_nope * scale).astype(jnp.float32),
                    k_nope.astype(jnp.float32))
    s2 = jnp.einsum("bqhd,bsd->bhqs", (q_pe * scale).astype(jnp.float32),
                    kpe.astype(jnp.float32))
    scores = s1 + s2
    if bias is not None:
        scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v.astype(jnp.float32)).astype(v.dtype)
    return jnp.einsum("bqhd,hdo->bqo", out, p["wo"])


def mla_forward(p, x, cfg: ArchConfig, window=0, theta=None, positions=None,
                return_kv: bool = False):
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q_nope, q_pe = _mla_q(p, x, cfg, positions)
    ckv, kpe = _mla_kv_latent(p, x, cfg, positions)
    bias = _full_mask(positions, positions, 0, cfg.causal)[:, None]
    out = _mla_attend(p, q_nope, q_pe, ckv, kpe, cfg, bias)
    return (out, (ckv, kpe)) if return_kv else out


def mla_decode(p, x, cache, cfg: ArchConfig, pos, window=0, theta=None):
    """Decode against the latent cache (ckv + kpe) — the MLA memory win."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos)
    q_nope, q_pe = _mla_q(p, x, cfg, positions)
    ckv_new, kpe_new = _mla_kv_latent(p, x, cfg, positions)
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), pos, 1)
    kpe = jax.lax.dynamic_update_slice_in_dim(
        cache["kpe"], kpe_new.astype(cache["kpe"].dtype), pos, 1)
    k_pos = jnp.broadcast_to(jnp.arange(ckv.shape[1]), (b, ckv.shape[1]))
    bias = _full_mask(positions, k_pos, 0, True)[:, None]
    out = _mla_attend(p, q_nope, q_pe, ckv, kpe, cfg, bias)
    return out, {"ckv": ckv, "kpe": kpe}
