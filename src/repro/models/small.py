"""The paper's backbones in pure JAX: mnist_2NN, the CIFAR CNN, and
ResNet-18 with GroupNorm (batch-norm replaced per the paper's Appendix A).

Each model exposes ``init(key) -> params`` and ``apply(params, x) -> logits``
plus a ready-made ``loss(params, batch) -> (ce_loss, accuracy)`` suitable for
``repro.core.engine.FLTrainer``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Model", "mnist_2nn", "tiny_mlp", "cifar_cnn", "resnet18_gn",
           "get_model"]


def _dense_init(key, n_in, n_out, scale=None):
    scale = scale or float(np.sqrt(2.0 / n_in))
    wk, _ = jax.random.split(key)
    return {
        "w": (scale * jax.random.normal(wk, (n_in, n_out))).astype(jnp.float32),
        "b": jnp.zeros((n_out,), jnp.float32),
    }


def _conv_init(key, kh, kw, c_in, c_out):
    fan_in = kh * kw * c_in
    scale = float(np.sqrt(2.0 / fan_in))
    return {
        "w": (scale * jax.random.normal(key, (kh, kw, c_in, c_out))).astype(
            jnp.float32
        ),
        "b": jnp.zeros((c_out,), jnp.float32),
    }


def _conv(x, p, stride=1, padding="SAME"):
    y = jax.lax.conv_general_dilated(
        x,
        p["w"],
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def _group_norm(x, p, groups=8, eps=1e-5):
    n, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(n, h, w, g, c // g)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return xg.reshape(n, h, w, c) * p["scale"] + p["bias"]


def _gn_init(c):
    return {"scale": jnp.ones((c,), jnp.float32), "bias": jnp.zeros((c,), jnp.float32)}


def _softmax_xent(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    ce = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return ce, acc


@dataclasses.dataclass(frozen=True)
class Model:
    name: str
    init: Callable
    apply: Callable

    def loss(self, params, batch):
        logits = self.apply(params, batch["x"])
        return _softmax_xent(logits, batch["y"])


# ---------------------------------------------------------------------------
# mnist_2NN: 784 -> 200 -> 200 -> 10 (Sun et al. 2022).
# ---------------------------------------------------------------------------

def mnist_2nn(n_classes: int = 10, in_dim: int = 784) -> Model:
    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "fc1": _dense_init(k1, in_dim, 200),
            "fc2": _dense_init(k2, 200, 200),
            "out": _dense_init(k3, 200, n_classes),
        }

    def apply(params, x):
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
        x = jax.nn.relu(x @ params["fc2"]["w"] + params["fc2"]["b"])
        return x @ params["out"]["w"] + params["out"]["b"]

    return Model("mnist_2nn", init, apply)


# ---------------------------------------------------------------------------
# Deliberately small MLP for population-scale paging benches/tests: a row
# is a few KB, so thousands of disk-backed clients cycle through the store
# in seconds rather than hours.
# ---------------------------------------------------------------------------

def tiny_mlp(in_dim: int = 32, hidden: int = 32, n_classes: int = 10) -> Model:
    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "fc1": _dense_init(k1, in_dim, hidden),
            "out": _dense_init(k2, hidden, n_classes),
        }

    def apply(params, x):
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
        return x @ params["out"]["w"] + params["out"]["b"]

    return Model("tiny_mlp", init, apply)


# ---------------------------------------------------------------------------
# CIFAR CNN: conv5x5(64) - pool - conv5x5(64) - pool - fc384 - fc192 - out
# (paper Appendix A).
# ---------------------------------------------------------------------------

def cifar_cnn(n_classes: int = 10, image: tuple = (32, 32, 3)) -> Model:
    h, w, c = image
    flat = (h // 4) * (w // 4) * 64

    def init(key):
        ks = jax.random.split(key, 5)
        return {
            "conv1": _conv_init(ks[0], 5, 5, c, 64),
            "conv2": _conv_init(ks[1], 5, 5, 64, 64),
            "fc1": _dense_init(ks[2], flat, 384),
            "fc2": _dense_init(ks[3], 384, 192),
            "out": _dense_init(ks[4], 192, n_classes),
        }

    def apply(params, x):
        x = jax.nn.relu(_conv(x, params["conv1"]))
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
        x = jax.nn.relu(_conv(x, params["conv2"]))
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
        x = jax.nn.relu(x @ params["fc2"]["w"] + params["fc2"]["b"])
        return x @ params["out"]["w"] + params["out"]["b"]

    return Model("cifar_cnn", init, apply)


# ---------------------------------------------------------------------------
# ResNet-18 with GroupNorm.
# ---------------------------------------------------------------------------

_STAGES = ((64, 1), (128, 2), (256, 2), (512, 2))  # (width, first stride)


def resnet18_gn(n_classes: int = 10, image: tuple = (32, 32, 3), width_mult: float = 1.0) -> Model:
    widths = [max(int(w * width_mult), 8) for w, _ in _STAGES]

    def init(key):
        keys = iter(jax.random.split(key, 64))
        params = {
            "stem": _conv_init(next(keys), 3, 3, image[2], widths[0]),
            "stem_gn": _gn_init(widths[0]),
        }
        c_in = widths[0]
        for s, ((_, stride), c_out) in enumerate(zip(_STAGES, widths)):
            for b in range(2):
                blk = {
                    "conv1": _conv_init(next(keys), 3, 3, c_in, c_out),
                    "gn1": _gn_init(c_out),
                    "conv2": _conv_init(next(keys), 3, 3, c_out, c_out),
                    "gn2": _gn_init(c_out),
                }
                if c_in != c_out or (b == 0 and stride != 1):
                    blk["proj"] = _conv_init(next(keys), 1, 1, c_in, c_out)
                    blk["proj_gn"] = _gn_init(c_out)
                params[f"s{s}b{b}"] = blk
                c_in = c_out
        params["head"] = _dense_init(next(keys), c_in, n_classes)
        return params

    def block(x, p, stride):
        y = _conv(x, p["conv1"], stride=stride)
        y = jax.nn.relu(_group_norm(y, p["gn1"]))
        y = _conv(y, p["conv2"])
        y = _group_norm(y, p["gn2"])
        if "proj" in p:
            x = _group_norm(_conv(x, p["proj"], stride=stride), p["proj_gn"])
        return jax.nn.relu(x + y)

    def apply(params, x):
        x = jax.nn.relu(_group_norm(_conv(x, params["stem"]), params["stem_gn"]))
        for s, (_, stride) in enumerate(_STAGES):
            for b in range(2):
                x = block(x, params[f"s{s}b{b}"], stride if b == 0 else 1)
        x = x.mean(axis=(1, 2))
        return x @ params["head"]["w"] + params["head"]["b"]

    return Model("resnet18_gn", init, apply)


def get_model(name: str, n_classes: int, image=(32, 32, 3)) -> Model:
    if name == "mnist_2nn":
        return mnist_2nn(n_classes, int(np.prod(image)))
    if name == "cifar_cnn":
        return cifar_cnn(n_classes, image)
    if name == "resnet18_gn":
        return resnet18_gn(n_classes, image)
    raise ValueError(name)
