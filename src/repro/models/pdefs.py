"""Parameter definitions with logical sharding axes.

Every model declares its parameters (and KV caches) as a pytree of ``PDef``
— shape + per-dim *logical axis names* + init spec.  From one declaration we
derive: real initialization (smoke tests / training), ShapeDtypeStructs
(dry-run, no allocation), and NamedShardings (logical→mesh rules live in
``repro.launch.sharding``).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PDef", "init_tree", "abstract_tree", "tree_num_params"]


class PDef(NamedTuple):
    shape: tuple
    axes: tuple  # logical axis name (str) or None per dim
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones
    fan_in: int = 0  # 0 -> last-but-one dim

    def scale(self) -> float:
        if self.init != "normal":
            return 0.0
        fan = self.fan_in or (self.shape[-2] if len(self.shape) >= 2 else self.shape[-1])
        return float(1.0 / np.sqrt(max(fan, 1)))


def _is_pdef(x):
    return isinstance(x, PDef)


def init_tree(key: jax.Array, defs) -> Any:
    """Materialize real parameters from a PDef tree."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_pdef)
    keys = jax.random.split(key, len(leaves))

    def make(k, d: PDef):
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        return (d.scale() * jax.random.normal(k, d.shape, jnp.float32)).astype(d.dtype)

    return jax.tree.unflatten(treedef, [make(k, d) for k, d in zip(keys, leaves)])


def abstract_tree(defs, sharding_fn=None) -> Any:
    """ShapeDtypeStruct tree (optionally with shardings) — no allocation."""

    def make(d: PDef):
        sh = sharding_fn(d) if sharding_fn else None
        return jax.ShapeDtypeStruct(d.shape, d.dtype, sharding=sh)

    return jax.tree.map(make, defs, is_leaf=_is_pdef)


def tree_num_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=_is_pdef)
    return int(sum(np.prod(d.shape) for d in leaves))
