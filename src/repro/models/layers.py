"""Shared model layers: norms, rotary embeddings, activations, losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "rope",
    "apply_rope",
    "sinusoidal_positions",
    "softmax_xent",
    "shard_act",
]


def shard_act(x, logical: tuple):
    """Activation sharding constraint hook; resolved by repro.launch.sharding
    when a mesh is active, identity otherwise (import-cycle-free)."""
    from repro.launch import sharding as shlib

    return shlib.constrain(x, logical)


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope(positions, dim: int, theta) -> tuple:
    """Returns (sin, cos) of shape positions.shape + (dim//2,).

    ``theta`` may be a python float or a traced scalar (per-layer theta is
    scanned over layers for gemma3's local/global split).
    """
    half = dim // 2
    freqs = jnp.exp(
        -jnp.log(jnp.asarray(theta, jnp.float32))
        * jnp.arange(half, dtype=jnp.float32) / half
    )
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x, sin, cos):
    """x: (..., seq, heads, head_dim); sin/cos: (..., seq, head_dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s = sin[..., None, :].astype(jnp.float32)
    c = cos[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * c - x2f * s, x2f * c + x1f * s], axis=-1
    ).astype(x.dtype)


def sinusoidal_positions(positions, dim: int):
    half = dim // 2
    freqs = jnp.exp(
        -jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def softmax_xent(logits, labels, mask=None):
    """Mean CE over (optionally masked) positions; returns (loss, acc)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    correct = (logits.argmax(-1) == labels).astype(jnp.float32)
    if mask is None:
        return -ll.mean(), correct.mean()
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    return -(ll * mask).sum() / denom, (correct * mask).sum() / denom
