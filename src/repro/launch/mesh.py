"""Production mesh construction (TPU v5e).

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — the "pod" axis is
the DFL client axis: each pod holds one push-sum replica.

Defined as functions (not module constants) so importing never touches jax
device state; the dry-run forces 512 host devices *before* calling these.
"""
from __future__ import annotations

import jax

try:  # AxisType only exists in newer jax; older versions imply Auto.
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None

__all__ = ["make_production_mesh", "make_host_mesh", "make_clients_mesh",
           "HARDWARE"]


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))

# TPU v5e constants used by the roofline model.
HARDWARE = {
    "chip": "tpu-v5e",
    "peak_flops_bf16": 197e12,  # FLOP/s per chip
    "hbm_bw": 819e9,  # B/s per chip
    "ici_bw": 50e9,  # B/s per link (~50 GB/s)
    "hbm_bytes": 16 * 2**30,
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many host devices exist (tests/examples)."""
    return _make_mesh(shape, axes)


def make_clients_mesh(n_devices: int | None = None):
    """1-D mesh whose single ``clients`` axis row-shards the flat bank.

    ``n_devices`` defaults to every visible device (on CPU CI that is
    whatever ``--xla_force_host_platform_device_count`` forced).  The bank
    row count must be divisible by the axis size — ``make_program``
    validates that when handed this mesh.
    """
    if n_devices is None:
        n_devices = jax.device_count()
    return _make_mesh((n_devices,), ("clients",))
