"""Logical-axis → mesh-axis resolution (params & activations).

Models declare *logical* axes ("heads", "mlp", "embed", "batch", ...); this
module owns the mapping onto the production mesh ("data", "model"[, "pod"]).
A context manager activates a mesh + rule set; without one everything is a
no-op so the same model code runs on a laptop CPU.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.pdefs import PDef

__all__ = [
    "MODEL_AXES",
    "FSDP_AXES",
    "use_mesh",
    "active_mesh",
    "constrain",
    "in_manual_region",
    "spec_for",
    "sharding_for",
    "bank_row_pins",
]

# Logical axes eligible for tensor/expert parallelism, in priority order —
# the *first* divisible dim of a param gets the "model" mesh axis.
MODEL_AXES = ("expert", "vocab", "heads", "kv_heads", "mlp", "head_dim", "ssm_inner")
# Logical axes eligible for FSDP-style sharding over "data".
FSDP_AXES = ("embed", "ffpar", "frontend", "rank")
# Activation logical names handled by `constrain`.
ACT_RULES = {
    "batch": "data",
    "expert": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "head_dim": "head_dim_fallback",  # only used when heads were replicated
    "ssm_inner": "model",
    "seq": None,
    "embed": None,
}

_STATE: list = []  # stack of (mesh, fsdp: bool, head_dim_fallback: bool)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, fsdp: bool = True):
    # jax.set_mesh only exists in newer jax; Mesh-as-context-manager is the
    # portable spelling and enters the same default device mesh.
    set_mesh = getattr(jax, "set_mesh", None)
    _STATE.append((mesh, fsdp))
    try:
        with (set_mesh(mesh) if set_mesh is not None else mesh):
            yield mesh
    finally:
        _STATE.pop()


def active_mesh() -> Optional[Mesh]:
    return _STATE[-1][0] if _STATE else None


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 0


def spec_for(pdef: PDef, mesh: Mesh, fsdp: bool = True,
             model_axes: tuple = None) -> P:
    """Resolve a parameter PDef to a PartitionSpec.

    At most one dim is sharded over "model" (first divisible logical axis in
    ``model_axes`` priority) and, when ``fsdp``, one over "data".
    """
    model_axes = MODEL_AXES if model_axes is None else model_axes
    model_n = _axis_size(mesh, "model")
    data_n = _axis_size(mesh, "data")
    spec: list = [None] * len(pdef.shape)

    def place(mesh_axis, mesh_n, candidates):
        if not mesh_n or mesh_axis in spec:
            return
        for logical in candidates:
            for i, (dim, name) in enumerate(zip(pdef.shape, pdef.axes)):
                if name == logical and spec[i] is None and dim % mesh_n == 0:
                    spec[i] = mesh_axis
                    return

    place("model", model_n, model_axes)
    # caches/activations: batch rides on "data" (takes priority over FSDP)
    place("data", data_n, ("batch",))
    if fsdp:
        place("data", data_n, FSDP_AXES)
    # long-context caches with unshardable batch: shard the sequence dim
    place("data", data_n, ("seq",))
    return P(*spec)


def sharding_for(pdef: PDef, mesh: Mesh = None, fsdp: bool = None):
    if mesh is None:
        if not _STATE:
            return None
        mesh, fsdp_active = _STATE[-1]
        fsdp = fsdp_active if fsdp is None else fsdp
    return NamedSharding(mesh, spec_for(pdef, mesh, True if fsdp is None else fsdp))


def bank_row_pins(mesh: Optional[Mesh], axis: str):
    """Row-sharding constraints for a flat client bank: ``(pin, pin_link)``.

    ``pin(x, lead=0)`` asserts that dim ``lead`` of ``x`` (the client-row
    dim) lives on mesh axis ``axis``, all other dims replicated — the
    GSPMD partitioner will otherwise happily rematerialize the bank
    replicated around ``ravel`` reshapes and concats, silently turning the
    sharded round into n copies of the single-device one.  ``pin_link``
    pins a LinkState carry: the ``(B, n, D)`` in-flight payload buffer and
    the ``(n, D)`` last-broadcast cache on their client dims; the small
    ``(B, n)`` mass buffer and the PRNG key are left to the partitioner.

    With ``mesh`` ``None`` (or the axis absent) both functions are
    identity, so unsharded callers compose through them bitwise unchanged.
    """
    if mesh is None or axis not in mesh.axis_names:
        return (lambda x, lead=0: x), (lambda link: link)

    def pin(x, lead: int = 0):
        spec = [None] * x.ndim
        spec[lead] = axis
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec))
        )

    def pin_link(link):
        if not link:  # the empty-carry () sentinel passes through
            return link
        upd = {}
        if not isinstance(link.bufx, tuple):
            upd["bufx"] = pin(link.bufx, lead=1)
        if not isinstance(link.last, tuple):
            upd["last"] = pin(link.last)
        return link._replace(**upd) if upd else link

    return pin, pin_link


def in_manual_region(mesh: Optional[Mesh] = None) -> bool:
    """Is the current trace inside a ``shard_map`` manual region over any
    axis of ``mesh`` (the active mesh when ``None``)?

    Inside such a region values are *per-shard* and GSPMD sharding
    constraints do not apply — ``with_sharding_constraint`` would raise.
    The probe is ``jax.lax.axis_index``: a mesh axis name is bound as a
    collective axis exactly inside the manual region (a plain jit, and
    ``vmap(spmd_axis_name=...)``, leave it unbound — constraints there are
    valid and wanted).
    """
    if mesh is None:
        mesh = active_mesh()
    if mesh is None:
        return False
    for name in mesh.axis_names:
        try:
            jax.lax.axis_index(name)
        except NameError:
            continue
        return True
    return False


def constrain(x, logical: tuple):
    """Activation sharding constraint by logical names (no-op without mesh).

    Inside a ``shard_map`` manual region (the halo gossip executor, or any
    model code a caller maps manually) the value is already per-shard and
    the constraint is explicitly skipped — detected by
    :func:`in_manual_region`, not by swallowing errors, so a genuinely
    malformed constraint (bad axis name, rank mismatch) still raises.
    """
    if not _STATE:
        return x
    mesh, _ = _STATE[-1]
    spec: list = [None] * x.ndim
    for i, name in enumerate(logical):
        if name is None:
            continue
        mesh_axis = ACT_RULES.get(name)
        if mesh_axis in (None, "head_dim_fallback"):
            continue
        n = _axis_size(mesh, mesh_axis)
        if n and x.shape[i] % n == 0 and mesh_axis not in spec:
            spec[i] = mesh_axis
    if in_manual_region(mesh):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))
