"""Serving launcher: batched prefill + greedy decode for any zoo arch.

  PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b --smoke \
      --batch 4 --prompt-len 12 --new-tokens 8
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_config
    from repro.launch.steps import make_serve_step
    from repro.models.registry import get_model_api

    cfg = get_config(args.arch, smoke=args.smoke)
    if not cfg.supports_decode():
        raise SystemExit(f"{cfg.name} is encoder-only: no decode path")
    api = get_model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    serve_step = jax.jit(make_serve_step(api), donate_argnums=(1,))

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    cache_len = args.prompt_len + args.new_tokens
    batch = {"tokens": prompts}
    if cfg.task == "vlm":
        batch["image_feats"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, 8, cfg.frontend_dim))
    n_prefix = batch.get("image_feats", jnp.zeros((0, 0))).shape[1]

    t0 = time.time()
    logits, cache = jax.jit(lambda p, b: api.prefill(p, b, cache_len))(params, batch)
    toks = logits[:, -1].argmax(-1).astype(jnp.int32)
    print(f"[serve] prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

    out = [toks]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        pos = jnp.int32(n_prefix + args.prompt_len + i)
        logits_i, cache = serve_step(params, cache, toks, pos)
        toks = logits_i.argmax(-1).astype(jnp.int32)
        out.append(toks)
    dt = time.time() - t0
    print(f"[serve] {args.new_tokens - 1} steps: "
          f"{1e3 * dt / max(args.new_tokens - 1, 1):.1f} ms/step")
    print(jnp.stack(out, axis=1))


if __name__ == "__main__":
    main()
