"""Serving launcher: batched prefill + greedy decode for any zoo arch.

  PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b --smoke \
      --batch 4 --prompt-len 12 --new-tokens 8

With ``--clients N`` the batch becomes a *personalized* decode: a low-rank
delta bank (frozen shared base = the init weights, rank ``--rank`` adapters)
holds one row per client, and every request lane serves a different client's
expanded model in the same XLA program.
"""
from __future__ import annotations

import argparse
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="shrink the arch config (--no-smoke for full size)")
    ap.add_argument("--clients", type=int, default=0,
                    help="serve this many per-client delta-bank models "
                         "(0 = plain shared-weights decode)")
    ap.add_argument("--rank", type=int, default=8,
                    help="adapter rank for the --clients delta bank")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_config
    from repro.launch.steps import make_personalized_serve_step, make_serve_step
    from repro.models.registry import get_model_api

    cfg = get_config(args.arch, smoke=args.smoke)
    if not cfg.supports_decode():
        raise SystemExit(f"{cfg.name} is encoder-only: no decode path")
    api = get_model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))

    if args.clients:
        _serve_personalized(args, cfg, api, params)
        return

    serve_step = jax.jit(make_serve_step(api), donate_argnums=(1,))

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    cache_len = args.prompt_len + args.new_tokens
    batch = {"tokens": prompts}
    if cfg.task == "vlm":
        batch["image_feats"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, 8, cfg.frontend_dim))
    n_prefix = batch.get("image_feats", jnp.zeros((0, 0))).shape[1]

    t0 = time.time()
    logits, cache = jax.jit(lambda p, b: api.prefill(p, b, cache_len))(params, batch)
    toks = logits[:, -1].argmax(-1).astype(jnp.int32)
    print(f"[serve] prefill {args.batch}x{args.prompt_len}: {time.time()-t0:.2f}s")

    out = [toks]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        pos = jnp.int32(n_prefix + args.prompt_len + i)
        logits_i, cache = serve_step(params, cache, toks, pos)
        toks = logits_i.argmax(-1).astype(jnp.int32)
        out.append(toks)
    dt = time.time() - t0
    print(f"[serve] {args.new_tokens - 1} steps: "
          f"{1e3 * dt / max(args.new_tokens - 1, 1):.1f} ms/step")
    print(jnp.stack(out, axis=1))


def _serve_personalized(args, cfg, api, params):
    """--clients path: one bank row per client, batched multi-model decode."""
    import jax
    import jax.numpy as jnp

    from repro.core.flat import bind_delta_spec, make_delta_spec
    from repro.launch.steps import make_personalized_serve_step

    dspec = make_delta_spec(params, rank=args.rank)
    spec = bind_delta_spec(dspec, params)
    ps = make_personalized_serve_step(api, spec)
    n = args.clients

    # A synthetic trained bank: each client a distinct small perturbation.
    bank = 0.02 * jax.random.normal(jax.random.PRNGKey(3), (n, dspec.dim),
                                    dspec.dtype)
    w = jnp.ones((n,), jnp.float32)
    ids = jnp.arange(n, dtype=jnp.int32)

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (n, args.prompt_len), 0, cfg.vocab_size)
    cache_len = args.prompt_len + args.new_tokens
    batch = {"tokens": prompts}
    if cfg.task == "vlm":
        batch["image_feats"] = jax.random.normal(
            jax.random.PRNGKey(2), (n, 8, cfg.frontend_dim))
    n_prefix = batch.get("image_feats", jnp.zeros((0, 0))).shape[1]

    expand = jax.jit(ps.expand)
    prefill = jax.jit(ps.prefill, static_argnums=(2,))
    decode = jax.jit(ps.decode_step, donate_argnums=(1,))

    t0 = time.time()
    stacked = expand(bank, w, ids)
    jax.block_until_ready(stacked)
    print(f"[serve] expand {n} clients (d_delta={dspec.dim}, "
          f"{100 * dspec.dim / dspec.full.dim:.1f}% of D): "
          f"{time.time()-t0:.2f}s")

    t0 = time.time()
    logits, caches = prefill(stacked, batch, cache_len)
    toks = logits[:, -1].argmax(-1).astype(jnp.int32)
    print(f"[serve] prefill {n}x{args.prompt_len}: {time.time()-t0:.2f}s")

    out = [toks]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        pos = jnp.int32(n_prefix + args.prompt_len + i)
        logits_i, caches = decode(stacked, caches, toks, pos)
        toks = logits_i.argmax(-1).astype(jnp.int32)
        out.append(toks)
    dt = time.time() - t0
    print(f"[serve] personalized {args.new_tokens - 1} steps: "
          f"{1e3 * dt / max(args.new_tokens - 1, 1):.1f} ms/step")
    print(jnp.stack(out, axis=1))


if __name__ == "__main__":
    main()
