"""Production training launcher: pods-as-clients DFedSGPSM.

On real hardware every pod's (data, model) submesh shards one replica and
the directed push-sum gossip crosses pods; on this container pass
``--host-mesh`` to run the identical program on forced host devices.
``--superstep N`` scans N rounds device-resident inside one jit (donated
carry) and only returns to the host at superstep boundaries for logging
and checkpointing; ``--resume`` restarts either driver from the latest
full round-state checkpoint.

  PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m \
      --host-mesh --rounds 6 --superstep 3 --smoke

``--paged`` switches to the virtual-client-population driver instead: a
population of ``--n-clients`` synthetic-MNIST clients lives in a
disk-backed store under ``--store-dir`` and each round pages in only the
``--k-active`` sampled clients plus their in-neighbors (background
prefetch, async write-back).  The checkpoint is the store itself;
``--resume`` reopens it and continues bit-identically.

  PYTHONPATH=src python -m repro.launch.train --paged --n-clients 4096 \
      --k-active 256 --rounds 3 --store-dir /tmp/pop
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np


def _paged_main(args):
    """Virtual-client-population driver: disk-backed store, paged rounds."""
    from repro.core.engine import FLTrainer, make_algo
    from repro.core.topology import TopologyConfig
    from repro.data.dirichlet import dirichlet_partition, stack_client_data
    from repro.data.synthetic import DatasetSpec, make_dataset
    from repro.models.small import tiny_mlp
    from repro.store import ClientStore

    if not args.store_dir:
        raise SystemExit("--paged requires --store-dir")
    if ClientStore.exists(args.store_dir) and not args.resume:
        raise SystemExit(
            f"{args.store_dir} already holds a client store; pass --resume "
            "to continue it or point --store-dir somewhere fresh"
        )
    n = args.n_clients
    spec = DatasetSpec("toy", (32,), 10, margin=3.0)
    train, _ = make_dataset(spec, n * 8, 256, seed=0)
    parts = dirichlet_partition(train["y"], n, alpha=0.3, seed=0)
    cdata = stack_client_data(train, parts, pad_to=16)
    model = tiny_mlp(in_dim=32, n_classes=10)
    topo_kw = dict(kind=args.topology, n_clients=n, k_out=args.k_out)
    if args.topology == "two_tier":
        topo_kw["n_pods"] = max(n // 8, 2)
    elif args.topology in ("ring", "exponential"):
        topo_kw["k_out"] = 1
    topo = TopologyConfig(**topo_kw)
    algo = make_algo(
        "dfedsgpsm", local_steps=args.local_steps, batch_size=args.batch,
        lr=args.lr, alpha=args.alpha, rho=args.rho,
        compressor=args.compress, topk_ratio=args.topk_ratio,
    )
    churn = None
    if args.churn_fail > 0:
        from repro.core.topology import ChurnModel

        churn = ChurnModel(
            fail_prob=args.churn_fail, recover_prob=args.churn_recover,
            permanent_frac=args.churn_permanent,
            resurrect=args.churn_resurrect,
        )
    faults = None
    if args.io_eio > 0 or args.io_corrupt > 0 or args.io_torn > 0:
        from repro.store import FaultInjector

        faults = FaultInjector(
            seed=args.io_seed, eio_prob=args.io_eio,
            torn_write_prob=args.io_torn, corrupt_prob=args.io_corrupt,
        )
    trainer = FLTrainer(
        model.loss, model.init, cdata, algo, topo,
        paged=True, store_dir=args.store_dir, k_active=args.k_active,
        churn=churn, faults=faults,
    )
    runner = trainer.runner
    print(f"[train] paged population n={n} k_active={args.k_active} "
          f"topology={args.topology} resident<={runner.resident_rows} rows "
          f"(round {runner.round_index})")
    r0 = runner.round_index
    for i in range(args.rounds):
        t0 = time.time()
        m = trainer.run_round()
        live = (f" live={m['live_frac']:.2f}" if "live_frac" in m else "")
        print(f"[train] round {r0 + i:4d} loss={m['loss']:.4f} "
              f"acc={m['acc']:.4f} resident={int(m['rows_resident'])} "
              f"mass_err={m['w_mass_closure_err']:.2e}{live} "
              f"dt={time.time() - t0:.2f}s", flush=True)
    path = trainer.save()  # the checkpoint IS the store manifest
    stats = runner.stats.as_dict()
    mass = runner.total_mass()
    heal = ""
    if faults is not None:
        heal = (f" io_retries={stats['io_retries']} "
                f"corrupt_chunks={stats['corrupt_chunks']} "
                f"rebuilt_rows={stats['rebuilt_rows']}")
    print(f"[train] committed {path} at round {runner.round_index} | "
          f"total_mass={mass:.4f} "
          f"prefetch_hit_rate={stats['prefetch_hit_rate']:.3f} "
          f"rows_faulted/round={stats['rows_faulted_per_round']:.1f}{heal}")
    assert abs(mass - n) < 1e-3 * n
    runner.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8, help="per-pod batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--alpha", type=float, default=0.9)
    ap.add_argument("--rho", type=float, default=0.05)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--superstep", type=int, default=1,
                    help="rounds per jit-resident lax.scan chunk: the whole "
                         "chunk runs device-side in ONE dispatch and the "
                         "host is only touched at superstep boundaries "
                         "(logging + checkpointing); 1 = per-round dispatch")
    ap.add_argument("--compress", default="identity",
                    help="pod gossip compressor stage name (e.g. int8_rows, "
                         "topk_ef — stateful stages carry their residual "
                         "bank through the round and checkpoints)")
    ap.add_argument("--topk-ratio", type=float, default=0.05,
                    help="kept fraction per row for --compress topk_ef")
    ap.add_argument("--link-drop", type=float, default=0.0,
                    help="per-round i.i.d. failure probability of each "
                         "directed pod link; drops renormalize the graph "
                         "BEFORE the send, so it stays exactly "
                         "column-stochastic and no push-sum mass leaks")
    ap.add_argument("--link-delay", type=int, default=0,
                    help="staleness bound B: each surviving link delivers "
                         "0..B rounds late; in-flight payloads ride the "
                         "round state (and checkpoints), node + in-flight "
                         "mass == n_pods exactly")
    ap.add_argument("--event-threshold", type=float, default=0.0,
                    help="event-triggered gossip: a pod retransmits only "
                         "after drifting this far (L2) from its last "
                         "broadcast; neighbors mix the cached row "
                         "otherwise (comm_fraction is logged)")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--host-mesh", action="store_true",
                    help="(2,2,2) mesh over 8 forced host devices")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="warm-restart from the latest checkpoint in "
                         "--ckpt-dir (params + momentum + w + round); with "
                         "--paged, reopen the store in --store-dir")
    ap.add_argument("--paged", action="store_true",
                    help="virtual client population: the (n, D) bank lives "
                         "in a disk-backed store and each round pages in "
                         "only the sampled clients + their in-neighbors")
    ap.add_argument("--n-clients", type=int, default=4096,
                    help="population size (--paged; disk-bounded, not RAM)")
    ap.add_argument("--k-active", type=int, default=256,
                    help="sampled clients per round (--paged)")
    ap.add_argument("--store-dir", default=None,
                    help="client-store directory (--paged; required)")
    ap.add_argument("--topology", default="kout",
                    choices=["ring", "exponential", "kout", "two_tier"],
                    help="graph family of the paged population")
    ap.add_argument("--k-out", type=int, default=2,
                    help="out-degree for kout/two_tier (--paged)")
    ap.add_argument("--churn-fail", type=float, default=0.0,
                    help="per-round node failure probability (--paged): "
                         "dead clients leave the sampling pool, their "
                         "push-sum mass stays frozen on disk; live + "
                         "frozen mass == n exactly")
    ap.add_argument("--churn-recover", type=float, default=0.0,
                    help="per-round resurrection probability of a "
                         "transiently-dead client")
    ap.add_argument("--churn-permanent", type=float, default=0.0,
                    help="fraction of failures that are permanent "
                         "(never resurrect)")
    ap.add_argument("--churn-resurrect", default="warm",
                    choices=["warm", "cold"],
                    help="warm = resume the stored row; cold = restart "
                         "from the init template (mass kept bit-for-bit)")
    ap.add_argument("--io-eio", type=float, default=0.0,
                    help="injected transient read-fault probability "
                         "(--paged; absorbed by bounded-backoff retries)")
    ap.add_argument("--io-torn", type=float, default=0.0,
                    help="injected torn-write probability (--paged)")
    ap.add_argument("--io-corrupt", type=float, default=0.0,
                    help="injected post-write bit-flip probability "
                         "(--paged; caught by chunk checksums)")
    ap.add_argument("--io-seed", type=int, default=0,
                    help="fault-injector PRNG seed")
    args = ap.parse_args()

    if args.paged:
        return _paged_main(args)

    if args.host_mesh and "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_count=8")

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.runtime import enable_compilation_cache

    enable_compilation_cache()

    from repro import checkpoint
    from repro.configs.registry import get_config
    from repro.data.synthetic import make_lm_stream
    from repro.kernels import ops as kops
    from repro.launch import sharding as shlib
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.launch.steps import (
        StepConfig,
        init_pod_comp_state,
        init_pod_link_state,
        make_round_step,
        pod_mixing_matrix,
        pod_mixing_neighbors,
        resolve_compressor,
        resolve_pod_link,
        resolve_pod_mixer,
    )
    from repro.models.pdefs import PDef
    from repro.models.registry import get_model_api

    mesh = (make_host_mesh((2, 2, 2), ("pod", "data", "model"))
            if args.host_mesh else make_production_mesh(multi_pod=True))
    n_pods = mesh.shape["pod"]
    cfg = get_config(args.arch, smoke=args.smoke)
    api = get_model_api(cfg)
    step_cfg = StepConfig(lr=args.lr, alpha=args.alpha, rho=args.rho,
                          local_steps=args.local_steps,
                          microbatches=args.microbatches,
                          compressor=args.compress,
                          topk_ratio=args.topk_ratio,
                          link_drop=args.link_drop,
                          link_delay=args.link_delay,
                          event_threshold=args.event_threshold)
    compressor = resolve_compressor(step_cfg)
    link_model = resolve_pod_link(step_cfg)
    mixer = resolve_pod_mixer(step_cfg, link_model)
    raw_round = make_round_step(api, step_cfg, mixer=mixer,
                                compressor=compressor, link_model=link_model)
    round_step = jax.jit(raw_round, donate_argnums=(0, 1, 3, 4))

    def _mass(w, link):
        """Total push-sum mass: node weights + any in-flight shares."""
        inflight = (link.bufw.sum()
                    if link != () and not isinstance(link.bufw, tuple)
                    else 0.0)
        return w.sum() + inflight

    def _superstep(params, v, w, comp, link, toks_chunk, P_pod):
        """lax.scan a whole superstep of rounds inside one jit; per-round
        (loss, acc, w-mass) come back stacked for boundary logging."""

        def body(carry, batch):
            params, v, w, comp, link = carry
            params, v, w, comp, link, m = raw_round(
                params, v, w, comp, link, {"tokens": batch}, P_pod)
            return (params, v, w, comp, link), (
                m["loss"], m["acc"], _mass(w, link))

        (params, v, w, comp, link), ys = jax.lax.scan(
            body, (params, v, w, comp, link), toks_chunk)
        return params, v, w, comp, link, ys

    # One executable per distinct chunk length (at most two: the full
    # superstep and the final remainder).
    superstep_jit = jax.jit(_superstep, donate_argnums=(0, 1, 3, 4))

    with shlib.use_mesh(mesh, fsdp=cfg.fsdp):
        defs = api.param_defs()
        params = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_pods,) + x.shape),
            api.init(jax.random.PRNGKey(0)))

        def shard(x, d: PDef):
            spec = shlib.spec_for(d, mesh, fsdp=cfg.fsdp)
            return jax.device_put(x, NamedSharding(mesh, P("pod", *spec)))

        params = jax.tree.map(shard, params, defs,
                              is_leaf=lambda x: isinstance(x, PDef))
        v = jax.tree.map(jnp.zeros_like, params)
        w = jnp.ones((n_pods,))
        comp = init_pod_comp_state(compressor, params)
        link = init_pod_link_state(mixer, link_model, params)
        # Directed pod ring, k_max = 2: neighbor-list form once the pod
        # count clears the shared density rule, dense below it.
        P_pod = (pod_mixing_neighbors(n_pods)
                 if kops.use_sparse_gossip(n_pods, 2)
                 else pod_mixing_matrix(n_pods))
        toks = make_lm_stream(
            cfg.vocab_size, args.seq,
            args.rounds * n_pods * args.local_steps * args.batch)
        toks = toks.reshape(args.rounds, n_pods, args.local_steps,
                            args.batch, args.seq)

        start = 0
        if args.resume and args.ckpt_dir:
            path = checkpoint.latest_checkpoint(args.ckpt_dir)
            if path is not None:
                like = {"params": params, "v": v, "w": w,
                        "round": np.zeros((), np.int32)}
                if compressor.stateful:
                    # The EF residual bank is part of the round state; a
                    # ckpt recorded without it fails the structure check
                    # instead of silently restarting the residual at zero.
                    like["comp"] = comp
                if link != ():
                    # Same for the link carry: in-flight payloads / event
                    # caches resume instead of silently resetting.
                    like["link"] = link
                restored = checkpoint.restore(path, like=like)
                # Re-pin the restored (host) arrays to the live shardings so
                # the warm restart costs one device_put, not a re-partition.
                params = jax.tree.map(
                    lambda x, ref: jax.device_put(jnp.asarray(x), ref.sharding),
                    restored["params"], params)
                v = jax.tree.map(
                    lambda x, ref: jax.device_put(jnp.asarray(x), ref.sharding),
                    restored["v"], v)
                w = jnp.asarray(restored["w"])
                if compressor.stateful:
                    comp = jnp.asarray(restored["comp"])
                if link != ():
                    link = jax.tree.map(jnp.asarray, restored["link"])
                start = int(restored["round"]) + 1
                print(f"[train] resumed {path} at round {start} "
                      f"(momentum bank restored)")

        print(f"[train] {cfg.name} | {n_pods} pods x {mesh.shape} | "
              f"K={args.local_steps} rho={args.rho} alpha={args.alpha} "
              f"superstep={args.superstep}")
        r = start
        while r < args.rounds:
            length = min(max(args.superstep, 1), args.rounds - r)
            t0 = time.time()
            if args.superstep > 1:
                params, v, w, comp, link, (losses, accs, wmass) = \
                    superstep_jit(params, v, w, comp, link,
                                  toks[r:r + length], P_pod)
                dt = (time.time() - t0) / length
                for i in range(length):
                    print(f"[train] round {r + i:4d} "
                          f"loss={float(losses[i]):.4f} "
                          f"acc={float(accs[i]):.4f} "
                          f"w_mass={float(wmass[i]):.4f} dt={dt:.2f}s",
                          flush=True)
                ckpt_due = args.ckpt_dir is not None  # superstep boundary
            else:
                params, v, w, comp, link, m = round_step(
                    params, v, w, comp, link, {"tokens": toks[r]}, P_pod)
                comm = (f" comm={float(m['comm_fraction']):.2f}"
                        if "comm_fraction" in m else "")
                print(f"[train] round {r:4d} loss={float(m['loss']):.4f} "
                      f"acc={float(m['acc']):.4f} "
                      f"w_mass={float(_mass(w, link)):.4f}{comm} "
                      f"dt={time.time() - t0:.2f}s", flush=True)
                ckpt_due = args.ckpt_dir and (r + 1) % 5 == 0
            r += length
            if ckpt_due:
                # Full round state — momentum bank, round index, and any
                # compressor residual or link carry included, so restarts
                # of momentum-persistent / error-feedback / delayed-link
                # variants stay warm.
                tree = {"params": params, "v": v, "w": w,
                        "round": np.int32(r - 1)}
                if compressor.stateful:
                    tree["comp"] = comp
                if link != ():
                    tree["link"] = link
                checkpoint.save(args.ckpt_dir, r - 1, tree)
        # Exact mass conservation — in-flight shares included, so the
        # invariant holds under drops AND bounded delays.
        assert abs(float(_mass(w, link)) - n_pods) < 1e-3


if __name__ == "__main__":
    main()
