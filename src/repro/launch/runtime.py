"""Process-level jax runtime tuning shared by the bench/train entrypoints.

``enable_compilation_cache`` turns on jax's persistent compilation cache so
repeated bench/CI invocations of the same programs (the superstep scan, the
sharded round) stop paying the XLA recompile tax — the second run of a CI
job deserializes executables instead of rebuilding them.

The cache directory resolves, in order: an explicit argument, the standard
``JAX_COMPILATION_CACHE_DIR`` environment variable, then a stable per-user
default under the system temp dir.  Thresholds are dropped to zero so even
the small smoke programs cache (the defaults skip sub-second compiles,
which is most of a CPU CI run).
"""
from __future__ import annotations

import os
import tempfile

__all__ = ["enable_compilation_cache"]


def enable_compilation_cache(cache_dir: str | None = None) -> str | None:
    """Best-effort: returns the cache dir, or None when this jax build has
    no persistent-cache config (the run proceeds uncached)."""
    import jax

    cache_dir = (
        cache_dir
        or os.environ.get("JAX_COMPILATION_CACHE_DIR")
        or os.path.join(
            tempfile.gettempdir(), f"jax-cache-{os.environ.get('USER', 'ci')}"
        )
    )
    try:
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    except Exception:  # pragma: no cover - very old jax
        return None
    return cache_dir
