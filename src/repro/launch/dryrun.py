import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (arch x input-shape x mesh)
against the production mesh with ShapeDtypeStruct stand-ins (no allocation),
record memory / cost / collective analysis for the roofline report.

  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b \
      --shape train_4k --mesh single,multi
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import INPUT_SHAPES  # noqa: E402
from repro.configs.registry import ARCH_IDS, get_config, input_specs  # noqa: E402
from repro.launch import sharding as shlib  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import StepConfig, make_round_step, make_serve_step, make_train_step  # noqa: E402
from repro.models.pdefs import PDef, abstract_tree, tree_num_params  # noqa: E402
from repro.models.registry import get_model_api  # noqa: E402
from repro.roofline.analysis import model_flops, parse_collectives, roofline_terms  # noqa: E402

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def _skip_reason(cfg, shape) -> str | None:
    if shape.kind == "decode":
        if not cfg.supports_decode():
            return "encoder-only architecture: no autoregressive decode"
        if shape.name == "long_500k" and not cfg.supports_long_context():
            return "pure full-attention arch: long_500k needs sub-quadratic decode"
    return None


def _pod_spec(spec: P, batch_dims: tuple, shape_tuple: tuple, n_pods: int) -> P:
    """Widen a single-pod spec: shard batch over ("pod","data") when it
    divides; leave everything else untouched (=> replicated over pod)."""
    if n_pods <= 1:
        return spec
    out = list(spec) + [None] * (len(shape_tuple) - len(spec))
    for i in batch_dims:
        if out[i] == "data" and shape_tuple[i] % (16 * n_pods) == 0:
            out[i] = ("pod", "data")
    return P(*out)


def _model_axes(cfg):
    if cfg.attn_fallback == "replicate":
        return tuple(a for a in shlib.MODEL_AXES if a != "head_dim")
    return shlib.MODEL_AXES


def _abstract_params(api, mesh, multi_pod: bool, replicate_pods: bool):
    n_pods = mesh.shape.get("pod", 1)
    maxes = _model_axes(api.cfg)

    def sharding_fn(pdef: PDef):
        spec = shlib.spec_for(pdef, mesh, fsdp=api.cfg.fsdp, model_axes=maxes)
        if multi_pod and not replicate_pods:
            spec = P("pod", *spec)  # leading replica axis
        return NamedSharding(mesh, spec)

    defs = api.param_defs()
    if multi_pod and not replicate_pods:
        defs = jax.tree.map(
            lambda d: PDef((n_pods,) + d.shape, ("pod_rep",) + d.axes,
                           d.dtype, d.init, d.fan_in),
            defs, is_leaf=lambda x: isinstance(x, PDef))

        def sharding_fn(pdef: PDef):  # noqa: F811
            inner = PDef(pdef.shape[1:], pdef.axes[1:], pdef.dtype)
            spec = shlib.spec_for(inner, mesh, fsdp=api.cfg.fsdp,
                                  model_axes=maxes)
            return NamedSharding(mesh, P("pod", *spec))

    return abstract_tree(defs, sharding_fn)


def _abstract_batch(cfg, shape, mesh, multi_pod: bool, stacked: bool):
    """Returns abstract batch pytree for train/prefill kinds."""
    n_pods = mesh.shape.get("pod", 1)
    specs = input_specs(cfg, shape)
    out = {}
    for name, sds in specs.items():
        sh = sds.shape
        spec = ["data" if sh[0] % 16 == 0 else None] + [None] * (len(sh) - 1)
        if multi_pod and stacked:
            # (n_pods, K=1, local_batch, ...) for the round_step scan
            local = (sh[0] // n_pods,) + sh[1:]
            full = (n_pods, 1) + local
            pspec = P("pod", None, "data" if local[0] % 16 == 0 else None,
                      *([None] * (len(sh) - 1)))
            out[name] = jax.ShapeDtypeStruct(full, sds.dtype,
                                             sharding=NamedSharding(mesh, pspec))
        elif multi_pod:
            pspec = _pod_spec(P(*spec), (0,), sh, n_pods)
            out[name] = jax.ShapeDtypeStruct(sh, sds.dtype,
                                             sharding=NamedSharding(mesh, pspec))
        else:
            out[name] = jax.ShapeDtypeStruct(
                sh, sds.dtype, sharding=NamedSharding(mesh, P(*spec)))
    return out


def _abstract_cache(api, mesh, batch: int, length: int, multi_pod: bool):
    n_pods = mesh.shape.get("pod", 1)
    maxes = _model_axes(api.cfg)
    seq_shard = api.cfg.serve_cache_shard == "seq"

    def sharding_fn(pdef: PDef):
        if seq_shard and "seq" in pdef.axes:
            # distributed flash-decode layout: batch->data, seq->model
            spec = P(*["data" if a == "batch" and d % 16 == 0
                       else "model" if a == "seq" and d % 16 == 0
                       else None
                       for a, d in zip(pdef.axes, pdef.shape)])
        else:
            spec = shlib.spec_for(pdef, mesh, fsdp=False, model_axes=maxes)
        if multi_pod:
            bdims = tuple(i for i, a in enumerate(pdef.axes) if a == "batch")
            spec = _pod_spec(spec, bdims, pdef.shape, n_pods)
        return NamedSharding(mesh, spec)

    return abstract_tree(api.cache_defs(batch, length), sharding_fn)


def _trip_count(cfg) -> int:
    """Iterations of the layer-stack scan (xlstm scans over groups)."""
    if cfg.block_kind == "xlstm" and cfg.slstm_every:
        return cfg.n_layers // cfg.slstm_every
    return cfg.n_layers


def _lower_one(cfg, shape, mesh_kind: str, step_cfg):
    """Build abstract args + lower + compile one combination."""
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    api = get_model_api(cfg)
    with shlib.use_mesh(mesh, fsdp=cfg.fsdp):
        if shape.kind == "train" and multi:
            n_pods = mesh.shape["pod"]
            params = _abstract_params(api, mesh, True, replicate_pods=False)
            v = params
            w = jax.ShapeDtypeStruct((n_pods,), jnp.float32,
                                     sharding=NamedSharding(mesh, P("pod")))
            batch = _abstract_batch(cfg, shape, mesh, True, stacked=True)
            P_pod = jax.ShapeDtypeStruct((n_pods, n_pods), jnp.float32)
            # Abstract compressor carry: stateful stages (topk_ef) lower
            # with their (n_pods, D) residual bank, stateless with ().
            from repro.core.flat import make_spec
            from repro.launch.steps import resolve_compressor

            comp = ()
            if resolve_compressor(step_cfg).stateful:
                row_view = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                    params)
                comp = jax.ShapeDtypeStruct(
                    (n_pods, make_spec(row_view).dim), jnp.float32)
            fn = jax.jit(make_round_step(api, step_cfg), donate_argnums=(0, 1))
            lowered = fn.lower(params, v, w, comp, (), batch, P_pod)
        elif shape.kind == "train":
            params = _abstract_params(api, mesh, False, False)
            v = params
            w = jax.ShapeDtypeStruct((), jnp.float32)
            batch = _abstract_batch(cfg, shape, mesh, False, stacked=False)
            fn = jax.jit(make_train_step(api, step_cfg), donate_argnums=(0, 1))
            lowered = fn.lower(params, v, w, batch)
        elif shape.kind == "prefill":
            params = _abstract_params(api, mesh, multi, replicate_pods=True)
            batch = _abstract_batch(cfg, shape, mesh, multi, stacked=False)
            fn = jax.jit(lambda p, b: api.forward(p, b))
            lowered = fn.lower(params, batch)
        else:  # decode
            params = _abstract_params(api, mesh, multi, replicate_pods=True)
            cache = _abstract_cache(api, mesh, shape.global_batch,
                                    shape.seq_len, multi)
            toks = jax.ShapeDtypeStruct(
                (shape.global_batch,), jnp.int32,
                sharding=NamedSharding(
                    mesh,
                    _pod_spec(P("data" if shape.global_batch % 16 == 0 else None),
                              (0,), (shape.global_batch,),
                              mesh.shape.get("pod", 1))))
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            fn = jax.jit(make_serve_step(api), donate_argnums=(1,))
            lowered = fn.lower(params, cache, toks, pos)

        return lowered.compile(), mesh


def run_one(arch: str, shape_name: str, mesh_kind: str, step_cfg=None,
            overrides: dict = None) -> dict:
    import dataclasses

    base_cfg = get_config(arch)
    if overrides:
        base_cfg = dataclasses.replace(base_cfg, **overrides)
    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "kind": shape.kind, "status": "ok"}
    reason = _skip_reason(base_cfg, shape)
    if reason:
        rec.update(status="skip", reason=reason)
        return rec

    step_cfg = step_cfg or StepConfig()
    t0 = time.time()
    # XLA's cost_analysis counts a while-loop body once regardless of trip
    # count.  Lower twice (unroll=1 and unroll=2): the delta is one layer's
    # cost, extrapolated across the layer-stack trip count.  (For odd L the
    # remainder iteration is peeled; (c2-c1)/2 blends both bodies, ~15% noise
    # — fine for bottleneck identification.)
    cfg1 = dataclasses.replace(base_cfg, scan_unroll=1)
    cfg2 = dataclasses.replace(base_cfg, scan_unroll=2)
    compiled, mesh = _lower_one(cfg1, shape, mesh_kind, step_cfg)
    compiled2, _ = _lower_one(cfg2, shape, mesh_kind, step_cfg)
    n_chips = mesh.size
    cfg = base_cfg
    api = get_model_api(cfg)

    L = _trip_count(cfg)
    copies2 = 2 + (L % 2 if L > 1 else 0)

    def _extrap(x1, x2):
        if L <= 1:
            return x1
        body = max(x2 - x1, 0.0) / (copies2 - 1)
        return x1 + (L - 1) * body

    mem = compiled.memory_analysis()
    cost1 = compiled.cost_analysis() or {}
    cost2 = compiled2.cost_analysis() or {}
    cost = {
        "flops": _extrap(float(cost1.get("flops", 0) or 0),
                         float(cost2.get("flops", 0) or 0)),
        "bytes accessed": _extrap(
            float(cost1.get("bytes accessed", 0) or 0),
            float(cost2.get("bytes accessed", 0) or 0)),
    }
    coll1 = parse_collectives(compiled.as_text())
    coll2 = parse_collectives(compiled2.as_text())
    coll = coll1
    for kind in set(coll1.bytes_by_kind) | set(coll2.bytes_by_kind):
        b1 = coll1.bytes_by_kind.get(kind, 0)
        b2 = coll2.bytes_by_kind.get(kind, 0)
        c1 = coll1.count_by_kind.get(kind, 0)
        c2 = coll2.count_by_kind.get(kind, 0)
        coll.bytes_by_kind[kind] = int(_extrap(b1, b2))
        coll.count_by_kind[kind] = int(round(_extrap(c1, c2)))
    terms = roofline_terms(cost, coll)

    n_params = tree_num_params(api.param_defs())
    if cfg.n_experts:
        per_layer = 3 * cfg.d_model * cfg.d_ff
        active = n_params - cfg.n_layers * (cfg.n_experts - cfg.top_k) * per_layer
    else:
        active = n_params
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    mf = model_flops(active, tokens,
                     "train" if shape.kind == "train" else "fwd")
    hlo_total = terms["flops_per_device"] * n_chips
    rec.update(
        compile_s=round(time.time() - t0, 1),
        n_chips=n_chips,
        n_params=n_params,
        n_params_active=active,
        bytes_per_device={
            "argument": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "alias": mem.alias_size_in_bytes,
            "peak_estimate": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        roofline=terms,
        collectives={"bytes": coll.bytes_by_kind, "count": coll.count_by_kind},
        model_flops=mf,
        useful_flops_ratio=(mf / hlo_total) if hlo_total else None,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or comma list")
    ap.add_argument("--shape", default=None, help="shape name or comma list")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.environ.get("DRYRUN_OUT", DEFAULT_OUT))
    ap.add_argument("--set", default=None, dest="overrides",
                    help="cfg overrides for perf variants, e.g. "
                         "attn_fallback=replicate,fsdp=false")
    ap.add_argument("--tag", default=None, help="suffix for variant records")
    args = ap.parse_args()

    overrides = {}
    step_overrides = {}
    if args.overrides:
        for kv in args.overrides.split(","):
            k, v = kv.split("=")
            if v.lower() in ("true", "false"):
                v = v.lower() == "true"
            elif v.replace(".", "", 1).isdigit():
                v = float(v) if "." in v else int(v)
            if k in ("microbatches", "lr", "alpha", "rho", "local_steps"):
                step_overrides[k] = v
            else:
                overrides[k] = v
    step_cfg = StepConfig(**step_overrides) if step_overrides else None

    archs = list(ARCH_IDS) if (args.all or not args.arch) else args.arch.split(",")
    shapes = (list(INPUT_SHAPES) if (args.all or not args.shape)
              else args.shape.split(","))
    meshes = args.mesh.split(",")
    os.makedirs(args.out, exist_ok=True)

    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"{arch}__{shape}__{mesh_kind}"
                if args.tag:
                    tag += f"__{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[dryrun] {tag}: cached", flush=True)
                    continue
                print(f"[dryrun] {tag}: lowering...", flush=True)
                try:
                    rec = run_one(arch, shape, mesh_kind, step_cfg=step_cfg,
                                  overrides=overrides or None)
                except Exception as e:  # record failures — they are bugs
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "variant": args.tag,
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                if args.tag:
                    rec["variant"] = args.tag
                    rec["overrides"] = {**overrides, **step_overrides}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" tc={r['t_compute_s']:.3e}"
                             f" tm={r['t_memory_s']:.3e}"
                             f" tx={r['t_collective_s']:.3e}"
                             f" compile={rec['compile_s']}s")
                elif status == "error":
                    extra = " " + rec["error"][:160]
                print(f"[dryrun] {tag}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
