"""Sharded train / round / serve step builders for the pod runtime.

  train_step  — one paper-faithful DFedSGPSM inner iteration (de-bias by the
                push-sum weight, SAM two-pass gradient, local momentum,
                descent) for a single client (= pod), GSPMD-sharded
                (FSDP over "data", tensor/expert parallel over "model").
  round_step  — multi-pod: every pod runs a local step on its own replica
                (vmap with spmd_axis_name="pod"), then the directed
                column-stochastic push-sum gossip mixes replicas & weights
                across the "pod" axis.  No global all-reduce crosses pods.
  serve_step  — one-token decode against the sharded KV cache.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.sam import apply_update, momentum_update, sam_gradient
from repro.models.registry import ModelApi

__all__ = ["StepConfig", "make_train_step", "make_round_step", "make_serve_step",
           "PersonalizedServe", "make_personalized_serve_step",
           "pod_mixing_matrix", "pod_mixing_neighbors", "pod_comm_plan",
           "resolve_compressor",
           "init_pod_comp_state", "resolve_pod_mixer", "init_pod_link_state"]


@dataclasses.dataclass(frozen=True)
class StepConfig:
    """Local-optimizer hyperparameters for the pod runtime (Algorithm 1)."""

    lr: float = 1e-2
    alpha: float = 0.9  # local momentum
    rho: float = 0.05  # SAM radius (0 disables the second grad pass)
    local_steps: int = 1  # K inner iterations per communication round
    # Gradient-accumulation microbatches per step: the loss is evaluated as
    # a checkpointed scan over batch chunks, so the live activation set is
    # one chunk (peak memory / microbatches), at no extra HBM traffic.
    microbatches: int = 1
    # Communication stage for the pod gossip — a ``repro.core.stages``
    # COMPRESSORS name.  Stateful stages (e.g. topk_ef) work too: their
    # residual bank rides the round_step signature as the ``comp`` carry,
    # exactly like ``FLState.comp`` in the simulation engine.
    compressor: str = "identity"
    topk_ratio: float = 0.05  # kept fraction per row (topk_ef)
    # Unreliable pod interconnect (``repro.core.topology.LinkModel``):
    # per-round link drops on the pod graph, bounded delivery delays
    # (in-flight buffers ride the round_step ``link`` carry, exactly like
    # ``comp``), or event-triggered transmission.  All-zero = perfect
    # links, bitwise identical to the pre-link round.
    link_drop: float = 0.0
    link_delay: int = 0
    event_threshold: float = 0.0


def _microbatched_loss(loss_fn, n_micro: int):
    """Evaluate ``loss_fn`` as a checkpointed scan over equal batch chunks.

    The ``(ce, acc)`` aux is accumulated through the scan alongside the
    loss, so microbatched runs report the true metrics (equal-size chunks
    make the mean-of-chunk-means equal the whole-batch mean).
    """

    def loss(params, batch):
        chunks = jax.tree.map(
            lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
            batch)

        def body(carry, chunk):
            tot_l, tot_ce, tot_acc = carry
            l, (ce, acc) = loss_fn(params, chunk)
            return (tot_l + l, tot_ce + ce, tot_acc + acc), None

        zeros = (jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0))
        (total, ce, acc), _ = jax.lax.scan(
            jax.checkpoint(body), zeros, chunks)
        return total / n_micro, (ce / n_micro, acc / n_micro)

    return loss


def pod_mixing_matrix(n_pods: int) -> jnp.ndarray:
    """Directed-ring column-stochastic mixing over pods: each pod sends to
    its successor and keeps a self-loop (out-degree 2 -> weights 1/2)."""
    eye = jnp.eye(n_pods, dtype=jnp.float32)
    ring = jnp.roll(eye, 1, axis=0)
    return 0.5 * (eye + ring) if n_pods > 1 else eye


def pod_mixing_neighbors(n_pods: int):
    """:func:`pod_mixing_matrix` in neighbor-list form — the O(n_pods * D)
    representation for rings wide enough to clear the density rule
    (``repro.kernels.ops.use_sparse_gossip``); ``round_step`` accepts
    either for ``P_pod``."""
    from repro.core.topology import NeighborList, neighbors_ring

    if n_pods == 1:
        return NeighborList(
            jnp.zeros((1, 1), jnp.int32), jnp.ones((1, 1), jnp.float32)
        )
    return neighbors_ring(n_pods)


def resolve_compressor(step_cfg: StepConfig):
    """``step_cfg.compressor`` -> the ``repro.core.stages`` stage object."""
    from repro.core.stages import COMPRESSORS

    try:
        return COMPRESSORS[step_cfg.compressor](step_cfg)
    except KeyError:
        raise ValueError(
            f"unknown compressor stage {step_cfg.compressor!r}; "
            f"choose from {sorted(COMPRESSORS)}"
        ) from None


def init_pod_comp_state(compressor, params):
    """Initial compressor carry for the pod round: the ``(n_pods, D)``
    residual bank for stateful stages (D from the replicas' flat row
    width), ``()`` for stateless ones."""
    if not compressor.stateful:
        return ()
    from repro.core.flat import make_spec

    row_view = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), params)
    n_pods = jax.tree.leaves(params)[0].shape[0]
    return compressor.init_state(n_pods, make_spec(row_view).dim)


def resolve_pod_link(step_cfg: StepConfig):
    """``step_cfg``'s link fields -> a ``topology.LinkModel`` or ``None``
    (perfect links — the round is built exactly as before)."""
    from repro.core.topology import LinkModel

    model = LinkModel(drop=step_cfg.link_drop, delay=step_cfg.link_delay,
                      event_threshold=step_cfg.event_threshold)
    return model if model.active else None


def resolve_pod_mixer(step_cfg: StepConfig, link_model=None):
    """The pod mixer for a link scenario: delayed / event-triggered
    push-sum when the model asks for it, plain push-sum otherwise."""
    from repro.core.stages import (
        DelayedPushSumMixer,
        EventTriggeredMixer,
        PushSumMixer,
    )

    if link_model is None:
        link_model = resolve_pod_link(step_cfg)
    if link_model is not None and link_model.delay:
        return DelayedPushSumMixer(delay=link_model.delay)
    if link_model is not None and link_model.event_threshold:
        return EventTriggeredMixer(threshold=link_model.event_threshold)
    return PushSumMixer()


def init_pod_link_state(mixer, link_model, params, seed: int = 0):
    """Initial unreliable-link carry for the pod round (mirrors
    ``program.init``): ``()`` on perfect links, otherwise a
    ``stages.LinkState`` with its own PRNG stream and the mixer's payload
    buffers sized from the ``(n_pods, D)`` replica bank."""
    if link_model is None and not getattr(mixer, "link_stateful", False):
        return ()
    from repro.core.flat import make_spec
    from repro.core.stages import LinkState

    spec = make_spec(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), params))
    bank = spec.ravel_stacked(params)
    return LinkState(
        key=jax.random.fold_in(jax.random.PRNGKey(seed), 0x11AB),
        **mixer.link_buffers(bank),
    )


def make_train_step(api: ModelApi, step_cfg: StepConfig) -> Callable:
    """Single-client sharded local step: (params, v, w, batch) ->
    (params, v, metrics)."""

    loss_fn = (api.loss if step_cfg.microbatches <= 1
               else _microbatched_loss(api.loss, step_cfg.microbatches))

    def train_step(params, v, w, batch):
        z = jax.tree.map(lambda p: (p / w).astype(p.dtype), params)  # de-bias
        g, (loss, (_, acc)) = sam_gradient(loss_fn, z, batch, step_cfg.rho)
        v = momentum_update(v, g, step_cfg.alpha)
        params = apply_update(params, v, step_cfg.lr)
        return params, v, {"loss": loss, "acc": acc}

    return train_step


def pod_comm_plan(n_pods: int, n_shards: int):
    """The pod runtime's :class:`~repro.comm.plan.CommPlan`: the pod graph
    is the directed ring of :func:`pod_mixing_matrix`, so the plan is the
    ring family's static shift plan over the "pod" axis — one ppermute of
    the boundary rows per round instead of an all-gather of every pod's
    replica."""
    from repro.comm.plan import CommPlan
    from repro.core.topology import TopologyConfig

    return CommPlan.build(
        TopologyConfig(kind="ring", n_clients=n_pods, k_out=1),
        n_shards=n_shards,
    )


def make_round_step(
    api: ModelApi,
    step_cfg: StepConfig,
    flat_mix: bool = True,
    mixer=None,
    compressor=None,
    link_model=None,
    gossip: str = "auto",
) -> Callable:
    """Multi-pod DFL round: (stacked params, stacked v, w (n_pods,),
    comp, link, batch (n_pods, ...), P_pod) -> updated
    (params, v, w, comp, link) + mean {loss, acc} metrics.

    Every leaf carries a leading replica axis sharded over "pod";
    ``spmd_axis_name`` threads that axis through all internal sharding
    constraints so each pod's replica stays pod-local during local compute.

    The communication step is the same Compressor / Mixer stage pair the
    simulation engine composes (``repro.core.stages``): with ``flat_mix``
    (default) replicas are ravelled into an ``(n_pods, D)`` bank, run
    through ``compressor.apply`` (``step_cfg.compressor`` when not given
    explicitly), and mixed with one ``mixer.mix_round`` call — the flat
    gossip kernel, with the self-loop contribution kept full precision
    under compression.  ``comp`` is the compressor carry
    (``init_pod_comp_state``): the error-feedback residual bank for
    stateful stages like ``topk_ef``, ``()`` otherwise.  ``link`` is the
    unreliable-link carry (``init_pod_link_state``): per-round drop masks
    draw from its PRNG stream (``link_model`` /
    ``step_cfg.link_drop`` — applied to the pod graph *before* sender
    normalization, keeping it exactly column-stochastic) and the
    delayed-mixer in-flight buffers or event caches ride it, exactly like
    ``FLState.link`` in ``core/program.py``; ``()`` on perfect links.
    ``P_pod`` is the dense ``(n_pods, n_pods)`` matrix or a
    ``NeighborList`` (``pod_mixing_neighbors``); ``mixer`` defaults to the
    link-appropriate directed push-sum stage (``resolve_pod_mixer``); a
    ``SymmetricMixer`` swaps in doubly-stochastic gossip with fixed
    weights.

    ``gossip`` is the executor knob of the same dispatch rule the
    simulation engine uses (``repro.comm.plan.resolve_backend``), resolved
    at trace time against the active mesh's "pod" axis: ``"auto"`` keeps
    the size-based default, ``"xla"`` forces the partitionable all-gather
    form, ``"halo"`` forces the ring halo exchange (requires a directed
    mixer and ``P_pod = pod_mixing_neighbors(n_pods)`` — the pod graph the
    runtime defines, whose static :func:`pod_comm_plan` the executor ships).
    """
    from repro.core.stages import IdentityCompressor
    from repro.core.topology import NeighborList

    local = make_train_step(api, step_cfg)
    if link_model is None:
        link_model = resolve_pod_link(step_cfg)
    mixer = mixer if mixer is not None else resolve_pod_mixer(
        step_cfg, link_model)
    if compressor is None:
        compressor = resolve_compressor(step_cfg)
    linked = link_model is not None or getattr(mixer, "link_stateful", False)
    if gossip not in ("auto", "xla", "halo"):
        raise ValueError(
            f"pod gossip must be auto|xla|halo, got {gossip!r}"
        )
    if gossip == "halo" and mixer.kind != "directed":
        raise ValueError(
            "the pod halo executor ships the directed ring plan; "
            f"mixer kind {mixer.kind!r} has no pod halo form"
        )
    if gossip == "halo" and not flat_mix:
        raise ValueError("gossip='halo' requires flat_mix=True (bank layout)")
    if not flat_mix and not isinstance(compressor, IdentityCompressor):
        raise ValueError("compression requires flat_mix=True (bank layout)")
    if not flat_mix and linked:
        raise ValueError("link scenarios require flat_mix=True (bank layout)")
    if (link_model is not None and mixer.kind != "directed"
            and (link_model.delay or link_model.event_threshold)):
        # Same composition rule make_program enforces: staleness and
        # event triggering are push-sum constructions.
        raise ValueError(
            "delayed / event-triggered mixing is push-sum (directed) only; "
            f"the configured mixer is {mixer.kind!r}"
        )

    def one_pod(params, v, w, batches):
        def body(carry, batch):
            p, vv = carry
            p, vv, m = local(p, vv, w, batch)
            return (p, vv), (m["loss"], m["acc"])

        (params, v), (losses, accs) = jax.lax.scan(body, (params, v), batches)
        return params, v, losses.mean(), accs.mean()

    def mix_flat(params, w, comp, link, P_pod):
        from repro.core.flat import make_spec
        from repro.core.stages import comm_phase
        from repro.launch import sharding as shlib

        # Spec from the per-pod row view; only static shape/dtype is read.
        row_view = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), params)
        spec = make_spec(row_view)
        bank = spec.ravel_stacked(params)
        # The communication phase is the shared ``stages.comm_phase`` the
        # flat-bank round program drives — one GSPMD representation for
        # both runtimes.  ``bank_row_pins`` pins the bank's layout
        # explicitly: rows on "pod", columns gathered.  Without the pins
        # the SPMD partitioner mis-propagates shardings through the ravel
        # reshape/concat chain and silently corrupts the mix (it also logs
        # "Involuntary full rematerialization" while doing so).
        mesh = shlib.active_mesh()
        pin, pin_link = shlib.bank_row_pins(mesh, "pod")
        mx = mixer
        if gossip != "auto" and mesh is not None and "pod" in mesh.axis_names:
            # Same dispatch rule as the simulation engine: "xla" re-backs
            # onto the partitionable all-gather twin; "halo" onto the pod
            # ring's static shift plan (one boundary-row ppermute).
            n_pods = jax.tree.leaves(params)[0].shape[0]
            if gossip == "halo" and n_pods > 1 and mesh.shape["pod"] > 1:
                if not isinstance(P_pod, NeighborList):
                    raise ValueError(
                        "gossip='halo' needs the neighbor-list pod ring "
                        "(pod_mixing_neighbors), not a dense P_pod"
                    )
                from repro.comm.plan import HaloBackend

                # mix_flat traces under jit: the plan build samples the
                # ring neighbor list with jnp ops, which must evaluate
                # eagerly (the plan is static host-side metadata, not part
                # of the traced computation).
                with jax.ensure_compile_time_eval():
                    plan = pod_comm_plan(n_pods, mesh.shape["pod"])
                backend = HaloBackend(mesh, "pod", plan)
            else:
                # A single pod (or a 1-wide pod axis) has no cross-shard
                # halo to ship; the all-gather form is already local.
                backend = "xla"
            mx = dataclasses.replace(mixer, backend=backend)
        bank, w, comp, link, extras = comm_phase(
            compressor, mx, P_pod, bank, w, comp, link,
            linked=linked, link_model=link_model,
            symmetric=mixer.kind == "symmetric",
            pin=pin, pin_link=pin_link,
        )
        return spec.unravel_stacked(bank), w, comp, link, extras

    def mix_leafwise(params, w, comp, link, P_pod):
        if isinstance(P_pod, NeighborList):
            raise ValueError(
                "neighbor-list P_pod requires flat_mix=True (bank layout)")

        def mix(x):
            return jnp.einsum(
                "ij,j...->i...", P_pod, x.astype(jnp.float32)).astype(x.dtype)

        params = jax.tree.map(mix, params)
        return params, mixer.mix_weights(P_pod, w), comp, link, {}

    def round_step(params, v, w, comp, link, batch, P_pod):
        params, v, loss, acc = jax.vmap(one_pod, spmd_axis_name="pod")(
            params, v, w, batch)
        # compress + gossip over "pod" (same stages as the engine)
        params, w, comp, link, extras = (
            mix_flat if flat_mix else mix_leafwise)(
            params, w, comp, link, P_pod)
        return params, v, w, comp, link, {
            "loss": loss.mean(), "acc": acc.mean(), **extras}

    return round_step


def make_serve_step(api: ModelApi) -> Callable:
    """(params, cache, tokens (B,), pos ()) -> (logits, cache)."""

    def serve_step(params, cache, tokens, pos):
        return api.decode_step(params, cache, tokens, pos)

    return serve_step


class PersonalizedServe(NamedTuple):
    """Batched many-model serving over the client bank (see
    :func:`make_personalized_serve_step`)."""

    expand: Callable       # (bank, w, ids) -> client-stacked params
    prefill: Callable      # (params_stacked, batch, cache_len) -> (logits, caches)
    decode_step: Callable  # (params_stacked, caches, tokens (B,), pos) -> ...


def make_personalized_serve_step(api: ModelApi, spec) -> PersonalizedServe:
    """Serve many *different* clients' models in one batched decode.

    The bank is a personalization store: request lane ``b`` serves client
    ``ids[b]``, whose model is its bank row expanded onto the shared
    weights.  ``spec`` is the program's bank spec — a
    :class:`~repro.core.flat.BoundDeltaSpec` expands ``base + (A @ B) / w``
    per leaf (the frozen base is closed over once, as a jit constant, and
    only the narrow ``(B, d_delta)`` rows are gathered per batch); a plain
    dense :class:`~repro.core.flat.BankSpec` works too (``row / w``), it is
    just D-wide per lane.

    ``expand`` runs once per batch; ``prefill``/``decode_step`` vmap the
    model-zoo prefill/decode over (params-lane, cache-lane) with an inner
    batch of 1, so every lane decodes its own client's weights in the same
    XLA program — one dispatch per token for the whole multi-client batch.
    """

    def expand(bank, w, ids):
        rows = bank[ids]
        wv = (jnp.ones(ids.shape, jnp.float32) if w is None
              else w[ids].astype(jnp.float32))
        return jax.vmap(spec.debias)(rows, wv)

    def prefill(params_stacked, batch, cache_len):
        logits, caches = jax.vmap(
            lambda p, b: api.prefill(p, b, cache_len)
        )(params_stacked, jax.tree.map(lambda v: v[:, None], batch))
        return logits[:, 0], caches

    def decode_step(params_stacked, caches, tokens, pos):
        logits, caches = jax.vmap(
            api.decode_step, in_axes=(0, 0, 0, None)
        )(params_stacked, caches, tokens[:, None], pos)
        return logits[:, 0], caches

    return PersonalizedServe(expand, prefill, decode_step)
