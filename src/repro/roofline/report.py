"""Render the dry-run JSON records into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m repro.roofline.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

__all__ = ["load_records", "dryrun_table", "roofline_table"]

_SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_records(directory: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    recs.sort(key=lambda r: (r["arch"], _SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in _SHAPE_ORDER else 9, r["mesh"]))
    return recs


def _gb(x) -> str:
    return f"{x / 2**30:.2f}"


def dryrun_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | params | bytes/dev (arg+tmp) GiB | "
        "collectives (count) | compile s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skip":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP — "
                f"{r['reason']} | | | | |")
            continue
        if r["status"] == "error":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | **ERROR** "
                f"{r['error'][:80]} | | | | |")
            continue
        b = r["bytes_per_device"]
        colls = ", ".join(
            f"{k}×{v}" for k, v in sorted(r["collectives"]["count"].items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['n_params'] / 1e9:.1f}B | "
            f"{_gb(b['argument'])}+{_gb(b['temp'])} | {colls or '—'} | "
            f"{r['compile_s']} |")
    return "\n".join(lines)


def roofline_table(recs: list[dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck | "
        "MODEL_FLOPS | useful/HLO | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] != "ok":
            reason = r.get("reason", r.get("error", ""))[:60]
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | {r['status']}: "
                f"{reason} | | | |")
            continue
        t = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        note = _note(t)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['t_compute_s']:.2e} | "
            f"{t['t_memory_s']:.2e} | {t['t_collective_s']:.2e} | "
            f"**{t['bottleneck']}** | {r['model_flops']:.2e} | "
            f"{ratio:.2f} | {note} |")
    return "\n".join(lines)


def _note(t: dict) -> str:
    b = t["bottleneck"]
    if b == "collective":
        return "reduce gossip/FSDP bytes (shard-aware gossip, overlap)"
    if b == "memory":
        return "fuse elementwise passes / raise arithmetic intensity"
    return "near-roofline: increase per-chip batch or reduce redundant FLOPs"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load_records(args.dir)
    print("## Dry-run records\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(recs, "single"))
    print("\n## Roofline (multi-pod)\n")
    print(roofline_table(recs, "multi"))


if __name__ == "__main__":
    main()
