"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / link_bw

``compiled.cost_analysis()`` is per-device post-SPMD, so no further division
by chip count.  Collective bytes are not in cost_analysis: we parse the
compiled HLO and sum the *operand* sizes of every collective op, weighting
all-reduce 2x (ring reduce-scatter + all-gather phases).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.launch.mesh import HARDWARE

__all__ = ["CollectiveStats", "parse_collectives", "roofline_terms", "model_flops"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# matches e.g. f32[16,128]{1,0} or bf16[2,4,8]
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[\w\[\]{},]+)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\(",
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def weighted_bytes(self) -> int:
        """all-reduce moves ~2x its operand bytes on a ring."""
        return sum(
            b * (2 if k == "all-reduce" else 1)
            for k, b in self.bytes_by_kind.items()
        )


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # operand sizes: everything inside the call parentheses references
        # prior instructions; their shapes are not on this line, so use the
        # instruction's own (output) shape(s) — equal to operand size for
        # all-reduce / permute / all-to-all, and the gathered size for
        # all-gather (an upper bound on bytes moved).  Slicing up to the op
        # keyword keeps tuple-shaped outputs like (f32[8], f32[8]).
        eq = line.index("=") + 1 if "=" in line else 0
        head = line[eq:m.start(1)]
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + _shape_bytes(head)
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


def roofline_terms(cost: dict, coll: CollectiveStats, hw=None) -> dict:
    hw = hw or HARDWARE
    flops = float(cost.get("flops", 0.0) or 0.0)
    bytes_accessed = float(cost.get("bytes accessed", 0.0) or 0.0)
    t_compute = flops / hw["peak_flops_bf16"]
    t_memory = bytes_accessed / hw["hbm_bw"]
    t_coll = coll.weighted_bytes / hw["ici_bw"]
    terms = {
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll.weighted_bytes,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
    }
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    terms["bottleneck"] = dominant
    return terms


def model_flops(n_params_active: int, n_tokens: int, kind: str = "train") -> float:
    """6ND for training, 2ND for a forward/decode pass."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * n_tokens
