from repro.optim.sgd import exponential_decay, sgd_momentum_step, warmup_cosine

__all__ = ["exponential_decay", "sgd_momentum_step", "warmup_cosine"]
