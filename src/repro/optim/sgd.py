"""Minimal optimizer utilities shared by the FL engine and the pod runtime.

The paper's local optimizer is SGD(+momentum) wrapped by SAM; these helpers
keep the schedule/update math in one place (no external optax dependency).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["exponential_decay", "warmup_cosine", "sgd_momentum_step"]


def exponential_decay(base_lr: float, decay: float = 0.998):
    """Per-round decay used by all paper experiments (0.998 ** round)."""

    def schedule(step):
        return base_lr * decay ** jnp.asarray(step, jnp.float32)

    return schedule


def warmup_cosine(base_lr: float, warmup: int, total: int, floor: float = 0.1):
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return schedule


def sgd_momentum_step(params, v, grads, lr, alpha: float = 0.0):
    """v' = alpha v + g ; x' = x - lr v'  (pytree-wide, dtype-preserving)."""

    def upd(p, vi, g):
        v_new = alpha * vi.astype(jnp.float32) + g.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * v_new
        return p_new.astype(p.dtype), v_new.astype(vi.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_v = treedef.flatten_up_to(v)
    flat_g = treedef.flatten_up_to(grads)
    out = [upd(p, vi, g) for p, vi, g in zip(flat_p, flat_v, flat_g)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    return new_p, new_v
