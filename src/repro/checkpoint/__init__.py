from repro.checkpoint.io import (
    latest_checkpoint,
    restore,
    restore_bank,
    save,
    save_bank,
)

__all__ = ["save", "restore", "latest_checkpoint", "save_bank", "restore_bank"]
