from repro.checkpoint.io import latest_checkpoint, restore, save

__all__ = ["save", "restore", "latest_checkpoint"]
