from repro.checkpoint.io import (
    latest_checkpoint,
    restore,
    restore_bank,
    restore_state,
    save,
    save_bank,
    save_state,
)

__all__ = [
    "save",
    "restore",
    "latest_checkpoint",
    "save_bank",
    "restore_bank",
    "save_state",
    "restore_state",
]
