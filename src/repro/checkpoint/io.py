"""Dependency-free pytree checkpointing (npz payload + msgpack treedef).

Good enough for FL simulation state and pod-replica snapshots; atomic via
rename, with round-robin retention.
"""
from __future__ import annotations

import json
import os
import re
import tempfile

import jax
import numpy as np

__all__ = ["save", "restore", "latest_checkpoint"]

_STEP_RE = re.compile(r"ckpt_(\d+)\.npz$")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree.flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [np.asarray(v) for _, v in flat]
    return paths, leaves, treedef


def save(directory: str, step: int, tree, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    payload = {f"leaf_{i}": leaf for i, leaf in enumerate(leaves)}
    payload["__paths__"] = np.array(json.dumps(paths))
    final = os.path.join(directory, f"ckpt_{step}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **payload)
    os.replace(tmp, final)
    _retain(directory, keep)
    return final


def _retain(directory: str, keep: int):
    ckpts = sorted(
        (int(m.group(1)), f)
        for f in os.listdir(directory)
        if (m := _STEP_RE.search(f))
    )
    for _, f in ckpts[:-keep] if keep else []:
        os.remove(os.path.join(directory, f))


def latest_checkpoint(directory: str) -> str | None:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(
        (int(m.group(1)), f)
        for f in os.listdir(directory)
        if (m := _STEP_RE.search(f))
    )
    return os.path.join(directory, ckpts[-1][1]) if ckpts else None


def restore(path: str, like=None):
    """Restore a pytree. With ``like`` given, leaves are reshaped into the
    example's treedef (validating paths); otherwise a nested dict is built."""
    data = np.load(path, allow_pickle=False)
    paths = json.loads(str(data["__paths__"]))
    leaves = [data[f"leaf_{i}"] for i in range(len(paths))]
    if like is not None:
        ex_paths, _, treedef = _flatten_with_paths(like)
        if ex_paths != paths:
            raise ValueError("checkpoint structure mismatch")
        return jax.tree.unflatten(treedef, leaves)
    out: dict = {}
    for path, leaf in zip(paths, leaves):
        keys = [k.strip("[]'\".") for k in path.split("/")]
        node = out
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = leaf
    return out
