"""Dependency-free pytree checkpointing (npz payload + msgpack treedef).

Good enough for FL simulation state and pod-replica snapshots; atomic via
rename, with round-robin retention.  Flat client-parameter banks have a
dedicated fast path: the (n_clients, D) buffer rides as row-chunked arrays
(format v2) streamed to the archive one host-sized piece at a time — a
GSPMD row-sharded bank is never gathered whole on one host — plus the
leaf-offset metadata needed to unravel rows back into pytrees.  v1
checkpoints (one monolithic ``__bank__`` array) load transparently.

For paged (disk-backed) populations the checkpoint is the
:class:`repro.store.store.ClientStore` itself — its manifest commit, not
an npz; see :meth:`repro.store.paged.PagedRunner.save`.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
import zipfile

import jax
import numpy as np

__all__ = [
    "save",
    "restore",
    "latest_checkpoint",
    "save_bank",
    "restore_bank",
    "save_state",
    "restore_state",
]

_STEP_RE = re.compile(r"ckpt_(\d+)\.npz$")


def _to_host(v):
    """Gather a (possibly GSPMD row-sharded) device array to one host
    ndarray.  ``device_get`` assembles the shards before ``asarray``
    copies, so a mesh-sharded bank checkpoints as the same single array a
    single-device run writes."""
    if isinstance(v, jax.Array):
        v = jax.device_get(v)
    return np.asarray(v)


def _flatten_with_paths(tree):
    # jax.tree.flatten_with_path only exists in newer jax; use tree_util.
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [_to_host(v) for _, v in flat]
    return paths, leaves, treedef


def save(directory: str, step: int, tree, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    payload = {f"leaf_{i}": leaf for i, leaf in enumerate(leaves)}
    payload["__paths__"] = np.array(json.dumps(paths))
    final = os.path.join(directory, f"ckpt_{step}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **payload)
    os.replace(tmp, final)
    _retain(directory, keep)
    return final


def _retain(directory: str, keep: int):
    ckpts = sorted(
        (int(m.group(1)), f)
        for f in os.listdir(directory)
        if (m := _STEP_RE.search(f))
    )
    for _, f in ckpts[:-keep] if keep else []:
        os.remove(os.path.join(directory, f))


def latest_checkpoint(directory: str) -> str | None:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(
        (int(m.group(1)), f)
        for f in os.listdir(directory)
        if (m := _STEP_RE.search(f))
    )
    return os.path.join(directory, ckpts[-1][1]) if ckpts else None


def _spec_meta(spec) -> dict:
    """JSON-serializable leaf-offset metadata of a ``core.flat.BankSpec``
    (or the delta-row layout of a bound ``core.flat.DeltaBankSpec`` — the
    presence of the ``delta`` sub-dict is what distinguishes the two
    on disk)."""
    from repro.core.flat import BoundDeltaSpec

    if isinstance(spec, BoundDeltaSpec):
        d = spec.delta
        return {
            "paths": list(d.paths),
            "shapes": [list(s) for s in d.full.shapes],
            "dtypes": [str(x) for x in d.full.dtypes],
            "offsets": list(d.offsets),
            "sizes": list(d.sizes),
            "dim": d.dim,
            "dtype": str(d.dtype),
            "delta": {
                "modes": list(d.modes),
                "ranks": list(d.ranks),
                "asizes": list(d.asizes),
                "full_dim": d.full.dim,
                "full_offsets": list(d.full.offsets),
            },
        }
    dummy = spec.treedef.unflatten(list(range(spec.treedef.num_leaves)))
    flat, _ = jax.tree_util.tree_flatten_with_path(dummy)
    paths = ["/".join(str(k) for k in p) for p, _ in flat]
    return {
        "paths": paths,
        "shapes": [list(s) for s in spec.shapes],
        "dtypes": [str(d) for d in spec.dtypes],
        "offsets": list(spec.offsets),
        "sizes": list(spec.sizes),
        "dim": spec.dim,
        "dtype": str(spec.dtype),
    }


# Target host-staging size per streamed bank chunk; a checkpoint's peak
# extra host memory is ~one chunk, not the (n, D) bank.
_CHUNK_BYTES = 64 << 20


def _default_chunk_rows(rows: int, row_nbytes: int) -> int:
    return max(1, min(rows, _CHUNK_BYTES // max(row_nbytes, 1)))


def _write_member(zf: zipfile.ZipFile, name: str, arr: np.ndarray):
    """Stream one array into the archive as an ``.npy`` member (the layout
    ``np.load`` reads back as an NpzFile entry)."""
    with zf.open(name + ".npy", "w", force_zip64=True) as m:
        np.lib.format.write_array(m, np.asarray(arr), allow_pickle=False)


def _bank_like(v, rows: int) -> bool:
    """Row-bank extras (leading dim == n_clients, at least 2-D: momentum,
    EF residuals, link payload buffers) are chunked like the bank itself;
    scalars and (n,) vectors stay whole."""
    shape = getattr(v, "shape", ())
    return len(shape) >= 2 and shape[0] == rows


def save_bank(directory: str, step: int, bank, spec, extra=None,
              keep: int = 3, chunk_rows: int | None = None) -> str:
    """Checkpoint a flat (n_clients, D) parameter bank as row-chunked
    arrays plus its unravel metadata (leaf paths / shapes / dtypes /
    offsets).

    Format v2: the bank (and every bank-shaped extra) is sliced into
    ``chunk_rows``-row pieces, each fetched to the host and streamed into
    the archive independently — ``np.asarray(bank[lo:hi])`` on a GSPMD
    row-sharded bank transfers only that slice, so checkpointing no longer
    gathers the full population onto one host (the v1 OOM past ~10k rows).

    ``extra`` may hold auxiliary arrays (push-sum weights, momentum bank,
    round counter) saved alongside under their own keys.

    Format v3 (delta banks): the row-chunked layout is unchanged — the
    chunks simply hold ``(n, d_delta)`` adapter rows — plus one ``__base__``
    member carrying the frozen shared base ravelled under the *full* model
    spec, so a v3 checkpoint is self-contained and the restore can verify
    the program's base matches the one the rows were trained against.
    """
    from repro.core.flat import BoundDeltaSpec

    os.makedirs(directory, exist_ok=True)
    rows = int(bank.shape[0]) if bank.ndim >= 2 else 0
    row_nbytes = int(np.prod(bank.shape[1:], initial=1)) * bank.dtype.itemsize
    cr = int(chunk_rows) if chunk_rows else _default_chunk_rows(
        max(rows, 1), row_nbytes)
    meta = _spec_meta(spec)
    extra = extra or {}
    chunked_extras = sorted(
        k for k, v in extra.items() if rows and _bank_like(v, rows)
    )
    n_chunks = max(-(-rows // cr), 1) if rows else 1
    is_delta = isinstance(spec, BoundDeltaSpec)
    meta.update(format=3 if is_delta else 2, rows=rows, chunk_rows=cr,
                bank_chunks=n_chunks, extra_chunked=chunked_extras)

    final = os.path.join(directory, f"ckpt_{step}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        with zipfile.ZipFile(f, "w", zipfile.ZIP_STORED,
                             allowZip64=True) as zf:
            _write_member(zf, "__bank_meta__",
                          np.array(json.dumps(meta)))
            if is_delta:
                _write_member(zf, "__base__", _to_host(spec.base_row()))
            if rows:
                for i in range(n_chunks):
                    lo, hi = i * cr, min((i + 1) * cr, rows)
                    _write_member(zf, f"__bank_c{i:05d}__",
                                  _to_host(bank[lo:hi]))
            else:  # central-row checkpoints: a single (D,) "chunk"
                _write_member(zf, "__bank_c00000__", _to_host(bank))
            for k, v in extra.items():
                if k in chunked_extras:
                    for i in range(n_chunks):
                        lo, hi = i * cr, min((i + 1) * cr, rows)
                        _write_member(zf, f"extra_{k}_c{i:05d}",
                                      _to_host(v[lo:hi]))
                else:
                    _write_member(zf, f"extra_{k}", _to_host(v))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    _retain(directory, keep)
    return final


def _gather_chunks(data, names) -> np.ndarray:
    parts = [data[n] for n in names]
    return parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)


def restore_bank(path: str, spec=None):
    """Restore ``(bank, extra, meta)`` saved by :func:`save_bank`.

    Reads v3 (base + delta rows), v2 (row-chunked) and legacy v1
    (monolithic ``__bank__``) checkpoints.  With ``spec`` given, the stored
    offset metadata is validated against it (mismatched model structure
    raises ``ValueError``); a delta spec additionally checks the stored
    ``__base__`` against its own frozen base — adapter rows over a
    different base are silent garbage, so drift is an error, not a
    warning.
    """
    data = np.load(path, allow_pickle=False)
    v2 = "__bank_c00000__" in data.files
    if not v2 and "__bank__" not in data.files:
        raise ValueError(f"{path} is not a flat-bank checkpoint")
    meta = json.loads(str(data["__bank_meta__"]))
    if spec is not None:
        from repro.core.flat import BoundDeltaSpec

        want = _spec_meta(spec)
        want_delta = isinstance(spec, BoundDeltaSpec)
        if want_delta != ("delta" in meta):
            stored = "delta-bank (v3)" if "delta" in meta else "dense-bank"
            mine = "delta-bank" if want_delta else "dense-bank"
            raise ValueError(
                f"bank checkpoint structure mismatch: {path} is a {stored} "
                f"checkpoint but the restoring spec is {mine} — restore "
                "with the bank representation that saved it"
            )
        keys = ("offsets", "shapes", "dtypes", "dim", "dtype")
        if any(want[k] != meta[k] for k in keys) or (
            want_delta and want["delta"] != meta["delta"]
        ):
            raise ValueError("bank checkpoint structure mismatch")
        if want_delta:
            stored_base = data["__base__"]
            base = _to_host(spec.base_row())
            if stored_base.shape != base.shape or not np.allclose(
                stored_base.astype(np.float64), base.astype(np.float64),
                rtol=1e-5, atol=1e-6,
            ):
                raise ValueError(
                    f"delta-bank checkpoint base mismatch: {path} was saved "
                    "over a different frozen base than this program's — "
                    "adapter rows are meaningless over another base"
                )
    if not v2:
        extra = {
            k[len("extra_"):]: data[k]
            for k in data.files if k.startswith("extra_")
        }
        return data["__bank__"], extra, meta
    n_chunks = int(meta["bank_chunks"])
    bank = _gather_chunks(
        data, [f"__bank_c{i:05d}__" for i in range(n_chunks)]
    )
    extra = {}
    for k in meta.get("extra_chunked", ()):
        extra[k] = _gather_chunks(
            data, [f"extra_{k}_c{i:05d}" for i in range(n_chunks)]
        )
    chunk_re = re.compile(r"^extra_(.+)_c\d{5}$")
    for f in data.files:
        if (not f.startswith("extra_")) or chunk_re.match(f):
            continue
        extra[f[len("extra_"):]] = data[f]
    return bank, extra, meta


def save_state(directory: str, step: int, state, spec, keep: int = 3) -> str:
    """Checkpoint a full ``repro.core.FLState`` through the bank fast path.

    The params bank rides as ``__bank__``; momentum bank, push-sum weights,
    RNG key, round counter, last losses, any array-valued compressor
    state (e.g. the top-k error-feedback residual), the unreliable-link
    carry (PRNG stream + in-flight payload buffers / event caches), and
    the node-churn carry (PRNG stream + (n,) liveness vector + optional
    cold-resurrection template row) ride as extras — so a restore is a
    genuinely warm restart, not just a parameter copy.
    """
    extra = {
        "w": state.w,
        "key": state.key,
        "round": state.round,
        "losses": state.losses,
    }
    if state.mom is not None:
        extra["mom"] = state.mom
    if state.comp is not None and not (
        isinstance(state.comp, tuple) and state.comp == ()
    ):
        extra["comp"] = state.comp
    link = getattr(state, "link", ())
    if not (isinstance(link, tuple) and link == ()):
        extra["link_key"] = link.key
        for field in ("bufx", "bufw", "last"):
            val = getattr(link, field)
            if not isinstance(val, tuple):
                extra[f"link_{field}"] = val
    churn = getattr(state, "churn", ())
    if not (isinstance(churn, tuple) and churn == ()):
        extra["churn_key"] = churn.key
        extra["churn_live"] = churn.live
        if not isinstance(churn.tpl, tuple):
            extra["churn_tpl"] = churn.tpl
    return save_bank(directory, step, state.params, spec, extra=extra,
                     keep=keep)


def restore_state(path: str, spec):
    """Restore the full ``FLState`` saved by :func:`save_state`."""
    import jax.numpy as jnp

    from repro.core.program import FLState
    from repro.core.stages import ChurnState, LinkState

    bank, extra, _ = restore_bank(path, spec=spec)
    for k in ("w", "key", "round", "losses"):
        if k not in extra:
            raise ValueError(f"{path} is not a full-FLState checkpoint "
                             f"(missing {k!r})")
    link = ()
    if "link_key" in extra:
        link = LinkState(
            key=jnp.asarray(extra["link_key"]),
            **{f: jnp.asarray(extra[f"link_{f}"])
               for f in ("bufx", "bufw", "last")
               if f"link_{f}" in extra},
        )
    churn = ()
    if "churn_key" in extra:
        churn = ChurnState(
            key=jnp.asarray(extra["churn_key"]),
            live=jnp.asarray(extra["churn_live"]),
            tpl=(jnp.asarray(extra["churn_tpl"])
                 if "churn_tpl" in extra else ()),
        )
    return FLState(
        params=jnp.asarray(bank),
        mom=jnp.asarray(extra["mom"]) if "mom" in extra else None,
        w=jnp.asarray(extra["w"]),
        key=jnp.asarray(extra["key"]),
        round=jnp.asarray(extra["round"]),
        losses=jnp.asarray(extra["losses"]),
        comp=jnp.asarray(extra["comp"]) if "comp" in extra else (),
        link=link,
        churn=churn,
    )


def restore(path: str, like=None):
    """Restore a pytree. With ``like`` given, leaves are reshaped into the
    example's treedef (validating paths); otherwise a nested dict is built."""
    data = np.load(path, allow_pickle=False)
    paths = json.loads(str(data["__paths__"]))
    leaves = [data[f"leaf_{i}"] for i in range(len(paths))]
    if like is not None:
        ex_paths, _, treedef = _flatten_with_paths(like)
        if ex_paths != paths:
            raise ValueError("checkpoint structure mismatch")
        return jax.tree.unflatten(treedef, leaves)
    out: dict = {}
    for path, leaf in zip(paths, leaves):
        keys = [k.strip("[]'\".") for k in path.split("/")]
        node = out
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = leaf
    return out
