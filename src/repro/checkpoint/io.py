"""Dependency-free pytree checkpointing (npz payload + msgpack treedef).

Good enough for FL simulation state and pod-replica snapshots; atomic via
rename, with round-robin retention.  Flat client-parameter banks have a
dedicated fast path: the whole (n_clients, D) buffer is one npz array plus
the leaf-offset metadata needed to unravel rows back into pytrees.
"""
from __future__ import annotations

import json
import os
import re
import tempfile

import jax
import numpy as np

__all__ = [
    "save",
    "restore",
    "latest_checkpoint",
    "save_bank",
    "restore_bank",
    "save_state",
    "restore_state",
]

_STEP_RE = re.compile(r"ckpt_(\d+)\.npz$")


def _to_host(v):
    """Gather a (possibly GSPMD row-sharded) device array to one host
    ndarray.  ``device_get`` assembles the shards before ``asarray``
    copies, so a mesh-sharded bank checkpoints as the same single array a
    single-device run writes."""
    if isinstance(v, jax.Array):
        v = jax.device_get(v)
    return np.asarray(v)


def _flatten_with_paths(tree):
    # jax.tree.flatten_with_path only exists in newer jax; use tree_util.
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [_to_host(v) for _, v in flat]
    return paths, leaves, treedef


def save(directory: str, step: int, tree, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    payload = {f"leaf_{i}": leaf for i, leaf in enumerate(leaves)}
    payload["__paths__"] = np.array(json.dumps(paths))
    final = os.path.join(directory, f"ckpt_{step}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **payload)
    os.replace(tmp, final)
    _retain(directory, keep)
    return final


def _retain(directory: str, keep: int):
    ckpts = sorted(
        (int(m.group(1)), f)
        for f in os.listdir(directory)
        if (m := _STEP_RE.search(f))
    )
    for _, f in ckpts[:-keep] if keep else []:
        os.remove(os.path.join(directory, f))


def latest_checkpoint(directory: str) -> str | None:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(
        (int(m.group(1)), f)
        for f in os.listdir(directory)
        if (m := _STEP_RE.search(f))
    )
    return os.path.join(directory, ckpts[-1][1]) if ckpts else None


def _spec_meta(spec) -> dict:
    """JSON-serializable leaf-offset metadata of a ``core.flat.BankSpec``."""
    dummy = spec.treedef.unflatten(list(range(spec.treedef.num_leaves)))
    flat, _ = jax.tree_util.tree_flatten_with_path(dummy)
    paths = ["/".join(str(k) for k in p) for p, _ in flat]
    return {
        "paths": paths,
        "shapes": [list(s) for s in spec.shapes],
        "dtypes": [str(d) for d in spec.dtypes],
        "offsets": list(spec.offsets),
        "sizes": list(spec.sizes),
        "dim": spec.dim,
        "dtype": str(spec.dtype),
    }


def save_bank(directory: str, step: int, bank, spec, extra=None,
              keep: int = 3) -> str:
    """Checkpoint a flat (n_clients, D) parameter bank as ONE array plus
    its unravel metadata (leaf paths / shapes / dtypes / offsets).

    ``extra`` may hold small auxiliary arrays (push-sum weights, momentum
    bank, round counter) saved alongside under their own keys.
    """
    os.makedirs(directory, exist_ok=True)
    payload = {"__bank__": _to_host(bank)}
    payload["__bank_meta__"] = np.array(json.dumps(_spec_meta(spec)))
    for k, v in (extra or {}).items():
        payload[f"extra_{k}"] = _to_host(v)
    final = os.path.join(directory, f"ckpt_{step}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **payload)
    os.replace(tmp, final)
    _retain(directory, keep)
    return final


def restore_bank(path: str, spec=None):
    """Restore ``(bank, extra, meta)`` saved by :func:`save_bank`.

    With ``spec`` given, the stored offset metadata is validated against it
    (mismatched model structure raises ``ValueError``).
    """
    data = np.load(path, allow_pickle=False)
    if "__bank__" not in data:
        raise ValueError(f"{path} is not a flat-bank checkpoint")
    meta = json.loads(str(data["__bank_meta__"]))
    if spec is not None:
        want = _spec_meta(spec)
        keys = ("offsets", "shapes", "dtypes", "dim", "dtype")
        if any(want[k] != meta[k] for k in keys):
            raise ValueError("bank checkpoint structure mismatch")
    extra = {
        k[len("extra_"):]: data[k] for k in data.files if k.startswith("extra_")
    }
    return data["__bank__"], extra, meta


def save_state(directory: str, step: int, state, spec, keep: int = 3) -> str:
    """Checkpoint a full ``repro.core.FLState`` through the bank fast path.

    The params bank rides as ``__bank__``; momentum bank, push-sum weights,
    RNG key, round counter, last losses, any array-valued compressor
    state (e.g. the top-k error-feedback residual), and the unreliable-link
    carry (PRNG stream + in-flight payload buffers / event caches) ride as
    extras — so a restore is a genuinely warm restart, not just a
    parameter copy.
    """
    extra = {
        "w": state.w,
        "key": state.key,
        "round": state.round,
        "losses": state.losses,
    }
    if state.mom is not None:
        extra["mom"] = state.mom
    if state.comp is not None and not (
        isinstance(state.comp, tuple) and state.comp == ()
    ):
        extra["comp"] = state.comp
    link = getattr(state, "link", ())
    if not (isinstance(link, tuple) and link == ()):
        extra["link_key"] = link.key
        for field in ("bufx", "bufw", "last"):
            val = getattr(link, field)
            if not isinstance(val, tuple):
                extra[f"link_{field}"] = val
    return save_bank(directory, step, state.params, spec, extra=extra,
                     keep=keep)


def restore_state(path: str, spec):
    """Restore the full ``FLState`` saved by :func:`save_state`."""
    import jax.numpy as jnp

    from repro.core.program import FLState
    from repro.core.stages import LinkState

    bank, extra, _ = restore_bank(path, spec=spec)
    for k in ("w", "key", "round", "losses"):
        if k not in extra:
            raise ValueError(f"{path} is not a full-FLState checkpoint "
                             f"(missing {k!r})")
    link = ()
    if "link_key" in extra:
        link = LinkState(
            key=jnp.asarray(extra["link_key"]),
            **{f: jnp.asarray(extra[f"link_{f}"])
               for f in ("bufx", "bufw", "last")
               if f"link_{f}" in extra},
        )
    return FLState(
        params=jnp.asarray(bank),
        mom=jnp.asarray(extra["mom"]) if "mom" in extra else None,
        w=jnp.asarray(extra["w"]),
        key=jnp.asarray(extra["key"]),
        round=jnp.asarray(extra["round"]),
        losses=jnp.asarray(extra["losses"]),
        comp=jnp.asarray(extra["comp"]) if "comp" in extra else (),
        link=link,
    )


def restore(path: str, like=None):
    """Restore a pytree. With ``like`` given, leaves are reshaped into the
    example's treedef (validating paths); otherwise a nested dict is built."""
    data = np.load(path, allow_pickle=False)
    paths = json.loads(str(data["__paths__"]))
    leaves = [data[f"leaf_{i}"] for i in range(len(paths))]
    if like is not None:
        ex_paths, _, treedef = _flatten_with_paths(like)
        if ex_paths != paths:
            raise ValueError("checkpoint structure mismatch")
        return jax.tree.unflatten(treedef, leaves)
    out: dict = {}
    for path, leaf in zip(paths, leaves):
        keys = [k.strip("[]'\".") for k in path.split("/")]
        node = out
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = leaf
    return out
