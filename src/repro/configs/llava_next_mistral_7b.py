"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

The SigLIP/CLIP vision tower is a stub per the task carve-out:
``input_specs()`` provides anyres patch embeddings (2880 tokens, dim 1024);
we implement the projector MLP + the Mistral decoder that consumes them.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    task="vlm",
    frontend_dim=1024,
    n_frontend_tokens=2880,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
