"""Hymba 1.5B [arXiv:2411.13676] — parallel attention + SSM heads per layer,
128 meta tokens, sliding-window attention with 3 global layers."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    block_kind="hymba",
    ssm_state=16,
    ssm_expand=2,
    n_meta_tokens=128,
    sliding_window=1024,
    global_layers=(0, 15, 31),
    source="arXiv:2411.13676",
)
