"""DeepSeek-V3 671B [arXiv:2412.19437] — MLA attention (low-rank q/kv with
decoupled RoPE), MoE with 1 shared + 256 routed experts (top-8, sigmoid
gating).  MTP head is out of scope (see DESIGN.md)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    vocab_size=129280,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    n_experts=256,
    n_shared_experts=1,
    top_k=8,
    source="arXiv:2412.19437",
)
