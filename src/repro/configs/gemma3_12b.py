"""Gemma-3 12B [hf:google/gemma-3-1b-pt family] — dense decoder, 5:1
local:global sliding-window pattern (window 1024), dual rope thetas,
qk-norm, tied embeddings, 262k vocab."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=256,
    sliding_window=1024,
    global_every=6,
    rope_theta=10_000.0,
    global_rope_theta=1_000_000.0,
    qk_norm=True,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)
