"""Architecture & input-shape schema for the assigned model pool."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "reduced"]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One selectable architecture (``--arch <name>``).

    ``block_kind`` picks the layer family:
      transformer — (GQA|MLA) attention + (dense|MoE) MLP
      xlstm       — mLSTM/sLSTM blocks
      hymba       — parallel attention + SSM heads, meta tokens
    ``task`` picks the loss/inputs: lm | masked_lm (audio) | vlm.
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    block_kind: str = "transformer"
    task: str = "lm"
    causal: bool = True
    # --- attention ---
    attn_type: str = "gqa"  # gqa | mla
    rope_theta: float = 10_000.0
    global_rope_theta: float = 0.0  # gemma3: separate theta for global layers
    # per-layer sliding window: (local_window, global_every) — every
    # ``global_every``-th layer is global (window 0 = unbounded).
    sliding_window: int = 0
    global_every: int = 0
    global_layers: tuple = ()  # explicit full-attention layer indices (hymba)
    qk_norm: bool = False
    # --- MLA (deepseek) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0
    mlp_act: str = "swiglu"  # swiglu | gelu
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_impl: str = "gshard"  # gshard (capacity einsum) | dense (exact ref)
    # position-in-expert computation inside the gshard dispatch:
    #   "cumsum" — one-hot cumsum over (B, S*k, E): simple but O(T*E) memory
    #   "sort"   — stable argsort + per-expert offsets: O(T) memory
    moe_pos: str = "cumsum"
    # dtype of the dispatch/combine one-hot tensors ("f32" | "bf16")
    moe_dispatch_dtype: str = "f32"
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_expand: int = 1
    n_meta_tokens: int = 0
    slstm_every: int = 0  # xlstm: every k-th layer is sLSTM (0 = none)
    # --- vlm / audio stubs ---
    frontend_dim: int = 0  # patch/frame embedding dim provided by the stub
    n_frontend_tokens: int = 0
    # --- numerics / runtime ---
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    # lax.scan unroll factor for the layer stack.  1 = rolled while-loop
    # (fast compiles); >=n_layers = straight-line HLO, used by the dry-run
    # cost pass because XLA's cost_analysis counts a while body only once.
    scan_unroll: int = 1
    # Sharding policy when n_heads is indivisible by the model axis:
    #   "head_dim"  — shard the head_dim (contraction) dim: keeps params
    #                 sharded but forces per-layer score all-reduces.
    #   "replicate" — keep attention weights replicated over "model";
    #                 attention runs data-parallel, only the MLP is TP.
    attn_fallback: str = "head_dim"
    # KV-cache sharding for serving:
    #   "heads" — shard kv_heads/head_dim over "model" (baseline)
    #   "seq"   — shard the cache sequence dim over "model": attention
    #             reduces over the sharded axis with tiny (B,H,hd)
    #             all-reduces — distributed flash-decode.
    serve_cache_shard: str = "heads"
    tie_embeddings: bool = False
    fsdp: bool = True
    remat: bool = True
    source: str = ""  # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    def window_for_layer(self, i: int) -> int:
        """0 means full attention."""
        if not self.sliding_window:
            return 0
        if i in self.global_layers:
            return 0
        if self.global_every and (i + 1) % self.global_every == 0:
            return 0
        return self.sliding_window

    def supports_decode(self) -> bool:
        return self.causal

    def supports_long_context(self) -> bool:
        """Sub-quadratic per-token decode state (task-spec long_500k gate)."""
        if self.block_kind in ("xlstm", "hymba"):
            return True
        return bool(self.sliding_window)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Smoke-test variant: 2 layers, d_model<=512, <=4 experts — same family."""
    small: dict = dict(
        n_layers=2 if not cfg.slstm_every else 2,
        d_model=min(cfg.d_model, 256),
        n_heads=min(cfg.n_heads, 4),
        n_kv_heads=min(cfg.n_kv_heads, 2),
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        head_dim=64 if cfg.head_dim else 0,
        dtype=jnp.float32,
        fsdp=False,
        remat=False,
    )
    if cfg.n_experts:
        small.update(n_experts=min(cfg.n_experts, 4), top_k=min(cfg.top_k, 2))
    if cfg.q_lora_rank:
        small.update(
            q_lora_rank=64, kv_lora_rank=32, qk_rope_head_dim=16,
            qk_nope_head_dim=32, v_head_dim=32,
        )
    if cfg.sliding_window:
        small.update(sliding_window=32, global_every=min(cfg.global_every, 2))
    if cfg.n_meta_tokens:
        small.update(n_meta_tokens=8)
    if cfg.slstm_every:
        small.update(slstm_every=2)
    if cfg.frontend_dim:
        small.update(frontend_dim=min(cfg.frontend_dim, 64),
                     n_frontend_tokens=min(cfg.n_frontend_tokens, 16))
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
