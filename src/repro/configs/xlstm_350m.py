"""xLSTM 350M [arXiv:2405.04517] — 24 blocks, mLSTM with interspersed sLSTM
(1-in-6), matrix-memory recurrence, O(1) decode state."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_kind="xlstm",
    slstm_every=6,
    source="arXiv:2405.04517",
)
