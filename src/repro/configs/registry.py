"""Architecture registry: ``--arch <id>`` resolution + input_specs().

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of the given input-shape — weak-type-correct, shardable, no
device allocation — used by the multi-pod dry-run and smoke tests alike.
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import INPUT_SHAPES, ArchConfig, InputShape, reduced

__all__ = ["ARCH_IDS", "get_config", "input_specs", "make_batch", "INPUT_SHAPES"]

_MODULES = {
    "hubert-xlarge": "hubert_xlarge",
    "gemma3-12b": "gemma3_12b",
    "phi3-medium-14b": "phi3_medium_14b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "glm4-9b": "glm4_9b",
    "dbrx-132b": "dbrx_132b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "xlstm-350m": "xlstm_350m",
    "hymba-1.5b": "hymba_1_5b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    cfg = mod.CONFIG
    return reduced(cfg) if smoke else cfg


def _batch_shapes(cfg: ArchConfig, batch: int, seq: int) -> dict:
    """Input name -> (shape, dtype) for a full-sequence (train/prefill) step."""
    if cfg.task == "masked_lm":
        return {
            "features": ((batch, seq, cfg.frontend_dim), jnp.float32),
            "mask": ((batch, seq), jnp.bool_),
            "targets": ((batch, seq), jnp.int32),
        }
    if cfg.task == "vlm":
        n_img = min(cfg.n_frontend_tokens, max(seq // 2, 1))
        return {
            "tokens": ((batch, seq - n_img), jnp.int32),
            "image_feats": ((batch, n_img, cfg.frontend_dim), jnp.float32),
        }
    return {"tokens": ((batch, seq), jnp.int32)}


def input_specs(cfg: ArchConfig, shape: InputShape | str, sharding_fn=None) -> dict:
    """ShapeDtypeStructs for one input shape.  For decode shapes this is the
    per-step request batch {tokens (B,), pos ()}; the KV cache is produced by
    the model's ``abstract_cache``."""
    if isinstance(shape, str):
        shape = INPUT_SHAPES[shape]
    if shape.kind == "decode":
        specs = {
            "tokens": ((shape.global_batch,), jnp.int32),
            "pos": ((), jnp.int32),
        }
    else:
        specs = _batch_shapes(cfg, shape.global_batch, shape.seq_len)

    def make(name, sh, dt):
        sharding = sharding_fn(name, sh) if sharding_fn else None
        return jax.ShapeDtypeStruct(sh, dt, sharding=sharding)

    return {k: make(k, sh, dt) for k, (sh, dt) in specs.items()}


def make_batch(cfg: ArchConfig, batch: int, seq: int, seed: int = 0) -> dict:
    """Concrete random batch (smoke tests / examples)."""
    rng = np.random.default_rng(seed)
    out: dict = {}
    for name, (sh, dt) in _batch_shapes(cfg, batch, seq).items():
        if dt == jnp.int32:
            hi = cfg.vocab_size if name in ("tokens", "targets") else 2
            out[name] = jnp.asarray(rng.integers(0, hi, size=sh), jnp.int32)
        elif dt == jnp.bool_:
            out[name] = jnp.asarray(rng.random(sh) < 0.3)
        else:
            out[name] = jnp.asarray(rng.standard_normal(sh), jnp.float32)
    return out
