"""HuBERT-XLarge [arXiv:2106.07447] — 48L encoder-only audio transformer.

The conv/mel frontend is a stub per the task carve-out: ``input_specs()``
provides precomputed frame embeddings (dim 512); we implement the encoder
and the masked-prediction head over 504 cluster targets.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    task="masked_lm",
    causal=False,
    mlp_act="gelu",
    frontend_dim=512,
    source="arXiv:2106.07447",
)
