import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sam


def test_perturbation_radius():
    """SAM extra step has exactly norm rho (Algorithm 1 line 7)."""
    key = jax.random.PRNGKey(0)
    p = {"a": jax.random.normal(key, (13,)), "b": jax.random.normal(key, (4, 5))}
    g = {"a": jax.random.normal(key, (13,)) * 3, "b": jax.random.normal(key, (4, 5))}
    for rho in (0.05, 0.25, 1.0):
        pert = sam.sam_perturb(p, g, rho)
        delta = jax.tree.map(lambda a, b: a - b, pert, p)
        assert np.isclose(float(sam.global_norm(delta)), rho, rtol=1e-4)


def test_sam_gradient_matches_manual():
    def loss(p, batch):
        return jnp.sum((p["w"] * batch["x"] - batch["y"]) ** 2), jnp.float32(0.0)

    p = {"w": jnp.array([1.0, 2.0])}
    batch = {"x": jnp.array([1.0, -1.0]), "y": jnp.array([0.5, 0.5])}
    rho = 0.3
    g1 = jax.grad(lambda q: loss(q, batch)[0])(p)
    norm = sam.global_norm(g1)
    pert = jax.tree.map(lambda a, b: a + rho * b / norm, p, g1)
    expected = jax.grad(lambda q: loss(q, batch)[0])(pert)
    got, (l, _) = sam.sam_gradient(loss, p, batch, rho)
    np.testing.assert_allclose(got["w"], expected["w"], rtol=1e-5)
    assert np.isclose(float(l), float(loss(p, batch)[0]))


def test_rho_zero_is_vanilla_gradient():
    def loss(p, batch):
        return jnp.sum(p["w"] ** 3), jnp.float32(0.0)

    p = {"w": jnp.array([1.0, -2.0])}
    got, _ = sam.sam_gradient(loss, p, {}, 0.0)
    np.testing.assert_allclose(got["w"], 3 * p["w"] ** 2)


def test_lemma1_closed_form():
    """Lemma 1: x_K - x_0 = -eta * sum_{k=1..K} sum_{s=1..k} alpha^{k-s} g_s.

    We run the momentum recursion (lines 9-10) with a fixed sequence of
    gradients and check the closed form exactly.
    """
    K, alpha, eta = 6, 0.7, 0.05
    rng = np.random.default_rng(0)
    gs = [jnp.asarray(rng.standard_normal(3), dtype=jnp.float32) for _ in range(K)]
    x = jnp.zeros(3)
    v = jnp.zeros(3)
    for g in gs:
        v = sam.momentum_update(v, g, alpha)
        x = sam.apply_update(x, v, eta)
    closed = -eta * sum(
        (alpha ** (k - s)) * gs[s - 1]
        for k in range(1, K + 1)
        for s in range(1, k + 1)
    )
    np.testing.assert_allclose(np.asarray(x), np.asarray(closed), rtol=1e-4, atol=1e-6)


def test_momentum_zero_alpha_is_identity():
    v = {"a": jnp.ones(3)}
    g = {"a": jnp.full(3, 2.0)}
    out = sam.momentum_update(v, g, 0.0)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(g["a"]))
