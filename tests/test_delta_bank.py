"""Low-rank delta bank: frozen shared base + per-client adapter rows.

The load-bearing contract is the equivalence oracle: with ``rank="full"``
(every selected leaf stored as a dense delta) the delta program is the
dense-bank program in different coordinates — ``d_i = x_i - w_i * base``
is preserved exactly by any linear mixing of ``(d, w)`` by the same
column-stochastic operator, so training from ``x_i(0) = base`` must match
the dense trainer to float tolerance.  Everything else (narrow gossip,
EF residuals, paging, checkpoints) rides on that identity.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DeltaConfig,
    FLTrainer,
    LinkModel,
    TopologyConfig,
    bind_delta_spec,
    make_algo,
    make_delta_spec,
)
from repro.data.dirichlet import dirichlet_partition, stack_client_data
from repro.data.synthetic import make_dataset
from repro.models.small import mnist_2nn

N_CLIENTS = 8


@pytest.fixture(scope="module")
def setting():
    train, test = make_dataset("mnist", 2000, 500, seed=0)
    parts = dirichlet_partition(train["y"], N_CLIENTS, alpha=0.3, seed=0)
    cdata = stack_client_data(train, parts, pad_to=256)
    cdata = {k: jnp.asarray(v) for k, v in cdata.items()}
    testj = {k: jnp.asarray(v) for k, v in test.items()}
    return mnist_2nn(), cdata, testj


def _topo():
    return TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)


def _algo(name):
    kw = {"batch_size": 32}
    if name != "sgp":
        kw["local_steps"] = 2
    return make_algo(name, **kw)


# ---------------------------------------------------------------------------
# Equivalence oracle: rank="full" == the dense-bank program.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["dfedsgpsm", "dfedsam", "sgp"])
def test_full_rank_matches_dense_program(setting, name):
    """rank="full" stores every leaf as a dense delta; started from
    ``x_i(0) = base`` the dense trainer must produce the same bank (modulo
    the coordinate change) round for round."""
    model, cdata, _ = setting
    tr_d = FLTrainer(model.loss, model.init, cdata, _algo(name), _topo(),
                     seed=0, participation=0.5,
                     delta=DeltaConfig(rank="full", adapt="all"))
    base = tr_d.spec.base
    tr_x = FLTrainer(model.loss, lambda k: base, cdata, _algo(name),
                     _topo(), seed=0, participation=0.5)
    for _ in range(3):
        md = tr_d.run_round()
        mx = tr_x.run_round()
    assert abs(float(md["loss"]) - float(mx["loss"])) < 1e-4
    zd = tr_d.debiased_models()
    zx = tr_x.debiased_models()
    err = max(float(jnp.abs(a - b).max())
              for a, b in zip(jax.tree.leaves(zd), jax.tree.leaves(zx)))
    assert err < 1e-4, f"full-rank delta diverged from dense: {err}"


def test_rank8_bank_is_narrow(setting):
    """The paper-facing size criterion: rank-8 adapters on the bench model
    hold <= 10% of the full parameter count per client row."""
    model, _, _ = setting
    params = model.init(jax.random.PRNGKey(0))
    dspec = make_delta_spec(params, rank=8)
    assert 0 < dspec.dim <= 0.10 * dspec.full.dim
    # and the full-rank spec is exactly full width (all-dense deltas)
    fspec = make_delta_spec(params, rank="full", adapt="all")
    assert fspec.dim == fspec.full.dim


def test_rank8_trains(setting):
    model, cdata, testj = setting
    tr = FLTrainer(model.loss, model.init, cdata, _algo("dfedsgpsm"),
                   _topo(), seed=0, participation=0.5, delta=8)
    l0, _ = tr.evaluate(testj)
    tr.fit(8)
    l1, acc = tr.evaluate(testj)
    # 13k adapter floats train slower than the 200k dense model; the pin
    # is monotone improvement, not the dense path's accuracy.
    assert np.isfinite(l1) and l1 < l0 - 0.1
    assert 0.0 <= acc <= 1.0


# ---------------------------------------------------------------------------
# Spec mechanics: round-trip, init, config validation.
# ---------------------------------------------------------------------------

def test_roundtrip_unravel_ravel(setting):
    model, _, _ = setting
    base = model.init(jax.random.PRNGKey(0))
    spec = bind_delta_spec(make_delta_spec(base, rank="full", adapt="all"),
                           base)
    row = jax.random.normal(jax.random.PRNGKey(1), (spec.dim,), spec.dtype)
    back = spec.ravel(spec.unravel(row))
    assert float(jnp.abs(back - row).max()) < 1e-5


def test_lowrank_rows_cannot_be_factored_back(setting):
    model, _, _ = setting
    base = model.init(jax.random.PRNGKey(0))
    spec = bind_delta_spec(make_delta_spec(base, rank=8), base)
    with pytest.raises(ValueError, match="factored"):
        spec.ravel(spec.unravel(jnp.zeros((spec.dim,), spec.dtype)))


def test_init_row_expands_to_base(setting):
    """B starts at zero, so every client's initial model IS the base."""
    model, _, _ = setting
    base = model.init(jax.random.PRNGKey(0))
    spec = bind_delta_spec(make_delta_spec(base, rank=8), base)
    tree = spec.unravel(spec.init_row(jax.random.PRNGKey(3)))
    for got, want in zip(jax.tree.leaves(tree), jax.tree.leaves(base)):
        assert float(jnp.abs(got - want).max()) < 1e-6


def test_delta_rejects_central_mixer(setting):
    model, cdata, _ = setting
    with pytest.raises(ValueError, match="central"):
        FLTrainer(model.loss, model.init, cdata, _algo("fedavg"),
                  _topo(), seed=0, delta=8)


def test_delta_rejects_pytree_oracle_path(setting):
    model, cdata, _ = setting
    with pytest.raises(ValueError, match="flat"):
        FLTrainer(model.loss, model.init, cdata, _algo("dfedsgpsm"),
                  _topo(), seed=0, flat=False, delta=8)


def test_adapt_filter_2d_freezes_biases(setting):
    model, _, _ = setting
    base = model.init(jax.random.PRNGKey(0))
    spec = make_delta_spec(base, rank="full", adapt="2d")
    # only the (in, out) weight matrices are adapted; biases are frozen
    n_mat = sum(1 for x in jax.tree.leaves(base) if x.ndim >= 2)
    assert sum(1 for m in spec.modes if m != "frozen") == n_mat
    d_mats = sum(int(np.prod(x.shape))
                 for x in jax.tree.leaves(base) if x.ndim >= 2)
    assert spec.dim == d_mats


# ---------------------------------------------------------------------------
# Invariant compositions: drops, sharding, paging.
# ---------------------------------------------------------------------------

def test_mass_conserved_under_drops(setting):
    model, cdata, _ = setting
    tr = FLTrainer(model.loss, model.init, cdata, _algo("dfedsgpsm"),
                   _topo(), seed=0, participation=0.5, delta=8,
                   link=LinkModel(drop=0.3))
    for _ in range(5):
        m = tr.run_round()
    assert np.isfinite(float(m["loss"]))
    assert np.isclose(float(tr.state.w.sum()), N_CLIENTS, atol=1e-3)


def test_sharded_delta_round(setting):
    """The delta bank row-shards like the dense one: same GSPMD pins on a
    (possibly 1-device) clients mesh, mass conserved."""
    from repro.launch.mesh import make_clients_mesh

    model, cdata, _ = setting
    tr = FLTrainer(model.loss, model.init, cdata, _algo("sgp"),
                   TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2,
                                  time_varying=False),
                   seed=0, participation=0.5, delta=8,
                   mesh=make_clients_mesh())
    m = tr.run_round()
    assert np.isfinite(float(m["loss"]))
    assert tr.state.params.shape == (N_CLIENTS, tr.spec.dim)
    assert np.isclose(float(tr.state.w.sum()), N_CLIENTS, atol=1e-3)


def test_paged_delta_round(tmp_path, setting):
    """The paged store holds d_delta-wide rows (fingerprinted by rank, so
    a store can't silently reopen under a different adapter shape) and
    conserves mass over the whole population."""
    model, cdata, _ = setting
    tr = FLTrainer(model.loss, model.init, cdata, _algo("dfedsgpsm"),
                   _topo(), seed=0, delta=8, paged=True,
                   store_dir=str(tmp_path / "store"), k_active=4)
    for _ in range(3):
        m = tr.run_round()
    assert np.isfinite(float(m["loss"]))
    assert tr.runner.store.fields["params"].shape == (tr.spec.dim,)
    assert abs(tr.runner.total_mass() - N_CLIENTS) < 1e-3
    tr.runner.close()


def test_population_eval_cadence(tmp_path, setting):
    """ROADMAP 2b: at the eval cadence the paged trainer streams a
    full-population pass through cold chunks and reports population
    metrics + their delta vs the hot closure's view."""
    model, cdata, testj = setting
    tr = FLTrainer(model.loss, model.init, cdata, _algo("dfedsgpsm"),
                   _topo(), seed=0, paged=True,
                   store_dir=str(tmp_path / "store"), k_active=4)
    hist = tr.fit(2, test_data=testj, eval_every=2)
    assert "pop_loss" not in hist[0]  # off-cadence rounds stay cheap
    rec = hist[1]
    for key in ("pop_loss", "pop_loss_max", "pop_mass",
                "pop_consensus_error", "pop_loss_delta", "test_loss"):
        assert key in rec, key
    assert np.isfinite(rec["pop_loss"])
    assert abs(rec["pop_mass"] - N_CLIENTS) < 1e-3
    assert rec["pop_loss_max"] >= rec["pop_loss"]
    tr.runner.close()


# ---------------------------------------------------------------------------
# Checkpoints: v3 save/restore, v2 transparency, mismatch errors.
# ---------------------------------------------------------------------------

def test_checkpoint_v3_roundtrip(tmp_path, setting):
    from repro.checkpoint import restore_bank, save_bank

    model, cdata, _ = setting
    tr = FLTrainer(model.loss, model.init, cdata, _algo("dfedsgpsm"),
                   _topo(), seed=0, delta=8)
    tr.run_round()
    path = save_bank(str(tmp_path), 1, tr.state.params, tr.spec,
                     extra={"w": tr.state.w})
    bank, extra, meta = restore_bank(path, tr.spec)
    assert meta["delta"]["ranks"] == list(tr.spec.delta.ranks)
    np.testing.assert_allclose(bank, np.asarray(tr.state.params),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(extra["w"], np.asarray(tr.state.w))


def test_checkpoint_v2_still_loads_dense(tmp_path, setting):
    """The dense path still writes/reads format v2 untouched — delta is
    additive, not a migration."""
    from repro.checkpoint import restore_bank, save_bank

    model, cdata, _ = setting
    tr = FLTrainer(model.loss, model.init, cdata, _algo("dfedsgpsm"),
                   _topo(), seed=0)
    tr.run_round()
    path = save_bank(str(tmp_path), 1, tr.state.params, tr.spec)
    bank, _, meta = restore_bank(path, tr.spec)
    assert meta.get("format", 2) == 2 and "delta" not in meta
    np.testing.assert_allclose(bank, np.asarray(tr.state.params),
                               rtol=1e-6, atol=1e-7)


def test_checkpoint_structure_mismatch_raises(tmp_path, setting):
    from repro.checkpoint import restore_bank, save_bank

    model, cdata, _ = setting
    base = model.init(jax.random.PRNGKey(0))
    dspec = bind_delta_spec(make_delta_spec(base, rank=8), base)
    tr = FLTrainer(model.loss, model.init, cdata, _algo("dfedsgpsm"),
                   _topo(), seed=0)
    path = save_bank(str(tmp_path), 1, tr.state.params, tr.spec)
    with pytest.raises(ValueError, match="mismatch"):
        restore_bank(path, dspec)  # dense ckpt, delta spec


def test_checkpoint_base_mismatch_raises(tmp_path, setting):
    """Adapter rows over a different base are silent garbage — restoring
    under a drifted base must fail loudly."""
    from repro.checkpoint import restore_bank, save_bank

    model, cdata, _ = setting
    tr = FLTrainer(model.loss, model.init, cdata, _algo("dfedsgpsm"),
                   _topo(), seed=0, delta=8)
    path = save_bank(str(tmp_path), 1, tr.state.params, tr.spec)
    other = jax.tree.map(lambda x: x + 0.5, tr.spec.base)
    drifted = bind_delta_spec(tr.spec.delta, other)
    with pytest.raises(ValueError, match="base"):
        restore_bank(path, drifted)


def test_paged_store_fingerprints_rank(tmp_path, setting):
    """A rank-8 store must refuse to reopen under a rank-16 program."""
    model, cdata, _ = setting
    store = str(tmp_path / "store")
    tr = FLTrainer(model.loss, model.init, cdata, _algo("dfedsgpsm"),
                   _topo(), seed=0, delta=8, paged=True,
                   store_dir=store, k_active=4)
    tr.run_round()
    tr.save()
    tr.runner.close()
    with pytest.raises(ValueError):
        FLTrainer(model.loss, model.init, cdata, _algo("dfedsgpsm"),
                  _topo(), seed=0, delta=16, paged=True,
                  store_dir=store, k_active=4)
