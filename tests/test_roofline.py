"""Roofline analysis unit tests: HLO collective parser + term math."""
import numpy as np
import pytest

from repro.roofline.analysis import (
    CollectiveStats,
    model_flops,
    parse_collectives,
    roofline_terms,
)

_HLO = """
HloModule jit_step
%add { ... }
ENTRY %main {
  %ag = bf16[16,1024]{1,0} all-gather(%p0), channel_id=1, replica_groups=[...]
  %ar = f32[4,256]{1,0} all-reduce(%x), channel_id=2, to_apply=%add
  %arr.27 = (f32[8]{0}, f32[8]{0}) all-reduce(%a, %b), channel_id=3
  %cp = bf16[2,64]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
  %a2a = f32[32,32]{1,0} all-to-all(%z), channel_id=4
  %rs = f32[128]{0} reduce-scatter(%w), channel_id=5
  %ags = bf16[16,8]{1,0} all-gather-start(%q), channel_id=6
  %dot = f32[8,8]{1,0} dot(%l, %r)   // not a collective
}
"""


def test_parse_collectives_kinds_and_bytes():
    st = parse_collectives(_HLO)
    assert st.count_by_kind["all-gather"] == 2
    assert st.count_by_kind["all-reduce"] == 2
    assert st.count_by_kind["collective-permute"] == 1
    assert st.count_by_kind["all-to-all"] == 1
    assert st.count_by_kind["reduce-scatter"] == 1
    assert st.bytes_by_kind["all-gather"] == 16 * 1024 * 2 + 16 * 8 * 2
    assert st.bytes_by_kind["all-reduce"] == 4 * 256 * 4 + 2 * 8 * 4
    assert st.bytes_by_kind["collective-permute"] == 2 * 64 * 2
    # dot must not be counted
    assert sum(st.count_by_kind.values()) == 7


def test_allreduce_double_weighted():
    st = CollectiveStats({"all-reduce": 100, "all-gather": 50}, {})
    assert st.total_bytes == 150
    assert st.weighted_bytes == 250


def test_roofline_terms_bottleneck_selection():
    coll = CollectiveStats({"all-gather": int(50e9)}, {})
    terms = roofline_terms({"flops": 197e12, "bytes accessed": 819e9 / 2}, coll)
    assert np.isclose(terms["t_compute_s"], 1.0)
    assert np.isclose(terms["t_memory_s"], 0.5)
    assert np.isclose(terms["t_collective_s"], 1.0)
    assert terms["bottleneck"] in ("compute", "collective")

    terms2 = roofline_terms({"flops": 0.0, "bytes accessed": 819e9 * 3}, coll)
    assert terms2["bottleneck"] == "memory"


def test_model_flops():
    assert model_flops(10, 7, "train") == 6 * 70
    assert model_flops(10, 7, "fwd") == 2 * 70


def test_parse_empty():
    st = parse_collectives("ENTRY %main { %d = f32[2]{0} add(%a, %b) }")
    assert st.total_bytes == 0 and not st.count_by_kind
