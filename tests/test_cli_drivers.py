"""Smoke tests for the launcher CLIs (subprocess, reduced configs)."""
import os
import subprocess
import sys

import pytest


def _run(args, timeout=600):
    env = {**os.environ, "PYTHONPATH": "src"}
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-m", *args], capture_output=True,
                          text=True, env=env, cwd="/root/repo", timeout=timeout)


def test_train_cli_host_mesh():
    r = _run(["repro.launch.train", "--arch", "xlstm-350m", "--smoke",
              "--host-mesh", "--rounds", "2", "--batch", "4", "--seq", "32"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "round    1" in r.stdout.replace("round   1", "round    1")
    assert "w_mass=2.0000" in r.stdout


def test_train_cli_superstep_resume(tmp_path):
    """--superstep scans rounds device-resident and checkpoints the full
    round state at superstep boundaries; --resume continues mid-run."""
    ckpt = str(tmp_path / "ck")
    r = _run(["repro.launch.train", "--arch", "xlstm-350m", "--smoke",
              "--host-mesh", "--rounds", "2", "--superstep", "2",
              "--batch", "4", "--seq", "32", "--ckpt-dir", ckpt])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "round    1" in r.stdout.replace("round   1", "round    1")
    assert "w_mass=2.0000" in r.stdout
    r2 = _run(["repro.launch.train", "--arch", "xlstm-350m", "--smoke",
               "--host-mesh", "--rounds", "4", "--superstep", "2",
               "--batch", "4", "--seq", "32", "--ckpt-dir", ckpt,
               "--resume"])
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed" in r2.stdout and "at round 2" in r2.stdout
    assert "round    3" in r2.stdout.replace("round   3", "round    3")


def test_train_cli_topk_ef_compressor(tmp_path):
    """--compress topk_ef: the stateful EF residual bank threads through
    the pod round (and superstep scan + checkpoint) instead of being
    rejected as it was when the pod round was stateless-only."""
    ckpt = str(tmp_path / "ck")
    r = _run(["repro.launch.train", "--arch", "xlstm-350m", "--smoke",
              "--host-mesh", "--rounds", "2", "--superstep", "2",
              "--batch", "4", "--seq", "32", "--compress", "topk_ef",
              "--topk-ratio", "0.1", "--ckpt-dir", ckpt])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "w_mass=2.0000" in r.stdout
    r2 = _run(["repro.launch.train", "--arch", "xlstm-350m", "--smoke",
               "--host-mesh", "--rounds", "4", "--superstep", "2",
               "--batch", "4", "--seq", "32", "--compress", "topk_ef",
               "--topk-ratio", "0.1", "--ckpt-dir", ckpt, "--resume"])
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed" in r2.stdout and "at round 2" in r2.stdout


def test_train_cli_unreliable_links(tmp_path):
    """--link-drop/--link-delay: per-round pod link failures + bounded
    staleness; the printed w_mass counts in-flight shares, so exact mass
    conservation is visible (and asserted by the driver) even while
    payloads are delayed; the link carry checkpoints and resumes."""
    ckpt = str(tmp_path / "ck")
    r = _run(["repro.launch.train", "--arch", "xlstm-350m", "--smoke",
              "--host-mesh", "--rounds", "2", "--superstep", "2",
              "--batch", "4", "--seq", "32",
              "--link-drop", "0.3", "--link-delay", "1",
              "--ckpt-dir", ckpt])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "w_mass=2.0000" in r.stdout
    r2 = _run(["repro.launch.train", "--arch", "xlstm-350m", "--smoke",
               "--host-mesh", "--rounds", "4", "--superstep", "2",
               "--batch", "4", "--seq", "32",
               "--link-drop", "0.3", "--link-delay", "1",
               "--ckpt-dir", ckpt, "--resume"])
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed" in r2.stdout
    assert "w_mass=2.0000" in r2.stdout


def test_train_cli_paged_population_resume(tmp_path):
    """--paged: the virtual client population trains against the
    disk-backed store, commits the manifest, and --resume reopens it at
    the committed round; a second run without --resume must refuse to
    clobber the store."""
    store = str(tmp_path / "pop")
    base = ["repro.launch.train", "--paged", "--n-clients", "256",
            "--k-active", "16", "--rounds", "2", "--store-dir", store]
    r = _run(base)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "paged population n=256" in r.stdout
    assert "committed" in r.stdout and "total_mass=256.0000" in r.stdout
    assert "prefetch_hit_rate=" in r.stdout
    r_clobber = _run(base)
    assert r_clobber.returncode != 0
    assert "already holds a client store" in (r_clobber.stdout
                                              + r_clobber.stderr)
    r2 = _run(base + ["--resume"])
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "round    2" in r2.stdout  # continues at the committed round
    assert "at round 4" in r2.stdout


def test_serve_cli():
    r = _run(["repro.launch.serve", "--arch", "glm4-9b", "--smoke",
              "--batch", "2", "--prompt-len", "8", "--new-tokens", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "ms/step" in r.stdout


def test_serve_smoke_flag_is_real():
    """--smoke used to be ``store_true`` with ``default=True`` — a no-op
    that made full-size serving unreachable.  It is now a
    BooleanOptionalAction pair: default on, ``--no-smoke`` turns it off
    (checked at the parser level; the full-size archs are CI-infeasible)."""
    sys.path.insert(0, "src")
    try:
        from repro.launch.serve import build_parser
    finally:
        sys.path.pop(0)
    ap = build_parser()
    assert ap.parse_args([]).smoke is True
    assert ap.parse_args(["--smoke"]).smoke is True
    assert ap.parse_args(["--no-smoke"]).smoke is False


def test_serve_cli_personalized():
    """--clients N serves N distinct delta-bank models in one batched
    decode (each lane expands its own rank-8 adapters onto the base)."""
    r = _run(["repro.launch.serve", "--arch", "glm4-9b", "--smoke",
              "--clients", "2", "--prompt-len", "8", "--new-tokens", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "personalized" in r.stdout and "ms/step" in r.stdout
    assert "d_delta=" in r.stdout


def test_serve_cli_rejects_encoder():
    r = _run(["repro.launch.serve", "--arch", "hubert-xlarge", "--smoke"])
    assert r.returncode != 0
    assert "encoder-only" in (r.stdout + r.stderr)


def test_dryrun_cli_importable_without_512_devices():
    # importing the module must not initialize jax devices at import time;
    # only running main() sets XLA_FLAGS (checked via a fresh interpreter).
    code = ("import repro.launch.mesh as m; "
            "f = m.make_production_mesh; print('import ok')")
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd="/root/repo", timeout=120)
    assert r.returncode == 0 and "import ok" in r.stdout
