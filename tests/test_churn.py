"""Client churn: whole-node failures with exact mass accounting.

Pins the churn subsystem's contracts: (1) a dead node is excised from the
sampled operator BEFORE sender normalization — its column becomes the
identity, so its push-sum mass freezes on the self-loop and the global
invariant live + in-flight + frozen dead mass == n holds exactly, every
round, for every topology family, composed with LinkModel drops and
delays; (2) zero churn is free — an inactive ChurnModel builds the
bitwise-identical program, resident and paged; (3) resurrection semantics
(warm = stored row, cold = re-init from template with mass kept) conserve
the invariant; (4) the churn carry survives checkpoints and the paged
runner drives the identical schedule host-side."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FLTrainer,
    LinkModel,
    TopologyConfig,
    make_algo,
    make_program,
)
from repro.core import topology as topo
from repro.data.dirichlet import dirichlet_partition, stack_client_data
from repro.data.synthetic import DatasetSpec, make_dataset
from repro.models.small import tiny_mlp
from repro.store import PagedRunner, ResidentDriver

N = 16
_DATA_CACHE: dict = {}


def _client_data(n=N):
    if n not in _DATA_CACHE:
        spec = DatasetSpec("toy", (16,), 4, margin=3.0)
        train, _ = make_dataset(spec, n * 16, 64, seed=0)
        parts = dirichlet_partition(train["y"], n, alpha=10.0, seed=0)
        _DATA_CACHE[n] = stack_client_data(train, parts, pad_to=32)
    return _DATA_CACHE[n]


def _trainer(churn=None, link=None, name="dfedsgpsm", kind="kout",
             gossip="dense", n=N, flat=True, **topo_kw):
    model = tiny_mlp(in_dim=16, n_classes=4)
    algo = make_algo(name, local_steps=2, batch_size=8)
    t = TopologyConfig(kind=kind, n_clients=n, **topo_kw)
    return FLTrainer(model.loss, model.init, _client_data(n), algo, t,
                     seed=0, participation=0.25, churn=churn, link=link,
                     gossip=gossip, flat=flat)


CHURN = topo.ChurnModel(fail_prob=0.15, recover_prob=0.3,
                        permanent_frac=0.2)


# ---------------------------------------------------------------------------
# Model validation + the Markov chain over liveness codes.
# ---------------------------------------------------------------------------

def test_churn_model_validation():
    for bad in (-0.1, 1.5):
        with pytest.raises(ValueError, match="fail_prob"):
            topo.ChurnModel(fail_prob=bad)
        with pytest.raises(ValueError, match="recover_prob"):
            topo.ChurnModel(fail_prob=0.1, recover_prob=bad)
        with pytest.raises(ValueError, match="permanent_frac"):
            topo.ChurnModel(fail_prob=0.1, permanent_frac=bad)
    with pytest.raises(ValueError, match="resurrect"):
        topo.ChurnModel(fail_prob=0.1, resurrect="lukewarm")
    # recover/permanent modulate failures: meaningless without fail_prob
    with pytest.raises(ValueError, match="fail_prob > 0"):
        topo.ChurnModel(recover_prob=0.5)
    assert not topo.ChurnModel().active
    assert topo.ChurnModel(fail_prob=0.01).active


def test_churn_transition_corners_and_absorption():
    live = jnp.array([topo.LIVE, topo.DOWN, topo.DOWN_PERMANENT],
                     dtype=jnp.int8)
    # fail_prob=1 + permanent_frac=1: every live node dies for good;
    # recover_prob=1 revives the recoverable-down node; permanent death
    # is absorbing under every model.
    m = topo.ChurnModel(fail_prob=1.0, permanent_frac=1.0,
                        recover_prob=1.0)
    nxt = np.asarray(topo.churn_transition(jax.random.PRNGKey(0), live, m))
    assert nxt[0] == topo.DOWN_PERMANENT
    assert nxt[1] == topo.LIVE
    assert nxt[2] == topo.DOWN_PERMANENT
    # fail_prob=1, permanent_frac=0: down-but-recoverable
    m = topo.ChurnModel(fail_prob=1.0)
    nxt = np.asarray(topo.churn_transition(jax.random.PRNGKey(1), live, m))
    assert nxt[0] == topo.DOWN and nxt[2] == topo.DOWN_PERMANENT


def test_dead_node_column_is_identity_dense_and_sparse():
    """Churn masks in/out edges before sender normalization: surviving
    senders renormalize over live receivers, a dead sender's column is
    exactly the identity (mass frozen on the self-loop), and the sparse
    neighbor-list masking matches the dense reference."""
    n, k = 12, 3
    key = jax.random.PRNGKey(0)
    alive = jnp.array([i % 3 != 0 for i in range(n)])
    P = topo.sample_kout(key, n, k)
    Pd = np.asarray(topo.churn_links_dense(P, alive))
    np.testing.assert_allclose(Pd.sum(axis=0), 1.0, atol=1e-6)
    dead = ~np.asarray(alive)
    eye = np.eye(n, dtype=Pd.dtype)
    np.testing.assert_array_equal(Pd[:, dead], eye[:, dead])
    # dead receivers get nothing from live senders
    assert np.all(Pd[np.ix_(dead, ~dead)] == 0)
    # Sparse twin on the SAME graph: churn the neighbor list, then compare
    # against the dense masking of its own dense rendering (the dense and
    # sparse k-out samplers draw different orientations, so the reference
    # must come from the identical adjacency).
    nl = topo.sample_kout_neighbors(key, n, k)
    P_nl = topo.dense_from_neighbors(nl, n)
    nld = topo.churn_links_neighbors(nl, alive)
    np.testing.assert_allclose(
        np.asarray(topo.dense_from_neighbors(nld, n)),
        np.asarray(topo.churn_links_dense(P_nl, alive)), atol=1e-6)


def test_zero_churn_is_bitwise_the_plain_program():
    a = _trainer(churn=None, k_out=2)
    b = _trainer(churn=topo.ChurnModel(), k_out=2)
    assert not b.program.churned
    for _ in range(2):
        ma, mb = a.run_round(), b.run_round()
        assert float(ma["loss"]) == float(mb["loss"])
    np.testing.assert_array_equal(np.asarray(a.state.params),
                                  np.asarray(b.state.params))
    np.testing.assert_array_equal(np.asarray(a.state.w),
                                  np.asarray(b.state.w))


# ---------------------------------------------------------------------------
# The acceptance invariant: exact mass across families x link faults.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,name,link,kw", [
    ("ring", "dfedsgpsm", LinkModel(drop=0.3), dict(k_out=1)),
    ("exponential", "dfedsgpsm", LinkModel(drop=0.2, delay=2),
     dict(k_out=1, time_varying=True)),
    ("kout", "dfedsgpsm", LinkModel(drop=0.3), dict(k_out=3)),
    ("kout", "dfedsgpsm", LinkModel(drop=0.2, delay=2), dict(k_out=3)),
    ("symmetric", "dfedavgm", LinkModel(drop=0.3), dict(k_out=3)),
    ("two_tier", "dfedsgpsm", LinkModel(drop=0.3),
     dict(k_out=2, n_pods=4)),
])
def test_churn_mass_conserved_50_rounds(kind, name, link, kw):
    """live + in-flight + frozen dead mass == n at EVERY round of a
    50-round run, for every topology family, churn composed with link
    drops (and bounded delays on the directed families)."""
    tr = _trainer(churn=CHURN, link=link, name=name, kind=kind,
                  gossip="dense", **kw)
    assert tr.program.churned
    state, hist = tr.program.run_superstep(tr.state, 50)
    np.testing.assert_allclose(np.asarray(hist["w_mass"]), N, atol=2e-3)
    assert np.all(np.isfinite(np.asarray(hist["loss"])))
    # churn actually bit: the population was not always fully live
    assert float(np.asarray(hist["live_frac"]).min()) < 1.0
    # dead mass is real mass, parked — not a leak
    dead = np.asarray(hist["dead_mass"])
    assert float(dead.max()) > 0.0


def test_permanent_failures_freeze_mass_forever():
    cm = topo.ChurnModel(fail_prob=0.3, permanent_frac=1.0)
    tr = _trainer(churn=cm, k_out=2)
    state, hist = tr.program.run_superstep(tr.state, 30)
    live = np.asarray(state.churn.live)
    assert (live == topo.DOWN_PERMANENT).any()
    assert not (live == topo.DOWN).any()  # permanent_frac=1: no limbo
    np.testing.assert_allclose(np.asarray(hist["w_mass"]), N, atol=2e-3)
    # the frozen account is exactly the dead nodes' w
    w = np.asarray(state.w)
    np.testing.assert_allclose(float(hist["dead_mass"][-1]),
                               w[live != topo.LIVE].sum(), atol=1e-4)
    # live_frac is monotone non-increasing: nobody ever comes back
    lf = np.asarray(hist["live_frac"])
    assert np.all(np.diff(lf) <= 1e-6)


@pytest.mark.parametrize("resurrect", ["warm", "cold"])
def test_resurrection_modes_conserve_mass(resurrect):
    cm = topo.ChurnModel(fail_prob=0.4, recover_prob=0.8,
                         resurrect=resurrect)
    tr = _trainer(churn=cm, k_out=2)
    state, hist = tr.program.run_superstep(tr.state, 25)
    np.testing.assert_allclose(np.asarray(hist["w_mass"]), N, atol=2e-3)
    lf = np.asarray(hist["live_frac"])
    assert lf.min() < 1.0 and lf[1:].max() > lf.min()  # died AND recovered
    assert np.all(np.isfinite(np.asarray(state.params)))


def test_churn_checkpoint_roundtrip(tmp_path):
    """The churn carry (PRNG stream + liveness + cold template) survives
    save/restore: the resumed trajectory matches the uninterrupted one,
    and composition mismatches are refused up front."""
    cm = topo.ChurnModel(fail_prob=0.3, recover_prob=0.5,
                         resurrect="cold")
    tr = _trainer(churn=cm, k_out=2)
    tr.run_round()
    tr.run_round()
    path = tr.save(str(tmp_path), 2)
    m_ref = tr.run_round()

    tr2 = _trainer(churn=cm, k_out=2)
    tr2.restore(path)
    m_res = tr2.run_round()
    assert float(m_res["loss"]) == float(m_ref["loss"])
    np.testing.assert_array_equal(np.asarray(tr2.state.churn.live),
                                  np.asarray(tr.state.churn.live))
    np.testing.assert_array_equal(np.asarray(tr2.state.params),
                                  np.asarray(tr.state.params))
    with pytest.raises(ValueError, match="churn"):
        _trainer(churn=None, k_out=2).restore(path)
    plain = _trainer(churn=None, k_out=2)
    plain.run_round()
    p_plain = plain.save(str(tmp_path / "plain"), 1)
    with pytest.raises(ValueError, match="churn"):
        _trainer(churn=cm, k_out=2).restore(p_plain)


def test_churn_composition_rules():
    model = tiny_mlp(in_dim=16, n_classes=4)
    algo = make_algo("dfedsgpsm", local_steps=2, batch_size=8)
    cdata = _client_data()
    t = TopologyConfig(kind="kout", n_clients=N, k_out=2)
    cm = topo.ChurnModel(fail_prob=0.1)
    with pytest.raises(ValueError, match="central"):
        make_program(model.loss, model.init, cdata, make_algo("fedavg"), t,
                     churn=cm)
    with pytest.raises(ValueError, match="event_threshold"):
        make_program(model.loss, model.init, cdata, algo, t, churn=cm,
                     link=LinkModel(event_threshold=0.1))
    with pytest.raises(ValueError, match="symmetric"):
        _trainer(churn=cm, name="dfedavgm", kind="symmetric",
                 gossip="sparse", k_out=3)
    with pytest.raises(ValueError, match="two_tier"):
        _trainer(churn=cm, kind="two_tier", gossip="sparse", k_out=2,
                 n_pods=4)
    with pytest.raises(ValueError, match="immortal"):
        _trainer(churn=cm, k_out=2, flat=False)


# ---------------------------------------------------------------------------
# Paged churn: the runner drives the identical schedule host-side.
# ---------------------------------------------------------------------------

def _paged_program(n=N, k_out=2):
    model = tiny_mlp(in_dim=16, n_classes=4)
    algo = make_algo("dfedsgpsm", local_steps=2, batch_size=8)
    t = TopologyConfig(kind="kout", n_clients=n, k_out=k_out)
    return make_program(model.loss, model.init, _client_data(n), algo, t,
                        gossip="dense")


def test_paged_zero_churn_is_bitwise_plain(tmp_path):
    a = PagedRunner(_paged_program(), str(tmp_path / "a"), k_active=4,
                    seed=3, rows_per_chunk=4)
    b = PagedRunner(_paged_program(), str(tmp_path / "b"), k_active=4,
                    seed=3, rows_per_chunk=4, churn=topo.ChurnModel())
    try:
        for _ in range(3):
            ma, mb = a.run_round(), b.run_round()
            assert ma == mb
        ra, rb = a.read_rows(np.arange(N)), b.read_rows(np.arange(N))
        for k in ra:
            np.testing.assert_array_equal(ra[k], rb[k])
    finally:
        a.close()
        b.close()


@pytest.mark.parametrize("resurrect", ["warm", "cold"])
def test_paged_churn_matches_resident_twin(tmp_path, resurrect):
    """The paged runner's host-side churn (dead clients leave the
    sampling pool, cold rebirth rewrites store rows) reproduces the
    resident driver's schedule on the identical PRNG chain."""
    cm = topo.ChurnModel(fail_prob=0.25, recover_prob=0.5,
                         permanent_frac=0.2, resurrect=resurrect)
    runner = PagedRunner(_paged_program(), str(tmp_path / "store"),
                         k_active=4, seed=3, rows_per_chunk=4, churn=cm)
    twin = ResidentDriver(_paged_program(), k_active=4, seed=3, churn=cm)
    try:
        for _ in range(6):
            mp, mt = runner.run_round(), twin.run_round()
            assert abs(mp["loss"] - mt["loss"]) < 1e-4
            assert mp["live_frac"] == mt["live_frac"]
            assert mp["live_frac"] < 1.0 or mt["live_frac"] == 1.0
        rows = runner.read_rows(np.arange(N))
        np.testing.assert_allclose(rows["params"],
                                   np.asarray(twin.state.params),
                                   atol=5e-5)
        np.testing.assert_allclose(rows["w"], np.asarray(twin.state.w),
                                   atol=1e-5)
        assert abs(runner.total_mass() - N) < 1e-3
        assert abs(twin.total_mass() - N) < 1e-3
    finally:
        runner.close()


def test_paged_churn_save_restore_resumes_schedule(tmp_path):
    """Liveness is committed as a store blob: a snapshot reopened by a
    fresh runner replays the identical churn continuation."""
    cm = topo.ChurnModel(fail_prob=0.3, recover_prob=0.5,
                         resurrect="cold")
    runner = PagedRunner(_paged_program(), str(tmp_path / "store"),
                         k_active=4, seed=3, rows_per_chunk=4, churn=cm)
    for _ in range(3):
        runner.run_round()
    runner.save()
    shutil.copytree(str(tmp_path / "store"), str(tmp_path / "snap"))
    a = [runner.run_round() for _ in range(3)]
    runner.close()

    resumed = PagedRunner(_paged_program(), str(tmp_path / "snap"),
                          k_active=4, seed=999, rows_per_chunk=4, churn=cm)
    assert resumed.round_index == 3
    b = [resumed.run_round() for _ in range(3)]
    resumed.close()
    assert a == b
    # a churn-free runner must refuse the churned store
    with pytest.raises(ValueError, match="churn"):
        PagedRunner(_paged_program(), str(tmp_path / "snap"), k_active=4,
                    rows_per_chunk=4)


def test_paged_rejects_churned_program(tmp_path):
    model = tiny_mlp(in_dim=16, n_classes=4)
    algo = make_algo("dfedsgpsm", local_steps=2, batch_size=8)
    t = TopologyConfig(kind="kout", n_clients=N, k_out=2)
    churned = make_program(model.loss, model.init, _client_data(), algo, t,
                           gossip="dense",
                           churn=topo.ChurnModel(fail_prob=0.1))
    with pytest.raises(ValueError, match="churn="):
        PagedRunner(churned, str(tmp_path / "s"), k_active=4)
