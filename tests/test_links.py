"""Unreliable-link gossip: dropped operators stay exactly column-stochastic
(drops hit the adjacency BEFORE sender normalization), push-sum mass is
conserved to float tolerance across long degraded runs — in-flight shares
included under bounded delays — event-triggered rounds report their
communication fraction, and the all-zero link configuration is bitwise the
perfect-link program.  Plus the compressed-gossip self-loop semantics:
client i's own contribution P[ii]·X[i] is never quantized/sparsified."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # graceful tier-1 degradation (see tests/_hyp.py)
    from _hyp import given, settings, st

from repro.core import (
    FLTrainer,
    LinkModel,
    TopologyConfig,
    make_algo,
    make_program,
)
from repro.core import pushsum
from repro.core import topology as topo
from repro.core.stages import (
    DelayedPushSumMixer,
    EventTriggeredMixer,
    Int8RowCompressor,
    LinkState,
    PushSumMixer,
    SymmetricMixer,
    TopKEFCompressor,
)

N_CLIENTS = 8


def _dense_family(family, key, n, k, losses=None):
    if family == "kout":
        return topo.sample_kout(key, n, k)
    if family == "kout_selective":
        l = jax.random.normal(key, (n,)) if losses is None else losses
        return topo.sample_kout_selective(key, l, n, k)
    if family == "ring":
        return topo.directed_ring(n)
    if family == "exponential":
        return topo.directed_exponential(n, k)  # k doubles as the hop
    if family == "full":
        return jnp.full((n, n), 1.0 / n, jnp.float32)
    raise AssertionError(family)


_DENSE_FAMILIES = ["kout", "kout_selective", "ring", "exponential", "full"]


# ---------------------------------------------------------------------------
# Dropped operators: exactly column-stochastic, for every family.
# ---------------------------------------------------------------------------

@given(st.sampled_from(_DENSE_FAMILIES), st.integers(3, 40),
       st.floats(0.0, 0.95), st.integers(0, 9999))
@settings(max_examples=30, deadline=None)
def test_dropped_dense_exactly_column_stochastic(family, n, drop, seed):
    k = max(1, min(n - 1, n // 3))
    P = _dense_family(family, jax.random.PRNGKey(seed), n, k)
    Pd = topo.drop_links_dense(jax.random.PRNGKey(seed + 1), P, drop)
    A = np.asarray(Pd)
    # drops renormalize the surviving adjacency — nothing leaks (the only
    # slack is the f32 rounding of count * (1/count))
    np.testing.assert_allclose(A.sum(axis=0), 1.0, atol=1e-6)
    assert np.all(A >= 0)
    assert np.all(np.diag(A) > 0)  # self-loops never drop
    # support shrinks, never grows
    assert np.all((A > 0) <= (np.asarray(P) > 0))


@given(st.sampled_from(["kout", "kout_selective", "ring", "exponential"]),
       st.integers(3, 40), st.floats(0.0, 0.95), st.integers(0, 9999))
@settings(max_examples=30, deadline=None)
def test_dropped_neighbors_exactly_column_stochastic(family, n, drop, seed):
    k = max(1, min(n - 1, n // 3))
    key = jax.random.PRNGKey(seed)
    if family == "kout":
        nl = topo.sample_kout_neighbors(key, n, k)
    elif family == "kout_selective":
        nl = topo.sample_kout_selective_neighbors(
            key, jax.random.normal(key, (n,)), n, k)
    elif family == "ring":
        nl = topo.neighbors_ring(n)
    else:
        nl = topo.neighbors_exponential(n, k)
    nld = topo.drop_links_neighbors(jax.random.PRNGKey(seed + 1), nl, drop)
    A = np.asarray(topo.dense_from_neighbors(nld, n))
    np.testing.assert_allclose(A.sum(axis=0), 1.0, atol=1e-6)
    assert np.all(np.diag(A) > 0)
    assert np.all(np.asarray(nld.wgt)[:, 0] > 0)  # slot-0 self-loop kept


@given(st.integers(4, 30), st.floats(0.0, 0.9), st.integers(0, 9999))
@settings(max_examples=20, deadline=None)
def test_dropped_symmetric_stays_doubly_stochastic(n, drop, seed):
    k = max(1, n // 3)
    W = topo.sample_symmetric_k_regular(jax.random.PRNGKey(seed), n, k)
    Wd = np.asarray(topo.drop_links_dense(
        jax.random.PRNGKey(seed + 1), W, drop, symmetric=True))
    assert np.allclose(Wd, Wd.T, atol=1e-6)  # one coin per undirected edge
    assert np.allclose(Wd.sum(0), 1.0, atol=1e-5)
    assert np.allclose(Wd.sum(1), 1.0, atol=1e-5)


def test_drop_zero_is_identity_on_both_representations():
    """drop=0 must reproduce the undropped operator exactly — the
    renormalization arithmetic may not perturb a single weight."""
    n, k = 12, 3
    key = jax.random.PRNGKey(0)
    for P in (topo.sample_kout(key, n, k), topo.directed_ring(n),
              topo.directed_exponential(n, 1)):
        np.testing.assert_array_equal(
            np.asarray(topo.drop_links_dense(jax.random.PRNGKey(1), P, 0.0)),
            np.asarray(P))
    for nl in (topo.sample_kout_neighbors(key, n, k),
               topo.neighbors_ring(n), topo.neighbors_exponential(n, 1)):
        nl0 = topo.drop_links_neighbors(jax.random.PRNGKey(1), nl, 0.0)
        np.testing.assert_array_equal(np.asarray(nl0.idx),
                                      np.asarray(nl.idx))
        np.testing.assert_array_equal(np.asarray(nl0.wgt),
                                      np.asarray(nl.wgt))


def test_link_model_validation():
    # drop=1.0 is a PINNED boundary, not an error: every inter-node edge
    # fails, each node keeps its whole mass on the forced self-loop, and
    # push-sum mass is still conserved exactly (nobody mixes).
    assert topo.LinkModel(drop=1.0).active
    with pytest.raises(ValueError, match="drop must be a probability"):
        topo.LinkModel(drop=1.5)
    with pytest.raises(ValueError, match="drop must be a probability"):
        topo.LinkModel(drop=-0.1)
    with pytest.raises(ValueError, match="do not compose"):
        topo.LinkModel(delay=2, event_threshold=0.1)
    # one sender-side cache row cannot model per-receiver misses, so
    # event triggering assumes reliable links
    with pytest.raises(ValueError, match="do not compose"):
        topo.LinkModel(drop=0.2, event_threshold=0.1)
    with pytest.raises(ValueError, match="delay"):
        DelayedPushSumMixer(delay=0)
    assert not topo.LinkModel().active
    assert topo.LinkModel(drop=0.1).active
    with pytest.raises(ValueError, match="symmetric neighbor-list"):
        topo.LinkModel(drop=0.5).drop_links(
            jax.random.PRNGKey(0),
            topo.sample_symmetric_neighbors(jax.random.PRNGKey(1), 8, 2),
            symmetric=True)


# ---------------------------------------------------------------------------
# Exact push-sum mass under drops and bounded delays (operator level).
# ---------------------------------------------------------------------------

@given(st.integers(4, 24), st.floats(0.0, 0.8), st.integers(1, 3),
       st.integers(0, 999))
@settings(max_examples=15, deadline=None)
def test_delayed_pushsum_mass_exact(n, drop, delay, seed):
    """Node mass + in-flight mass == n at EVERY round, for any drop/delay
    pattern — the invariant that makes the de-bias ratio trustworthy."""
    d = 6
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    w = jnp.ones((n,))
    mixer = DelayedPushSumMixer(delay=delay)
    link = LinkState(key=jax.random.PRNGKey(seed + 1),
                     **mixer.link_buffers(X))
    x_mass0 = np.asarray(X.sum(0))
    for t in range(10):
        P = topo.sample_kout(jax.random.PRNGKey(100 + t), n,
                             max(1, n // 4))
        lkey, dkey, nkey = jax.random.split(link.key, 3)
        link = link._replace(key=nkey)
        if drop > 0:
            P = topo.drop_links_dense(dkey, P, drop)
        X, w, link, _ = mixer.mix_round(P, X, w, link, lkey, X)
        np.testing.assert_allclose(
            float(w.sum() + link.bufw.sum()), n, rtol=1e-5)
        np.testing.assert_allclose(
            np.asarray(X.sum(0) + link.bufx.sum(axis=(0, 1))), x_mass0,
            rtol=1e-4, atol=1e-4)


def test_delayed_ring_consensus_converges():
    """Push-sum over a directed ring with every link up to 2 rounds stale
    still drives z = x / w to the exact initial average."""
    n, d = 8, 7
    X = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    target = np.asarray(X.mean(0))
    w = jnp.ones((n,))
    mixer = DelayedPushSumMixer(delay=2)
    link = LinkState(key=jax.random.PRNGKey(1), **mixer.link_buffers(X))
    P = topo.directed_ring(n)
    # the ring's spectral gap is ~1/n^2 and staleness halves the rate:
    # give the slow graph a long horizon
    for _ in range(400):
        lkey, nkey = jax.random.split(link.key)
        link = link._replace(key=nkey)
        X, w, link, _ = mixer.mix_round(P, X, w, link, lkey, X)
    z = np.asarray(pushsum.debias_bank(X, w))
    np.testing.assert_allclose(z, np.broadcast_to(target, (n, d)),
                               rtol=5e-3, atol=5e-3)


def test_event_triggered_thresholds_trade_comm_for_drift():
    """threshold -> 0 transmits every round (comm_fraction 1) and matches
    plain push-sum bitwise; a huge threshold stops transmitting after the
    warm-start cache (comm_fraction 0) while mass stays exact."""
    n, d = 10, 5
    X0 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    w0 = jnp.ones((n,))
    P = topo.sample_kout(jax.random.PRNGKey(1), n, 3)

    def run(threshold, rounds=3):
        mixer = EventTriggeredMixer(threshold=threshold)
        X, w = X0, w0
        # a drifted cache: the mixer decides per round what to resend
        link = LinkState(key=jax.random.PRNGKey(2),
                         **mixer.link_buffers(0.5 * X0))
        fracs = []
        for t in range(rounds):
            lkey, nkey = jax.random.split(link.key)
            link = link._replace(key=nkey)
            X, w, link, ex = mixer.mix_round(P, X, w, link, lkey, X)
            fracs.append(float(ex["comm_fraction"]))
            np.testing.assert_allclose(float(w.sum()), n, rtol=1e-5)
        return X, w, fracs

    X_eager, w_eager, fr_eager = run(0.0)
    assert fr_eager == [1.0] * 3
    ref, wref = X0, w0
    for _ in range(3):
        ref, wref = PushSumMixer().mix(P, ref, wref)
    np.testing.assert_array_equal(np.asarray(X_eager), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(w_eager), np.asarray(wref))

    _, _, fr_lazy = run(1e9)
    assert fr_lazy == [0.0] * 3


# ---------------------------------------------------------------------------
# Compressed gossip never compresses the self-loop (the headline bugfix).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mixer_cls", [PushSumMixer, SymmetricMixer])
@pytest.mark.parametrize("sparse", [False, True])
def test_selfloop_rides_full_precision(mixer_cls, sparse):
    """mix_round must produce X'[i] = P[ii]·X[i] + sum_{j!=i} P[ij]·C(X)[j]
    on both representations — with topk at ratio 0.05 the OLD semantics
    kept only 5% of a client's own coordinates."""
    n, d = 9, 40
    X = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    Xc = np.asarray(TopKEFCompressor(ratio=0.1).apply(
        jnp.zeros((n, d)), X)[1])
    w = jnp.ones((n,))
    if sparse:
        P = topo.sample_kout_neighbors(jax.random.PRNGKey(1), n, 3)
        dense = np.asarray(topo.dense_from_neighbors(P, n))
        selfw = np.asarray(P.wgt[:, 0])
    else:
        P = topo.sample_kout(jax.random.PRNGKey(1), n, 3)
        dense = np.asarray(P)
        selfw = np.diag(dense)
    got, _, _, _ = mixer_cls().mix_round(P, jnp.asarray(Xc), w, (), None, X)
    want = (dense - np.diag(selfw)) @ Xc + selfw[:, None] * np.asarray(X)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)


def test_selfloop_identity_composition_bitwise_unchanged():
    """With identity compression (X_full is X) mix_round must be literally
    mixer.mix — not 'equal up to fp', the same bits."""
    n, d = 8, 33
    X = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    w = jax.random.uniform(jax.random.PRNGKey(1), (n,), minval=0.5,
                           maxval=1.5)
    for P in (topo.sample_kout(jax.random.PRNGKey(2), n, 2),
              topo.sample_kout_neighbors(jax.random.PRNGKey(2), n, 2)):
        for mixer in (PushSumMixer(), SymmetricMixer()):
            got = mixer.mix_round(P, X, w, (), None, X)
            want = mixer.mix(P, X, w)
            np.testing.assert_array_equal(np.asarray(got[0]),
                                          np.asarray(want[0]))
            np.testing.assert_array_equal(np.asarray(got[1]),
                                          np.asarray(want[1]))


def test_int8_round_preserves_self_contribution(setting):
    """End to end: with int8 gossip, a client's own de-quantized row error
    affects only what OTHERS receive; its self-contribution is exact.
    Pin by decomposing one round's mix against the program internals."""
    model, cdata = setting
    algo = make_algo("dfedsgpsm", local_steps=1, batch_size=16,
                     compressor="int8_rows")
    t = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    tr = FLTrainer(model.loss, model.init, cdata, algo, t, seed=0,
                   participation=0.25)
    first = tr.run_round()
    for _ in range(3):
        last = tr.run_round()
    assert float(last["loss"]) < float(first["loss"])
    np.testing.assert_allclose(float(tr.state.w.sum()), N_CLIENTS,
                               atol=1e-3)
    # operator-level pin with the live compressor on a live-sized bank
    comp = Int8RowCompressor()
    X = jax.random.normal(jax.random.PRNGKey(5), (N_CLIENTS, 64))
    _, Xc = comp.apply((), X)
    P = topo.sample_kout(jax.random.PRNGKey(6), N_CLIENTS, 2)
    got = PushSumMixer().mix_round(P, Xc, jnp.ones((N_CLIENTS,)), (),
                                   None, X)[0]
    A = np.asarray(P)
    want = ((A - np.diag(np.diag(A))) @ np.asarray(Xc)
            + np.diag(A)[:, None] * np.asarray(X))
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Linked round programs end to end.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setting():
    from repro.data.dirichlet import dirichlet_partition, stack_client_data
    from repro.data.synthetic import make_dataset
    from repro.models.small import mnist_2nn

    train, _ = make_dataset("mnist", 800, 50, seed=0)
    parts = dirichlet_partition(train["y"], N_CLIENTS, alpha=0.3, seed=0)
    cdata = stack_client_data(train, parts, pad_to=64)
    return mnist_2nn(), {k: jnp.asarray(v) for k, v in cdata.items()}


def _trainer(setting, link=None, name="dfedsgpsm", **kw):
    model, cdata = setting
    algo = make_algo(name, local_steps=2, batch_size=16)
    t = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    return FLTrainer(model.loss, model.init, cdata, algo, t, seed=0,
                     participation=0.25, link=link, **kw)


def test_zero_link_model_is_bitwise_the_plain_program(setting):
    """LinkModel() with all-zero fields must build the EXACT perfect-link
    program: same states, same bits, dense and sparse."""
    for gossip in ("dense", "sparse"):
        a = _trainer(setting, link=None, gossip=gossip)
        b = _trainer(setting, link=LinkModel(), gossip=gossip)
        assert not b.program.linked
        for _ in range(2):
            ma, mb = a.run_round(), b.run_round()
            assert float(ma["loss"]) == float(mb["loss"])
        np.testing.assert_array_equal(np.asarray(a.state.params),
                                      np.asarray(b.state.params))
        np.testing.assert_array_equal(np.asarray(a.state.w),
                                      np.asarray(b.state.w))


def test_dropped_run_conserves_mass_50_rounds(setting):
    """The acceptance invariant: under any sampled drop pattern the
    per-round mass sum_i w_i == n holds to float tolerance across a
    50-round run (drop-only: nothing is ever in flight)."""
    tr = _trainer(setting, link=LinkModel(drop=0.3))
    state, hist = tr.program.run_superstep(tr.state, 50)
    mass = np.asarray(hist["w_mass"])
    np.testing.assert_allclose(mass, N_CLIENTS, atol=2e-3)
    assert np.all(np.isfinite(np.asarray(hist["loss"])))
    assert float(hist["loss"][-1]) < float(hist["loss"][0])


def test_delayed_run_conserves_total_mass(setting):
    """With bounded delays the invariant counts the in-flight shares:
    w_mass (node + buffer) == n every round, and training still makes
    progress on stale payloads."""
    tr = _trainer(setting, link=LinkModel(drop=0.2, delay=2))
    assert isinstance(tr.program.mixer, DelayedPushSumMixer)
    state, hist = tr.program.run_superstep(tr.state, 12)
    np.testing.assert_allclose(np.asarray(hist["w_mass"]), N_CLIENTS,
                               atol=1e-3)
    assert float(hist["loss"][-1]) < float(hist["loss"][0])
    # the node mass alone is NOT n — some is genuinely in flight
    assert abs(float(state.w.sum()) - N_CLIENTS) > 1e-4
    assert float(state.link.bufw.sum()) > 0


def test_event_triggered_run_reports_comm_fraction(setting):
    tr = _trainer(setting, link=LinkModel(event_threshold=1e-6))
    assert isinstance(tr.program.mixer, EventTriggeredMixer)
    hist = tr.fit(3)
    assert all(rec["comm_fraction"] == 1.0 for rec in hist)
    tr = _trainer(setting, link=LinkModel(event_threshold=1e9))
    hist = tr.fit(3)
    assert all(rec["comm_fraction"] == 0.0 for rec in hist)
    assert all(abs(rec["w_mass"] - N_CLIENTS) < 1e-3 for rec in hist)


# ---------------------------------------------------------------------------
# Event-threshold schedules: decaying / callable communication censoring.
# ---------------------------------------------------------------------------

def test_threshold_at_resolves_decay_and_schedule():
    """`threshold * decay ** t` when decaying, the callable when given
    (schedule overrides decay), the plain python float when fixed — and a
    loud error when a schedule needs the round index but none is threaded."""
    m = EventTriggeredMixer(threshold=4.0, decay=0.5)
    assert float(m._threshold_at(3)) == pytest.approx(0.5)
    m = EventTriggeredMixer(threshold=5.0, decay=0.5,
                            schedule=lambda t: 7.0 - t)
    assert float(m._threshold_at(2)) == pytest.approx(5.0)
    fixed = EventTriggeredMixer(threshold=0.25)
    assert fixed._threshold_at(None) == 0.25  # resolved at trace time
    with pytest.raises(ValueError, match="round"):
        EventTriggeredMixer(threshold=1.0, decay=0.9)._threshold_at(None)


def test_decaying_threshold_crosses_known_drift_at_known_round():
    """Rows with drift of exactly 1.4 start transmitting the first round
    the decayed threshold falls below that — the trend the schedule exists
    to produce (sparse early, full gossip late), pinned deterministically
    against a cold cache each round."""
    n, d = 6, 4
    X = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    X = 1.4 * X / jnp.linalg.norm(X, axis=1, keepdims=True)
    P = topo.directed_ring(n)
    mixer = EventTriggeredMixer(threshold=4.0, decay=0.5)
    fracs = []
    for t in range(4):
        link = LinkState(key=jax.random.PRNGKey(1),
                         **mixer.link_buffers(jnp.zeros((n, d))))
        _, w, _, ex = mixer.mix_round(P, X, jnp.ones((n,)), link, None, X,
                                      t=t)
        fracs.append(float(ex["comm_fraction"]))
        np.testing.assert_allclose(float(w.sum()), n, rtol=1e-5)
    # thresholds 4, 2, 1, 0.5 against drift 1.4: cross between t=1 and t=2
    assert fracs == [0.0, 0.0, 1.0, 1.0]


def test_event_schedule_raises_comm_fraction_over_training(setting):
    """End to end: a decaying threshold starts mute (the round-0 threshold
    dwarfs any local-step drift) and tightens toward full gossip —
    comm_fraction trends up across the run while push-sum mass stays exact
    every round."""
    tr = _trainer(setting, link=LinkModel(event_threshold=1e3,
                                          event_decay=0.1))
    assert isinstance(tr.program.mixer, EventTriggeredMixer)
    hist = tr.fit(8)
    fracs = [rec["comm_fraction"] for rec in hist]
    assert fracs[0] == 0.0
    assert fracs[-1] > 0.0
    assert max(fracs[4:]) > max(fracs[:2])
    assert all(abs(rec["w_mass"] - N_CLIENTS) < 1e-3 for rec in hist)


def test_constant_schedule_matches_fixed_threshold_bitwise(setting):
    """A schedule that returns the fixed value must reproduce the fixed-
    threshold program exactly: the traced-threshold branch may not perturb
    a single send decision or bit of state."""
    a = _trainer(setting, link=LinkModel(event_threshold=0.05))
    b = _trainer(setting, link=LinkModel(event_threshold=0.05,
                                         event_schedule=lambda t: 0.05))
    for _ in range(3):
        ma, mb = a.run_round(), b.run_round()
        assert float(ma["loss"]) == float(mb["loss"])
        assert ma["comm_fraction"] == mb["comm_fraction"]
    np.testing.assert_array_equal(np.asarray(a.state.params),
                                  np.asarray(b.state.params))
    np.testing.assert_array_equal(np.asarray(a.state.w),
                                  np.asarray(b.state.w))


def test_event_schedule_validation():
    with pytest.raises(ValueError, match="event_decay"):
        LinkModel(event_threshold=0.1, event_decay=0.0)
    with pytest.raises(ValueError, match="callable"):
        LinkModel(event_threshold=0.1, event_schedule=3.0)
    with pytest.raises(ValueError, match="event_threshold > 0"):
        LinkModel(event_decay=0.5)
    with pytest.raises(ValueError, match="event_threshold > 0"):
        LinkModel(event_schedule=lambda t: 1.0)


def test_linked_checkpoint_roundtrip(setting, tmp_path):
    """The link carry (PRNG stream + in-flight buffers) survives a full
    save/restore: the resumed trajectory matches the uninterrupted one."""
    link = LinkModel(drop=0.2, delay=2)
    tr = _trainer(setting, link=link)
    tr.run_round()
    tr.run_round()
    path = tr.save(str(tmp_path), 2)
    m_ref = tr.run_round()

    tr2 = _trainer(setting, link=link)
    state = tr2.restore(path)
    assert isinstance(state.link, LinkState)
    m_res = tr2.run_round()
    np.testing.assert_allclose(float(m_res["loss"]), float(m_ref["loss"]),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(tr2.state.params),
                               np.asarray(tr.state.params),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(tr2.state.link.bufw),
                                  np.asarray(tr.state.link.bufw))
    # a link-free trainer must refuse the linked checkpoint (and vice versa)
    with pytest.raises(ValueError, match="link"):
        _trainer(setting).restore(path)
    # ...and so must a DIFFERENT link composition: a delayed carry in an
    # event-triggered program (or another delay bound) fails the structure
    # check up front instead of crashing inside the next traced round
    with pytest.raises(ValueError, match="link carry field"):
        _trainer(setting,
                 link=LinkModel(event_threshold=0.1)).restore(path)
    with pytest.raises(ValueError, match="link carry field"):
        _trainer(setting, link=LinkModel(drop=0.2, delay=3)).restore(path)
    plain = _trainer(setting)
    plain.run_round()
    p_plain = plain.save(str(tmp_path / "plain"), 1)
    with pytest.raises(ValueError, match="link"):
        _trainer(setting, link=link).restore(p_plain)


def test_linked_program_composition_rules(setting):
    model, cdata = setting
    t = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    with pytest.raises(ValueError, match="central"):
        make_program(model.loss, model.init, cdata, make_algo("fedavg"), t,
                     link=LinkModel(drop=0.2))
    with pytest.raises(ValueError, match="directed"):
        make_program(model.loss, model.init, cdata, make_algo("dfedsam"), t,
                     link=LinkModel(delay=2))
    # symmetric gossip + drops works on the dense representation
    tr = _trainer(setting, link=LinkModel(drop=0.3), name="dfedsam",
                  gossip="dense")
    m = tr.run_round()
    assert np.isfinite(float(m["loss"]))
    with pytest.raises(ValueError, match="symmetric"):
        _trainer(setting, link=LinkModel(drop=0.3), name="dfedsam",
                 gossip="sparse")
    with pytest.raises(ValueError, match="perfect links"):
        _trainer(setting, link=LinkModel(drop=0.3), flat=False)
