"""Sparse neighbor-indexed gossip: dense/sparse equivalence for every
topology family, mass conservation, padded-self-loop correctness, the
density dispatch rule, and sparse round programs end to end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # graceful tier-1 degradation (see tests/_hyp.py)
    from _hyp import given, settings, st

from repro.core import FLTrainer, TopologyConfig, make_algo, make_program
from repro.core import pushsum
from repro.core import topology as topo
from repro.kernels import ops, ref
from repro.kernels.gossip_gather import gossip_gather_pallas


def _sample_family(family: str, key, n: int, k: int) -> topo.NeighborList:
    if family == "kout":
        return topo.sample_kout_neighbors(key, n, k)
    if family == "kout_selective":
        losses = jax.random.normal(key, (n,))
        return topo.sample_kout_selective_neighbors(key, losses, n, k)
    if family == "symmetric":
        return topo.sample_symmetric_neighbors(key, n, k)
    if family == "ring":
        return topo.neighbors_ring(n)
    if family == "exponential":
        return topo.neighbors_exponential(n, k)  # k doubles as the hop t
    raise AssertionError(family)


_FAMILIES = ["kout", "kout_selective", "symmetric", "ring", "exponential"]


# ---------------------------------------------------------------------------
# Sparse gossip == densified matmul, for every family (the tentpole pin).
# ---------------------------------------------------------------------------

@given(st.sampled_from(_FAMILIES), st.integers(3, 40), st.integers(1, 200),
       st.integers(0, 9999))
@settings(max_examples=30, deadline=None)
def test_sparse_matches_dense_gossip(family, n, D, seed):
    k = max(1, min(n - 1, n // 3))
    nl = _sample_family(family, jax.random.PRNGKey(seed), n, k)
    P = topo.dense_from_neighbors(nl, n)
    X = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, D))
    want = np.asarray(ref.gossip_matmul_ref(P, X))
    for use_kernel in (False, True):
        got = np.asarray(
            ops.gossip_mix_sparse(nl.idx, nl.wgt, X, use_kernel=use_kernel))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    # the push-sum weight vector mixes with the SAME operator
    w = jax.random.uniform(jax.random.PRNGKey(seed + 2), (n,)) + 0.5
    np.testing.assert_allclose(
        np.asarray(pushsum.gossip_weights(nl, w)),
        np.asarray(P @ w), rtol=1e-5, atol=1e-6)
    # mass conservation: column-stochastic operators preserve sum_i x_i
    got = np.asarray(ops.gossip_mix_sparse(nl.idx, nl.wgt, X))
    np.testing.assert_allclose(got.sum(0), np.asarray(X.sum(0)),
                               rtol=1e-3, atol=1e-3)


@given(st.integers(4, 32), st.integers(1, 100), st.integers(0, 999))
@settings(max_examples=10, deadline=None)
def test_exponential_cycle_sparse_matches_dense(n, D, seed):
    """The time-varying exponential cycle: every hop's neighbor slice is
    exactly its dense matrix, so a scanned round can index either form."""
    cycle_nl = topo.neighbors_exponential_cycle(n)
    cycle_dense = topo.exponential_cycle(n)
    hops = cycle_dense.shape[0]
    assert cycle_nl.idx.shape == (hops, n, 2)
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, D))
    for t in range(hops):
        nl_t = jax.tree.map(lambda a: a[t], cycle_nl)
        np.testing.assert_array_equal(
            np.asarray(topo.dense_from_neighbors(nl_t, n)),
            np.asarray(cycle_dense[t]))
        np.testing.assert_allclose(
            np.asarray(pushsum.gossip_bank(nl_t, X)),
            np.asarray(pushsum.gossip_bank(cycle_dense[t], X)),
            rtol=1e-4, atol=1e-5)


def test_ring_neighbors_densify_exactly():
    for n in (3, 8, 17):
        np.testing.assert_array_equal(
            np.asarray(topo.dense_from_neighbors(topo.neighbors_ring(n), n)),
            np.asarray(topo.directed_ring(n)))


# ---------------------------------------------------------------------------
# Stochasticity of the sampled neighbor families.
# ---------------------------------------------------------------------------

@given(st.integers(4, 50), st.integers(0, 9999))
@settings(max_examples=15, deadline=None)
def test_kout_neighbors_column_stochastic(n, seed):
    k = max(1, min(n - 1, n // 3))
    nl = topo.sample_kout_neighbors(jax.random.PRNGKey(seed), n, k)
    P = topo.dense_from_neighbors(nl, n)
    assert topo.is_column_stochastic(P)
    # every receiver has its self-loop plus exactly k distinct in-neighbors
    assert np.all(np.count_nonzero(np.asarray(P), axis=1) == k + 1)


@given(st.integers(4, 40), st.integers(0, 9999))
@settings(max_examples=15, deadline=None)
def test_symmetric_neighbors_doubly_stochastic(n, seed):
    k = max(1, n // 3)
    nl = topo.sample_symmetric_neighbors(jax.random.PRNGKey(seed), n, k)
    W = np.asarray(topo.dense_from_neighbors(nl, n))
    assert np.allclose(W, W.T, atol=1e-6)
    assert np.allclose(W.sum(0), 1.0, atol=1e-5)
    assert np.allclose(W.sum(1), 1.0, atol=1e-5)
    assert np.all(W >= -1e-6)
    # bounded degree by construction: at most 2k neighbors + self
    assert np.all(np.count_nonzero(W, axis=1) <= 2 * k + 1)


def test_kout_neighbors_union_connected():
    """Assumption 1 holds for the sparse family exactly as for the dense
    one: the union over a window of sampled graphs is strongly connected."""
    n, k = 50, 5
    mats = [
        topo.dense_from_neighbors(
            topo.sample_kout_neighbors(jax.random.PRNGKey(s), n, k), n)
        for s in range(3)
    ]
    assert topo.union_strongly_connected(mats)


# ---------------------------------------------------------------------------
# Padded self-loops at ragged out-degrees.
# ---------------------------------------------------------------------------

def test_zero_weight_pads_are_inert():
    """Padding slots (idx -> self, wgt 0) must not perturb the mix, and
    duplicate indices must accumulate — the two invariants that make one
    fixed (n, k_max) shape serve ragged in-degrees."""
    n, D = 7, 13
    base = topo.neighbors_ring(n)
    X = jax.random.normal(jax.random.PRNGKey(0), (n, D))
    want = np.asarray(pushsum.gossip_bank(base, X))
    # pad three extra zero-weight self slots
    i = jnp.arange(n, dtype=jnp.int32)[:, None]
    padded = topo.NeighborList(
        jnp.concatenate([base.idx, jnp.tile(i, (1, 3))], axis=1),
        jnp.concatenate([base.wgt, jnp.zeros((n, 3), jnp.float32)], axis=1))
    got = np.asarray(pushsum.gossip_bank(padded, X))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)
    # duplicates accumulate: splitting a slot's weight across two copies
    # of the same index is the identical operator
    split = topo.NeighborList(
        jnp.concatenate([base.idx, base.idx[:, 1:]], axis=1),
        jnp.concatenate(
            [base.wgt.at[:, 1].mul(0.5), 0.5 * base.wgt[:, 1:]], axis=1))
    np.testing.assert_allclose(
        np.asarray(pushsum.gossip_bank(split, X)), want,
        rtol=1e-5, atol=1e-6)


def test_symmetric_self_hits_are_zero_weight_pads():
    """pi_t(i) = i permutation self-hits must land as weight-0 pads; the
    densified diagonal stays the Metropolis residual."""
    # With k=1 and tiny n, self-hits occur with decent probability; scan
    # seeds until one shows up to pin the invariant.
    for s in range(200):
        nl = topo.sample_symmetric_neighbors(jax.random.PRNGKey(s), 4, 1)
        idx, wgt = np.asarray(nl.idx), np.asarray(nl.wgt)
        self_hits = idx[:, 1:] == np.arange(4)[:, None]
        if self_hits.any():
            assert np.all(wgt[:, 1:][self_hits] == 0.0)
            return
    pytest.skip("no permutation self-hit in 200 seeds")


# ---------------------------------------------------------------------------
# The density dispatch rule: one rule, one place.
# ---------------------------------------------------------------------------

def test_dispatch_rule_boundaries():
    """The rule is backend-aware: the measured CPU crossover is n~128
    (interpret-mode gather loses to the dense einsum below that — 0.22x
    at n=32, 0.78x at n=64, k_out=10), while the Mosaic kernel wins from
    n=32 on TPU.  These tests run on CPU, so the 128 floor is pinned
    directly and the TPU floor via the constant."""
    assert not ops.use_sparse_gossip(16, 2)  # golden scale stays dense
    assert not ops.use_sparse_gossip(31, 2)
    # Below the measured CPU crossover: dense, even at TPU-floor sizes.
    assert not ops.use_sparse_gossip(32, 8)
    assert not ops.use_sparse_gossip(64, 11)
    assert not ops.use_sparse_gossip(100, 11)
    assert not ops.use_sparse_gossip(127, 11)
    # From n=128 the gather wins; density cap 0.25 still applies.
    assert ops.use_sparse_gossip(128, 11)  # the retuned paper-like point
    assert ops.use_sparse_gossip(128, 32)  # k_max/n == 0.25 inclusive
    assert not ops.use_sparse_gossip(128, 33)
    assert ops.use_sparse_gossip(512, 74)  # two-tier at the shard scale
    assert ops._SPARSE_GOSSIP_MIN_CLIENTS_TPU == 32  # TPU floor unchanged


def test_golden_configs_resolve_dense(tiny_setting):
    """The recorded golden configs (n <= 16) must keep the dense samplers
    bit-for-bit — the dispatch rule may never flip them."""
    model, cdata, n = tiny_setting
    tr = FLTrainer(model.loss, model.init, cdata,
                   make_algo("dfedsgpsm", local_steps=1, batch_size=16),
                   TopologyConfig(kind="kout", n_clients=n, k_out=2),
                   seed=0, participation=0.25)
    assert not tr.program.sparse_mix
    state = tr.program.init(jax.random.PRNGKey(0))
    P = tr.program.mixing_matrix(jax.random.PRNGKey(1), state)
    assert isinstance(P, jnp.ndarray) and P.shape == (n, n)


def test_gossip_mode_forced_and_rejected(tiny_setting):
    model, cdata, n = tiny_setting
    kout = TopologyConfig(kind="kout", n_clients=n, k_out=2)
    algo = make_algo("dfedsgpsm", local_steps=1, batch_size=16)
    assert make_program(model.loss, model.init, cdata, algo, kout,
                        gossip="sparse").sparse_mix
    assert not make_program(model.loss, model.init, cdata, algo, kout,
                            gossip="dense").sparse_mix
    with pytest.raises(ValueError, match="auto|sparse|dense"):
        make_program(model.loss, model.init, cdata, algo, kout,
                     gossip="bogus")
    with pytest.raises(ValueError, match="full graph"):
        make_program(model.loss, model.init, cdata, algo,
                     TopologyConfig(kind="full", n_clients=n, k_out=2),
                     gossip="sparse")


# ---------------------------------------------------------------------------
# Sparse round programs end to end.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_setting():
    from repro.data.dirichlet import dirichlet_partition, stack_client_data
    from repro.data.synthetic import make_dataset
    from repro.models.small import mnist_2nn

    n = 8
    train, _ = make_dataset("mnist", 400, 50, seed=0)
    parts = dirichlet_partition(train["y"], n, alpha=0.3, seed=0)
    cdata = stack_client_data(train, parts, pad_to=32)
    return mnist_2nn(), {k: jnp.asarray(v) for k, v in cdata.items()}, n


@pytest.mark.parametrize("kind", ["ring", "exponential"])
def test_structured_rounds_sparse_equals_dense(tiny_setting, kind):
    """ring / time-varying exponential have IDENTICAL operators in both
    representations, so whole training rounds must agree to float
    tolerance — the sparse path changes the execution, not the algorithm."""
    model, cdata, n = tiny_setting
    t = TopologyConfig(kind=kind, n_clients=n, k_out=2)
    algo = make_algo("dfedsgpsm", local_steps=2, batch_size=16)
    runs = {}
    for mode in ("dense", "sparse"):
        tr = FLTrainer(model.loss, model.init, cdata, algo, t, seed=0,
                       participation=0.25, gossip=mode)
        for _ in range(3):
            m = tr.run_round()
        runs[mode] = (float(m["loss"]), np.asarray(tr.state.params),
                      np.asarray(tr.state.w))
    np.testing.assert_allclose(runs["dense"][0], runs["sparse"][0],
                               rtol=1e-4)
    np.testing.assert_allclose(runs["dense"][1], runs["sparse"][1],
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(runs["dense"][2], runs["sparse"][2],
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("name", ["dfedsgpsm", "dfedsgpsm_s", "dfedsam"])
def test_sparse_rounds_train_and_conserve_mass(tiny_setting, name):
    """Forced-sparse sampled families: finite metrics, conserved push-sum
    mass, and the scanned superstep driver both work on neighbor lists."""
    model, cdata, n = tiny_setting
    t = TopologyConfig(kind="kout", n_clients=n, k_out=2)
    tr = FLTrainer(model.loss, model.init, cdata,
                   make_algo(name, local_steps=2, batch_size=16), t,
                   seed=0, participation=0.25, gossip="sparse")
    assert tr.program.sparse_mix
    first = tr.run_round()
    for _ in range(3):
        last = tr.run_round()
    assert np.isfinite(float(last["loss"]))
    assert float(last["loss"]) < float(first["loss"])
    np.testing.assert_allclose(float(tr.state.w.sum()), n, atol=1e-3)
    state = tr.program.init(jax.random.PRNGKey(1))
    state, hist = tr.program.run_superstep(state, 3)
    assert hist["loss"].shape == (3,)
    assert np.all(np.isfinite(np.asarray(hist["loss"])))


# ---------------------------------------------------------------------------
# Kernel tiling sweep (multi-block pallas path, padded D).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,D,block_d", [(20, 130, 64), (37, 777, 256),
                                         (8, 512, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gossip_gather_blocked(n, D, block_d, dtype):
    nl = topo.sample_kout_neighbors(jax.random.PRNGKey(0), n,
                                    max(1, n // 4))
    X = jax.random.normal(jax.random.PRNGKey(1), (n, D), dtype)
    got = gossip_gather_pallas(nl.idx, nl.wgt, X, block_d=block_d,
                               interpret=True)
    want = ref.gossip_gather_ref(nl.idx, nl.wgt, X)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
