"""CommPlan: the single communication-plan layer under mix, dispatch, store.

Pins the contracts the three consumers rely on: (1) the per-family k_in
table is ONE table (family_k_in == neighbor_k_max - 1 == active_k_in);
(2) the plan's in-neighbor sets equal the nonzero off-diagonal columns of
the densified sampled operator for every family, including every hop of
the time-varying exponential cycle; (3) the static ShiftLeg transport
delivers exactly the remote rows each shard's receivers read, and the
dynamic capacity is never exceeded by a sampled realization; (4) the
backend dispatch rule routes dense / sparse / xla / halo as documented;
(5) `launch.sharding.constrain` skips sharding constraints inside a
`shard_map` manual region by positive detection — not by swallowing
exceptions — so a genuinely failing constraint still raises.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.plan import CommPlan, HaloBackend, resolve_backend
from repro.core import topology as topo
from repro.core.topology import TopologyConfig
from repro.launch import sharding as shlib

N = 64


def _cfg(kind, **kw):
    kw.setdefault("n_clients", N)
    kw.setdefault("k_out", {"ring": 1, "exponential": 1}.get(kind, 4))
    if kind == "two_tier":
        kw.setdefault("n_pods", 8)
    return TopologyConfig(kind=kind, **kw)


ALL_KINDS = ["ring", "exponential", "kout", "two_tier", "symmetric", "full"]


# ---------------------------------------------------------------------------
# (1) One k_in table.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ALL_KINDS)
def test_k_in_single_source_of_truth(kind):
    cfg = _cfg(kind)
    k_in = topo.family_k_in(cfg)
    assert topo.neighbor_k_max(cfg) == k_in + 1
    if kind in ("ring", "exponential", "kout", "two_tier"):
        assert topo.active_k_in(cfg) == k_in
        plan = CommPlan.build(cfg)
        assert plan.k_in == k_in and plan.k_max == k_in + 1
    # the symmetric mixer overrides every family to the matching graph
    assert topo.family_k_in(cfg, "symmetric") == 2 * cfg.k_out


def test_k_in_matches_sampled_list_shapes():
    """The table IS the slot count of the concrete samplers."""
    key = jax.random.PRNGKey(0)
    for kind in ("ring", "exponential", "kout", "symmetric", "two_tier"):
        cfg = _cfg(kind)
        nl = topo.sample_neighbors(key, cfg)
        if kind == "two_tier":
            # inter list: self slot + k_out cross edges; intra covers the
            # pod's ps - 1 other senders — together the table entry.
            ps = cfg.n_clients // cfg.n_pods
            assert nl.inter.idx.shape[1] == cfg.k_out + 1
            assert topo.family_k_in(cfg) == ps - 1 + cfg.k_out
        else:
            assert nl.idx.shape[1] == topo.neighbor_k_max(cfg)


# ---------------------------------------------------------------------------
# (2) Plan in-neighbors == dense operator support (every family, every hop).
# ---------------------------------------------------------------------------

def _dense_support(P):
    """Off-diagonal nonzero columns per row of a densified operator."""
    P = np.asarray(P)
    return [
        set(np.flatnonzero(P[i]).tolist()) - {i} for i in range(P.shape[0])
    ]


@pytest.mark.parametrize("kind,t", [
    ("ring", 0),
    ("exponential", 0),
    ("exponential_cycle", 0),
    ("exponential_cycle", 1),
    ("exponential_cycle", 5),   # wraps past log2(N) hops
    ("kout", 0),
    ("two_tier", 0),
])
def test_plan_in_neighbors_match_dense_support(kind, t):
    """`CommPlan.in_neighbors` over the full active set names exactly the
    senders the densified sampled operator reads — the pager's fault-in
    set and the mixing support can never disagree."""
    tv = kind == "exponential_cycle"
    cfg = _cfg("exponential" if tv else kind, time_varying=tv)
    plan = CommPlan.build(cfg)
    key = jax.random.PRNGKey(7)
    op = topo.sample_neighbors(key, cfg, t=t)
    dense = (
        topo.dense_from_two_tier(op)
        if cfg.kind == "two_tier"
        else topo.dense_from_neighbors(op, N)
    )
    support = _dense_support(dense)
    picks = np.asarray(plan.in_neighbors(key, jnp.arange(N, dtype=jnp.int32), t=t))
    assert picks.shape == (N, plan.k_in)
    for i in range(N):
        assert set(picks[i].tolist()) == support[i], f"row {i}"


# ---------------------------------------------------------------------------
# (3) Static legs cover exactly the shard reads; dynamic capacity bounds.
# ---------------------------------------------------------------------------

def _legs_delivered(plan, shard):
    """Global rows the ShiftLeg transport delivers to `shard`."""
    rows = []
    for leg in plan.legs:
        src = (shard - leg.delta) % plan.n_shards
        rows.extend(src * plan.m + off for off in leg.offsets)
    return set(rows)


@pytest.mark.parametrize("kind,tv", [
    ("ring", False), ("exponential", False), ("exponential", True),
])
@pytest.mark.parametrize("n_shards", [2, 4, 8])
def test_static_legs_cover_shard_reads(kind, tv, n_shards):
    cfg = _cfg(kind, time_varying=tv)
    plan = CommPlan.build(cfg, n_shards=n_shards)
    assert plan.static and plan.legs
    hops = (
        range(max(int(np.ceil(np.log2(N))), 1)) if tv else [0]
    )
    for t in hops:
        nl = topo.sample_neighbors(jax.random.PRNGKey(0), cfg, t=t)
        for s in range(n_shards):
            need = set(plan.shard_remote_rows(nl, s).tolist())
            got = _legs_delivered(plan, s)
            assert need <= got, f"t={t} shard {s}: missing {need - got}"
    if not tv:
        # single-hop plans are exact, not just covering
        nl = topo.sample_neighbors(jax.random.PRNGKey(0), cfg)
        for s in range(n_shards):
            assert _legs_delivered(plan, s) == set(
                plan.shard_remote_rows(nl, s).tolist()
            )


@pytest.mark.parametrize("kind", ["kout", "two_tier", "symmetric"])
def test_dynamic_capacity_bounds_sampled_realizations(kind):
    mixer_kind = "symmetric" if kind == "symmetric" else "directed"
    cfg = _cfg(kind)
    plan = CommPlan.build(cfg, n_shards=8, mixer_kind=mixer_kind)
    assert not plan.static
    for seed in range(5):
        op = topo.sample_neighbors(jax.random.PRNGKey(seed), cfg)
        nl = op.inter if cfg.kind == "two_tier" else op
        for s in range(plan.n_shards):
            rows = plan.shard_remote_rows(nl, s)
            # per source shard, distinct requests fit the pair capacity
            for src in range(plan.n_shards):
                lo, hi = src * plan.m, (src + 1) * plan.m
                pair = rows[(rows >= lo) & (rows < hi)]
                assert pair.size <= plan.capacity
        meas = plan.measured_rows(op)
        assert meas["rows_max"] <= plan.halo_rows()


def test_halo_traffic_accounting():
    ring = CommPlan.build(_cfg("ring"), n_shards=8)
    assert ring.halo_rows() == 1                  # one boundary row
    assert ring.request_ints() == 0               # static: no index traffic
    assert ring.allgather_rows() == 7 * 8
    assert ring.halo_bytes(d=100) == 400
    assert ring.allgather_bytes(d=100) == 7 * 8 * 100 * 4
    kout = CommPlan.build(_cfg("kout"), n_shards=8)
    assert kout.halo_rows() == 7 * kout.capacity
    assert kout.request_ints() == 7 * kout.capacity
    one = CommPlan.build(_cfg("kout"), n_shards=1)
    assert one.halo_rows() == 0 and one.allgather_rows() == 0


def test_plan_store_side_matches_topology():
    cfg = _cfg("kout")
    plan = CommPlan.build(cfg)
    assert plan.pageable
    from repro.store import paging

    assert plan.closure_bound(16) == paging.closure_bound(
        N, 16, topo.active_k_in(cfg)
    )
    sym = CommPlan.build(_cfg("symmetric"))
    assert not sym.pageable
    with pytest.raises(ValueError, match="no active-set"):
        sym.closure_bound(16)


def test_build_rejects_indivisible_shards():
    with pytest.raises(ValueError, match="divisible"):
        CommPlan.build(_cfg("ring"), n_shards=7)


# ---------------------------------------------------------------------------
# (4) The dispatch rule.
# ---------------------------------------------------------------------------

def _mesh1(axis="clients"):
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()[:1]), (axis,))


def test_resolve_backend_without_mesh():
    cfg = _cfg("ring")
    assert resolve_backend("auto", True, cfg, "directed") is None
    assert resolve_backend("sparse", True, cfg, "directed") is None
    assert resolve_backend("xla", True, cfg, "directed") == "xla"
    with pytest.raises(ValueError, match="halo"):
        resolve_backend("halo", True, cfg, "directed")
    with pytest.raises(ValueError, match="gossip must be"):
        resolve_backend("bogus", True, cfg, "directed")


def test_resolve_backend_with_mesh():
    cfg = _cfg("ring")
    mesh = _mesh1()
    # dense representation under a mesh: the partitioner needs plain HLO
    assert resolve_backend("dense", False, cfg, "directed", mesh) == "xla"
    assert resolve_backend("xla", True, cfg, "directed", mesh) == "xla"
    b = resolve_backend("halo", True, cfg, "directed", mesh)
    assert isinstance(b, HaloBackend) and b.axis == "clients"
    # auto on a single-shard axis: nothing crosses shards, all-gather is free
    assert resolve_backend("auto", True, cfg, "directed", mesh) == "xla"
    # a mesh without the bank-row axis is no mesh at all for the bank
    assert resolve_backend("auto", True, cfg, "directed",
                           _mesh1("data")) is None


# ---------------------------------------------------------------------------
# (5) Manual-region detection: constrain skips by detection, not except.
# ---------------------------------------------------------------------------

def test_in_manual_region_detection():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = _mesh1("data")
    assert shlib.in_manual_region(mesh) is False
    seen = {}

    def body(x):
        seen["inside"] = shlib.in_manual_region(mesh)
        return shlib.constrain(x + 1.0, ("batch", "embed"))  # must not raise

    with shlib.use_mesh(mesh):
        out = shard_map(body, mesh=mesh, in_specs=P("data"),
                        out_specs=P("data"))(jnp.ones((4, 8)))
        assert seen["inside"] is True
        # outside the region the constraint applies normally
        y = shlib.constrain(jnp.ones((4, 8)), ("batch", "embed"))
    np.testing.assert_array_equal(np.asarray(out), 2.0)
    np.testing.assert_array_equal(np.asarray(y), 1.0)
    assert shlib.in_manual_region(mesh) is False


def test_constrain_spmd_axis_vmap_still_constrained():
    """`vmap(spmd_axis_name=...)` is NOT a manual region — constraints
    there are valid, wanted, and must keep flowing to the partitioner."""
    mesh = _mesh1("data")
    with shlib.use_mesh(mesh):
        out = jax.vmap(
            lambda x: shlib.constrain(x * 2.0, ("embed",)),
            spmd_axis_name="data",
        )(jnp.ones((4, 8)))
    np.testing.assert_array_equal(np.asarray(out), 2.0)


def test_constrain_propagates_real_errors(monkeypatch):
    """The old implementation swallowed EVERY exception from
    with_sharding_constraint; a malformed constraint must now raise."""
    mesh = _mesh1("data")

    def boom(*a, **k):
        raise ValueError("malformed sharding constraint")

    with shlib.use_mesh(mesh):
        monkeypatch.setattr(jax.lax, "with_sharding_constraint", boom)
        with pytest.raises(ValueError, match="malformed"):
            shlib.constrain(jnp.ones((4, 8)), ("batch", "embed"))
