"""Distribution layer: sharding rule resolution + multi-device round-trip
of the pod push-sum gossip (subprocess with forced host device count)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import spec_for
from repro.launch.steps import StepConfig, make_train_step, pod_mixing_matrix
from repro.models.pdefs import PDef


# ---------------------------------------------------------------------------
# spec_for rules (pure; uses an abstract mesh description).
# ---------------------------------------------------------------------------

class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_spec_moe_expert_parallel():
    pdef = PDef((61, 256, 7168, 2048), ("layers", "expert", "embed", "mlp"))
    assert spec_for(pdef, _FakeMesh()) == P(None, "model", "data", None)


def test_spec_head_dim_fallback_when_heads_indivisible():
    # phi3: 40 heads % 16 != 0 -> falls back to head_dim
    pdef = PDef((5120, 40, 128), ("embed", "heads", "head_dim"))
    assert spec_for(pdef, _FakeMesh()) == P("data", None, "model")


def test_spec_vocab_and_embed():
    pdef = PDef((262144, 3840), ("vocab", "embed"))
    assert spec_for(pdef, _FakeMesh()) == P("model", "data")


def test_spec_cache_batch_priority():
    pdef = PDef((128, 32768, 8, 256), ("batch", "seq", "kv_heads", "head_dim"))
    # kv=8 indivisible by 16 -> head_dim gets model; batch gets data
    assert spec_for(pdef, _FakeMesh()) == P("data", None, None, "model")


def test_spec_seq_fallback_for_batch_one():
    pdef = PDef((1, 524288, 8, 256), ("batch", "seq", "kv_heads", "head_dim"))
    assert spec_for(pdef, _FakeMesh()) == P(None, "data", None, "model")


def test_spec_no_fsdp():
    pdef = PDef((4096, 11008), ("embed", "mlp"))
    assert spec_for(pdef, _FakeMesh(), fsdp=False) == P(None, "model")


def test_pod_mixing_matrix_column_stochastic():
    for n in (1, 2, 4):
        Ppod = np.asarray(pod_mixing_matrix(n))
        np.testing.assert_allclose(Ppod.sum(0), 1.0, atol=1e-6)


def test_pod_mixing_neighbors_densifies_to_matrix():
    from repro.core.topology import dense_from_neighbors
    from repro.launch.steps import pod_mixing_neighbors

    for n in (1, 2, 4, 8):
        nl = pod_mixing_neighbors(n)
        np.testing.assert_allclose(
            np.asarray(dense_from_neighbors(nl, n)),
            np.asarray(pod_mixing_matrix(n)), atol=1e-6)


# ---------------------------------------------------------------------------
# Local-step semantics == FL-engine inner loop.
# ---------------------------------------------------------------------------

def test_train_step_matches_manual_sam_momentum():
    from repro.configs.registry import get_config, make_batch
    from repro.models.registry import get_model_api
    from repro.core.sam import sam_gradient, momentum_update, apply_update

    cfg = get_config("codeqwen1.5-7b", smoke=True)
    api = get_model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 8, seed=0)
    sc = StepConfig(lr=0.1, alpha=0.9, rho=0.05)
    step = jax.jit(make_train_step(api, sc))
    p1, v1, loss = step(params, jax.tree.map(jnp.zeros_like, params),
                        jnp.float32(1.25), batch)

    z = jax.tree.map(lambda p: (p / 1.25).astype(p.dtype), params)
    g, _ = sam_gradient(api.loss, z, batch, sc.rho)
    v_ref = momentum_update(jax.tree.map(jnp.zeros_like, params), g, sc.alpha)
    p_ref = apply_update(params, v_ref, sc.lr)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=2e-2, atol=2e-4)


def test_microbatched_loss_matches_whole_batch_metrics():
    """Gradient accumulation must not change the reported metrics: the
    (loss, ce, acc) triple is accumulated through the microbatch scan, so
    microbatches > 1 reports the TRUE accuracy (it used to hardcode 0)."""
    from repro.configs.registry import get_config, make_batch
    from repro.launch.steps import _microbatched_loss
    from repro.models.registry import get_model_api

    cfg = get_config("xlstm-350m", smoke=True)
    api = get_model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 16, seed=0)

    loss_w, (ce_w, acc_w) = api.loss(params, batch)
    loss_m, (ce_m, acc_m) = _microbatched_loss(api.loss, 2)(params, batch)
    # equal-size chunks: mean-of-chunk-means == whole-batch mean
    np.testing.assert_allclose(float(loss_m), float(loss_w), rtol=1e-5)
    np.testing.assert_allclose(float(ce_m), float(ce_w), rtol=1e-5)
    np.testing.assert_allclose(float(acc_m), float(acc_w), rtol=1e-5,
                               atol=1e-7)
    # the gradient path (checkpointed scan) agrees too
    g_w, _ = jax.grad(api.loss, has_aux=True)(params, batch)
    g_m, _ = jax.grad(_microbatched_loss(api.loss, 2), has_aux=True)(
        params, batch)
    for a, b in zip(jax.tree.leaves(g_m), jax.tree.leaves(g_w)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-6)


def test_train_step_reports_metrics_dict():
    """train_step surfaces {loss, acc} — microbatched or not."""
    from repro.configs.registry import get_config, make_batch
    from repro.models.registry import get_model_api
    from repro.launch.steps import make_train_step

    cfg = get_config("xlstm-350m", smoke=True)
    api = get_model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 16, seed=0)
    v0 = jax.tree.map(jnp.zeros_like, params)
    metrics = {}
    for n_micro in (1, 2):
        sc = StepConfig(lr=0.05, alpha=0.9, rho=0.0, microbatches=n_micro)
        step = jax.jit(make_train_step(api, sc))
        _, _, metrics[n_micro] = step(params, v0, jnp.float32(1.0), batch)
    for m in metrics.values():
        assert set(m) == {"loss", "acc"}
        assert np.isfinite(float(m["loss"]))
    np.testing.assert_allclose(float(metrics[2]["acc"]),
                               float(metrics[1]["acc"]), rtol=1e-5, atol=1e-7)


def _pod_setting(n_pods=2):
    from repro.configs.registry import get_config, make_batch
    from repro.models.registry import get_model_api

    cfg = get_config("xlstm-350m", smoke=True)
    api = get_model_api(cfg)
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_pods,) + x.shape),
        api.init(jax.random.PRNGKey(0)))
    v = jax.tree.map(jnp.zeros_like, params)
    w = jnp.ones((n_pods,))
    batch = make_batch(cfg, 4, 16, seed=0)
    batches = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_pods, 1) + x.shape), batch)
    return api, params, v, w, batches


def test_round_step_accepts_neighbor_list_P_pod():
    """The pod round mixes identically through the dense matrix and its
    neighbor-list form — the sparse representation changes execution, not
    the algorithm."""
    from repro.launch.steps import StepConfig, make_round_step, \
        pod_mixing_neighbors

    api, params, v, w, batches = _pod_setting()
    step = jax.jit(make_round_step(api, StepConfig(lr=0.05, rho=0.0)))
    p1, v1, w1, _, _, m1 = step(params, v, w, (), (), batches,
                                pod_mixing_matrix(2))
    p2, v2, w2, _, _, m2 = step(params, v, w, (), (), batches,
                                pod_mixing_neighbors(2))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), rtol=1e-6)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-6)
    # leafwise mixing has no bank layout to gather from
    leafwise = make_round_step(api, StepConfig(lr=0.05, rho=0.0),
                               flat_mix=False)
    with pytest.raises(ValueError, match="flat_mix"):
        leafwise(params, v, w, (), (), batches, pod_mixing_neighbors(2))


def test_round_step_threads_ef_residual_state():
    """topk_ef in the pod round: the residual bank carries across rounds
    (ROADMAP 'stateless compressors only' restriction lifted) and error
    feedback holds exactly: compressed + residual' == bank + residual."""
    from repro.core.flat import make_spec
    from repro.launch.steps import (
        StepConfig,
        init_pod_comp_state,
        make_round_step,
        resolve_compressor,
    )

    api, params, v, w, batches = _pod_setting()
    sc = StepConfig(lr=0.05, rho=0.0, compressor="topk_ef", topk_ratio=0.1)
    comp = resolve_compressor(sc)
    c0 = init_pod_comp_state(comp, params)
    assert c0.shape[0] == 2 and not np.any(np.asarray(c0))
    step = jax.jit(make_round_step(api, sc, compressor=comp))
    p1, v1, w1, c1, _, m1 = step(params, v, w, c0, (), batches,
                                 pod_mixing_matrix(2))
    assert c1.shape == c0.shape
    assert np.any(np.asarray(c1))  # residual bank is live after round 1
    assert np.isfinite(float(m1["loss"]))
    # second round consumes the carried residual without shape drift
    p2, v2, w2, c2, _, m2 = step(p1, v1, w1, c1, (), batches,
                                 pod_mixing_matrix(2))
    assert c2.shape == c0.shape and np.isfinite(float(m2["loss"]))
    np.testing.assert_allclose(float(w2.sum()), 2.0, atol=1e-4)


def test_round_step_threads_link_carry():
    """Unreliable pod links: per-round drop masks draw from the link
    carry's PRNG stream, the dropped pod graph stays exactly
    column-stochastic, and delayed in-flight shares ride the carry —
    node mass + in-flight mass == n_pods at every round."""
    from repro.launch.steps import (
        StepConfig,
        init_pod_link_state,
        make_round_step,
        resolve_pod_link,
        resolve_pod_mixer,
    )

    api, params, v, w, batches = _pod_setting()
    sc = StepConfig(lr=0.05, rho=0.0, link_drop=0.3, link_delay=2)
    lm = resolve_pod_link(sc)
    mixer = resolve_pod_mixer(sc, lm)
    link = link0 = init_pod_link_state(mixer, lm, params)
    assert link0.bufx.shape[0] == 2 and link0.bufw.shape == (2, 2)
    step = jax.jit(make_round_step(api, sc, mixer=mixer, link_model=lm))
    for _ in range(3):
        params, v, w, _, link, m = step(params, v, w, (), link, batches,
                                        pod_mixing_matrix(2))
        np.testing.assert_allclose(
            float(w.sum() + link.bufw.sum()), 2.0, atol=1e-4)
        assert np.isfinite(float(m["loss"]))
    # the carry's stream advanced (fresh drop masks each round)
    assert not np.array_equal(np.asarray(link.key), np.asarray(link0.key))
    # perfect-link configs stay link-free: no carry, no extra state
    assert init_pod_link_state(
        resolve_pod_mixer(StepConfig()), None, params) == ()


def test_pod_comm_plan_is_static_ring():
    """The pod runtime's CommPlan: the directed pod ring has a static
    shift plan — one boundary row per shard pair, zero index traffic."""
    from repro.launch.steps import pod_comm_plan

    plan = pod_comm_plan(8, 4)
    assert plan.static and plan.k_in == 1
    assert plan.halo_rows() == 1 and plan.request_ints() == 0
    # a single-shard pod axis ships nothing
    assert pod_comm_plan(8, 1).halo_rows() == 0


def test_round_step_gossip_knob_validation():
    from repro.core.stages import SymmetricMixer
    from repro.launch.steps import StepConfig, make_round_step

    api, *_ = _pod_setting()
    with pytest.raises(ValueError, match="auto|xla|halo"):
        make_round_step(api, StepConfig(), gossip="bogus")
    with pytest.raises(ValueError, match="flat_mix"):
        make_round_step(api, StepConfig(), flat_mix=False, gossip="halo")
    with pytest.raises(ValueError, match="no pod halo form"):
        make_round_step(api, StepConfig(), gossip="halo",
                        mixer=SymmetricMixer())


def test_round_step_gossip_forms_agree_without_mesh():
    """Off-mesh the knob must be a pure executor choice: halo falls
    through to the local form (nothing to ship) and xla re-backs onto the
    traced-jnp twin — all three produce the same round."""
    from repro.launch.steps import StepConfig, make_round_step, \
        pod_mixing_neighbors

    api, params, v, w, batches = _pod_setting()
    nl = pod_mixing_neighbors(2)
    outs = {}
    for gossip in ("auto", "xla", "halo"):
        step = jax.jit(make_round_step(api, StepConfig(lr=0.05, rho=0.0),
                                       gossip=gossip))
        outs[gossip] = step(params, v, w, (), (), batches, nl)
    for gossip in ("xla", "halo"):
        for a, b in zip(jax.tree.leaves(outs["auto"][0]),
                        jax.tree.leaves(outs[gossip][0])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(np.asarray(outs["auto"][2]),
                                   np.asarray(outs[gossip][2]), rtol=1e-6)


# ---------------------------------------------------------------------------
# Multi-device pod gossip on a real (2,2,2) host mesh via subprocess.
# ---------------------------------------------------------------------------

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import pod_mixing_matrix

mesh = make_host_mesh((2, 2, 2), ("pod", "data", "model"))
n = 2
x = jnp.stack([jnp.full((4, 8), 1.0), jnp.full((4, 8), 3.0)])
x = jax.device_put(x, NamedSharding(mesh, P("pod", "data", "model")))
w = jnp.ones((n,))
Ppod = pod_mixing_matrix(n)

@jax.jit
def gossip(x, w):
    mix = jnp.einsum("ij,j...->i...", Ppod, x)
    return mix, Ppod @ w

for _ in range(25):
    x, w = gossip(x, w)
z = x / w[:, None, None]
np.testing.assert_allclose(np.asarray(z), 2.0, rtol=1e-5)
assert abs(float(w.sum()) - n) < 1e-5
print("OK consensus=", float(z.mean()))
"""


def test_multidevice_pod_gossip_consensus():
    import os

    env = {**os.environ, "PYTHONPATH": "src"}
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], capture_output=True,
                       text=True, env=env, cwd="/root/repo", timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK consensus= 2.0" in r.stdout


_SUBPROC_HALO = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.registry import get_config, make_batch
from repro.launch import sharding as shlib
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import StepConfig, make_round_step, \
    pod_mixing_neighbors
from repro.models.registry import get_model_api

mesh = make_host_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_config("xlstm-350m", smoke=True)
api = get_model_api(cfg)
n_pods = 2
params = jax.tree.map(lambda x: jnp.broadcast_to(x, (n_pods,) + x.shape),
                      api.init(jax.random.PRNGKey(0)))
v = jax.tree.map(jnp.zeros_like, params)
w = jnp.ones((n_pods,))
batch = make_batch(cfg, 4, 16, seed=0)
batches = jax.tree.map(lambda x: jnp.broadcast_to(x, (n_pods, 1) + x.shape),
                       batch)
nl = pod_mixing_neighbors(n_pods)
outs = {}
with shlib.use_mesh(mesh):
    pp = jax.device_put(params, jax.tree.map(
        lambda x: NamedSharding(mesh, P("pod")), params))
    for gossip in ("xla", "halo"):
        # the halo branch builds pod_comm_plan at TRACE time; a regression
        # that samples the neighbor list with traced jnp ops dies here
        step = jax.jit(make_round_step(api, StepConfig(lr=0.05, rho=0.0),
                                       gossip=gossip))
        outs[gossip] = jax.device_get(step(pp, v, w, (), (), batches, nl))
err = max(float(jnp.max(jnp.abs(jnp.asarray(a, jnp.float32)
                                - jnp.asarray(b, jnp.float32))))
          for a, b in zip(jax.tree.leaves(outs["xla"][0]),
                          jax.tree.leaves(outs["halo"][0])))
assert err < 1e-5, err
np.testing.assert_allclose(np.asarray(outs["xla"][2]),
                           np.asarray(outs["halo"][2]), rtol=1e-6)
assert abs(float(outs["halo"][2].sum()) - n_pods) < 1e-4
print("OK pod halo err=", err)
"""


def test_multidevice_pod_halo_matches_xla():
    """The pod halo executor on a REAL (2,2,2) mesh: gossip='halo' runs
    the ring's static shift plan over the "pod" axis and must match the
    all-gather executor through a full round with exact pod mass.  Also
    pins that ``pod_comm_plan`` builds eagerly inside the jit trace —
    single-device equivalence checks cannot catch either failure."""
    import os

    env = {**os.environ, "PYTHONPATH": "src"}
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC_HALO],
                       capture_output=True, text=True, env=env,
                       cwd="/root/repo", timeout=540)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK pod halo err=" in r.stdout
