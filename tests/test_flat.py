"""Flat client-parameter bank: ravel/unravel round-trips, kernel oracles,
bank checkpointing, and — the load-bearing guarantee — exact equivalence of
the flat-bank engine round with the seed pytree path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # graceful tier-1 degradation (see tests/_hyp.py)
    from _hyp import given, settings, st

from repro import checkpoint
from repro.core import FLTrainer, TopologyConfig, make_algo, make_spec
from repro.core import pushsum, topology
from repro.data.dirichlet import dirichlet_partition, stack_client_data
from repro.data.synthetic import make_dataset
from repro.kernels import ops, ref
from repro.models.small import mnist_2nn

N_CLIENTS = 8


# ---------------------------------------------------------------------------
# ravel / unravel round-trips
# ---------------------------------------------------------------------------

_DTYPES = [jnp.float32, jnp.bfloat16, jnp.int8, jnp.int32]


def _random_tree(seed: int, n_leaves: int, rng):
    """A nested mixed-dtype pytree with random leaf shapes."""
    tree, keys = {}, jax.random.split(jax.random.PRNGKey(seed), n_leaves)
    for i, k in enumerate(keys):
        shape = tuple(rng.randint(1, 5) for _ in range(rng.randint(0, 3)))
        dt = _DTYPES[rng.randint(0, len(_DTYPES) - 1)]
        if jnp.issubdtype(dt, jnp.integer):
            # Stay far inside float-exact integer range so the promoted
            # flat storage dtype round-trips losslessly.
            leaf = jax.random.randint(k, shape, -100, 100, jnp.int32).astype(dt)
        else:
            leaf = jax.random.normal(k, shape, dt)
        group = tree.setdefault(f"g{i % 3}", {})
        group[f"leaf{i}"] = leaf
    return tree


@given(st.integers(0, 999), st.integers(1, 9))
@settings(max_examples=15, deadline=None)
def test_ravel_unravel_roundtrip(seed, n_leaves):
    import random

    rng = random.Random(seed)
    tree = _random_tree(seed, n_leaves, rng)
    spec = make_spec(tree)
    row = spec.ravel(tree)
    assert row.shape == (spec.dim,)
    back = spec.unravel(row)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_ravel_unravel_stacked_roundtrip():
    tree = {
        "w": jax.random.normal(jax.random.PRNGKey(0), (3, 4)),
        "b": jnp.arange(5, dtype=jnp.bfloat16),
    }
    spec = make_spec(tree)
    stacked = jax.tree.map(
        lambda x: jnp.stack([x, 2 * x, 3 * x, 4 * x]), tree)
    bank = spec.ravel_stacked(stacked)
    assert bank.shape == (4, spec.dim)
    back = spec.unravel_stacked(bank)
    for a, b in zip(jax.tree.leaves(stacked), jax.tree.leaves(back)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))
    # row i of the bank unravels to client i's pytree
    one = spec.unravel(bank[2])
    np.testing.assert_array_equal(np.asarray(one["w"]), np.asarray(3 * tree["w"]))


def test_spec_offsets_are_contiguous():
    tree = {"a": jnp.zeros((2, 3)), "b": jnp.zeros((7,)), "c": jnp.zeros(())}
    spec = make_spec(tree)
    assert spec.offsets[0] == 0
    for o, s, o_next in zip(spec.offsets, spec.sizes, spec.offsets[1:]):
        assert o + s == o_next
    assert spec.offsets[-1] + spec.sizes[-1] == spec.dim == 2 * 3 + 7 + 1


# ---------------------------------------------------------------------------
# banked kernels vs oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d", [(3, 17), (8, 256), (5, 1000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_update_bank_matches_ref(n, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    X = jax.random.normal(ks[0], (n, d), dtype)
    V = jax.random.normal(ks[1], (n, d), jnp.float32)
    G = jax.random.normal(ks[2], (n, d), dtype)
    w = jax.random.uniform(ks[3], (n,), jnp.float32, 0.5, 2.0)
    got = ops.fused_update_bank(X, V, G, 0.9, 0.05, w)
    want = ref.fused_update_bank_ref(X, V, G, 0.9, 0.05, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    for a, b in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=tol, atol=tol)


def test_fused_update_bank_blocked_grid_path():
    """Force the multi-block pl.pallas_call route (padding + tiling)."""
    n, d = 5, 300
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    X = jax.random.normal(ks[0], (n, d))
    V = jax.random.normal(ks[1], (n, d))
    G = jax.random.normal(ks[2], (n, d))
    w = jax.random.uniform(ks[3], (n,), jnp.float32, 0.5, 2.0)
    got = ops.fused_update_bank(X, V, G, 0.5, 0.1, w, block_n=8, block_d=128)
    want = ref.fused_update_bank_ref(X, V, G, 0.5, 0.1, w)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_gossip_bank_matches_pytree_gossip():
    n, shapes = 6, ((3, 4), (7,))
    key = jax.random.PRNGKey(0)
    tree = {
        f"p{i}": jax.random.normal(k, (n,) + s)
        for i, (k, s) in enumerate(zip(jax.random.split(key, 2), shapes))
    }
    spec = make_spec(jax.tree.map(lambda x: x[0], tree))
    P = topology.sample_kout(jax.random.PRNGKey(1), n, 2)
    bank = spec.ravel_stacked(tree)
    mixed_bank = spec.unravel_stacked(pushsum.gossip_bank(P, bank))
    mixed_tree = pushsum.gossip(P, tree)
    for k in tree:
        np.testing.assert_allclose(
            np.asarray(mixed_bank[k]), np.asarray(mixed_tree[k]),
            rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# engine equivalence: flat bank vs seed pytree path
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setting():
    train, _ = make_dataset("mnist", 1200, 100, seed=0)
    parts = dirichlet_partition(train["y"], N_CLIENTS, alpha=0.3, seed=0)
    cdata = stack_client_data(train, parts, pad_to=128)
    return mnist_2nn(), {k: jnp.asarray(v) for k, v in cdata.items()}


@pytest.mark.parametrize("name", ["dfedsgpsm", "dfedavgm", "fedavg"])
def test_flat_round_matches_pytree_round(setting, name):
    model, cdata = setting
    algo = make_algo(name, local_steps=3, batch_size=32)
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)

    def trainer(flat):
        return FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0,
                         participation=0.25, flat=flat)

    trf, trp = trainer(True), trainer(False)
    for _ in range(3):
        mf = trf.run_round()
        mp = trp.run_round()
        np.testing.assert_allclose(
            float(mf["loss"]), float(mp["loss"]), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(
            float(mf["acc"]), float(mp["acc"]), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(trf.state.w), np.asarray(trp.state.w), rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(trf.average_model()),
                    jax.tree.leaves(trp.average_model())):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_flat_debiased_models_match(setting):
    model, cdata = setting
    algo = make_algo("dfedsgpsm", local_steps=2, batch_size=32)
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    trf = FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0,
                    participation=0.25, flat=True)
    trp = FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0,
                    participation=0.25, flat=False)
    trf.run_round()
    trp.run_round()
    for a, b in zip(jax.tree.leaves(trf.debiased_models()),
                    jax.tree.leaves(trp.debiased_models())):
        assert a.shape == b.shape  # client-stacked layout preserved
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(
        float(trf.consensus_error()), float(trp.consensus_error()),
        rtol=1e-3, atol=1e-6)


def test_flat_momentum_bank_populated(setting):
    model, cdata = setting
    algo = make_algo("dfedsgpsm", local_steps=2, batch_size=32)
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    tr = FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0,
                   participation=0.25, flat=True)
    assert tr.state.mom.shape == (N_CLIENTS, tr.spec.dim)
    assert not np.any(np.asarray(tr.state.mom))
    tr.run_round()
    assert np.any(np.asarray(tr.state.mom))  # end-of-round momentum stored


# ---------------------------------------------------------------------------
# time-varying exponential graphs actually vary with the round (bug fix)
# ---------------------------------------------------------------------------

def test_exponential_cycle_matrices():
    cyc = topology.exponential_cycle(16)
    assert cyc.shape == (4, 16, 16)
    for t in range(4):
        np.testing.assert_allclose(
            np.asarray(cyc[t]), np.asarray(topology.directed_exponential(16, t)))


def test_exponential_topology_varies_across_rounds(setting):
    model, cdata = setting
    algo = make_algo("dfedsgpsm", local_steps=1, batch_size=16)
    topo = TopologyConfig(kind="exponential", n_clients=N_CLIENTS, k_out=1)
    tr = FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0, flat=True)
    key = jax.random.PRNGKey(0)
    hops = tr._exp_cycle.shape[0]
    mats = [
        np.asarray(tr._mixing(key, tr.state._replace(round=jnp.int32(t))))
        for t in range(hops)
    ]
    for t in range(1, hops):
        assert not np.allclose(mats[0], mats[t]), "graph must vary with round"
    np.testing.assert_allclose(
        mats[1], np.asarray(topology.directed_exponential(N_CLIENTS, 1)))
    # the union over one cycle restores Assumption 1 connectivity
    assert topology.union_strongly_connected(mats)
    tr.run_round()  # and the round stays jittable end-to-end
    assert np.isclose(float(tr.state.w.sum()), N_CLIENTS, atol=1e-3)


# ---------------------------------------------------------------------------
# flat-bank checkpointing: one array + offsets
# ---------------------------------------------------------------------------

def test_bank_checkpoint_roundtrip(tmp_path):
    tree = {"layer": {"w": jnp.ones((2, 3)), "b": jnp.zeros((3,))}}
    spec = make_spec(tree)
    bank = jax.random.normal(jax.random.PRNGKey(0), (4, spec.dim))
    w = jnp.full((4,), 1.25)
    path = checkpoint.save_bank(str(tmp_path), 7, bank, spec, extra={"w": w})
    assert checkpoint.latest_checkpoint(str(tmp_path)) == path
    got, extra, meta = checkpoint.restore_bank(path, spec=spec)
    np.testing.assert_array_equal(got, np.asarray(bank))
    np.testing.assert_array_equal(extra["w"], np.asarray(w))
    assert meta["dim"] == spec.dim
    assert meta["offsets"] == list(spec.offsets)


def test_bank_checkpoint_v2_row_chunked_roundtrip(tmp_path):
    """Format v2: the bank and every bank-shaped extra stream into the
    archive as row chunks (the writer never holds the (n, D) bank whole on
    the host); reassembly is exact across chunk boundaries, (n,) vectors
    and scalars stay whole members."""
    tree = {"layer": {"w": jnp.ones((2, 3)), "b": jnp.zeros((3,))}}
    spec = make_spec(tree)
    n = 1000
    bank = jax.random.normal(jax.random.PRNGKey(0), (n, spec.dim))
    mom = jax.random.normal(jax.random.PRNGKey(1), (n, spec.dim))
    w = jnp.linspace(0.5, 1.5, n)
    path = checkpoint.save_bank(
        str(tmp_path), 3, bank, spec,
        extra={"mom": mom, "w": w, "round": jnp.int32(3)}, chunk_rows=128)
    with np.load(path) as data:
        chunks = [f for f in data.files if f.startswith("__bank_c")]
        assert len(chunks) == 8  # ceil(1000 / 128)
        assert "extra_mom_c00000" in data.files  # bank-shaped: chunked
        assert "extra_w" in data.files           # (n,) vector: whole
    got, extra, meta = checkpoint.restore_bank(path, spec=spec)
    assert meta["format"] == 2 and meta["bank_chunks"] == 8
    np.testing.assert_array_equal(got, np.asarray(bank))
    np.testing.assert_array_equal(extra["mom"], np.asarray(mom))
    np.testing.assert_array_equal(extra["w"], np.asarray(w))
    assert int(extra["round"]) == 3


def test_bank_checkpoint_v1_loads_transparently(tmp_path):
    """A legacy monolithic ``__bank__`` checkpoint (pre-chunking) restores
    through the same reader, extras included — old run directories stay
    resumable after the format bump."""
    import json

    from repro.checkpoint import io as ckpt_io

    spec = make_spec({"a": jnp.zeros((3,))})
    bank = np.arange(12, dtype=np.float32).reshape(4, 3)
    p = str(tmp_path / "ckpt_0.npz")
    np.savez(p, __bank__=bank,
             __bank_meta__=np.array(json.dumps(ckpt_io._spec_meta(spec))),
             extra_w=np.full((4,), 1.25, np.float32))
    got, extra, meta = checkpoint.restore_bank(p, spec=spec)
    np.testing.assert_array_equal(got, bank)
    np.testing.assert_array_equal(extra["w"], np.full((4,), 1.25,
                                                      np.float32))
    assert meta.get("format", 1) != 2


def test_bank_checkpoint_central_row(tmp_path):
    """A central (D,) row (FedAvg server state) rides the same writer as a
    single whole chunk."""
    spec = make_spec({"a": jnp.zeros((5,))})
    row = jnp.arange(5, dtype=jnp.float32)
    path = checkpoint.save_bank(str(tmp_path), 0, row, spec)
    got, _, meta = checkpoint.restore_bank(path, spec=spec)
    np.testing.assert_array_equal(got, np.asarray(row))
    assert meta["rows"] == 0


def test_bank_checkpoint_structure_mismatch(tmp_path):
    spec = make_spec({"a": jnp.zeros((3,))})
    other = make_spec({"a": jnp.zeros((4,))})
    path = checkpoint.save_bank(str(tmp_path), 0, jnp.zeros((2, 3)), spec)
    with pytest.raises(ValueError):
        checkpoint.restore_bank(path, spec=other)
    with pytest.raises(ValueError):
        checkpoint.restore_bank(
            checkpoint.save(str(tmp_path), 1, {"a": jnp.zeros(3)}))
