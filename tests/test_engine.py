"""End-to-end behaviour of the FL engine: all 10 algorithms train, push-sum
mass is conserved, and the paper's qualitative claims hold on synthetic data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ALGORITHMS, FLTrainer, TopologyConfig, make_algo
from repro.data.dirichlet import dirichlet_partition, stack_client_data
from repro.data.synthetic import make_dataset
from repro.models.small import mnist_2nn


N_CLIENTS = 8


@pytest.fixture(scope="module")
def setting():
    train, test = make_dataset("mnist", 2000, 500, seed=0)
    parts = dirichlet_partition(train["y"], N_CLIENTS, alpha=0.3, seed=0)
    cdata = stack_client_data(train, parts, pad_to=256)
    cdata = {k: jnp.asarray(v) for k, v in cdata.items()}
    testj = {k: jnp.asarray(v) for k, v in test.items()}
    return mnist_2nn(), cdata, testj


def _trainer(setting, name, **kw):
    model, cdata, _ = setting
    algo = make_algo(name, local_steps=3, batch_size=32, **kw)
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    return FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0,
                     participation=0.25)


@pytest.mark.parametrize("name", sorted(ALGORITHMS))
def test_every_algorithm_one_round(setting, name):
    tr = _trainer(setting, name)
    metrics = tr.run_round()
    assert np.isfinite(float(metrics["loss"]))
    avg = tr.average_model()
    for leaf in jax.tree.leaves(avg):
        assert np.all(np.isfinite(np.asarray(leaf)))


def test_pushsum_mass_conserved_over_training(setting):
    tr = _trainer(setting, "dfedsgpsm")
    for _ in range(5):
        tr.run_round()
    assert np.isclose(float(tr.state.w.sum()), N_CLIENTS, atol=1e-3)
    assert np.all(np.asarray(tr.state.w) > 0)


def test_training_improves_over_init(setting):
    _, _, testj = setting
    tr = _trainer(setting, "dfedsgpsm")
    l0, a0 = tr.evaluate(testj)
    tr.fit(15)
    l1, a1 = tr.evaluate(testj)
    assert l1 < l0
    assert a1 > max(a0, 0.3)


def test_sam_momentum_beats_plain_osgp(setting):
    """Paper Table 2 direction: OSGP + momentum + SAM > OSGP."""
    _, _, testj = setting
    accs = {}
    for name in ("osgp", "dfedsgpsm"):
        tr = _trainer(setting, name)
        tr.fit(15)
        accs[name] = tr.evaluate(testj)[1]
    assert accs["dfedsgpsm"] > accs["osgp"]


def test_selection_variant_trains(setting):
    _, _, testj = setting
    tr = _trainer(setting, "dfedsgpsm_s")
    tr.fit(10)
    _, acc = tr.evaluate(testj)
    assert acc > 0.3


def test_quantized_gossip_still_converges(setting):
    """Beyond-paper: int8 gossip payloads preserve convergence."""
    _, _, testj = setting
    tr = _trainer(setting, "dfedsgpsm", quantize_gossip=True)
    tr.fit(12)
    _, acc = tr.evaluate(testj)
    assert acc > 0.3
    assert np.isclose(float(tr.state.w.sum()), N_CLIENTS, atol=1e-3)


def test_fedavg_uses_global_model(setting):
    tr = _trainer(setting, "fedavg")
    tr.run_round()
    # centralized state keeps a single (unstacked) pytree
    leaf = jax.tree.leaves(tr.state.params)[0]
    assert leaf.shape[0] != N_CLIENTS or leaf.ndim == 1


def test_history_records(setting):
    tr = _trainer(setting, "osgp")
    hist = tr.fit(3, test_data=setting[2], eval_every=2)
    assert len(hist) == 3
    assert "test_acc" in hist[1]
