"""The composable round-program API: golden equivalence of every registry
algorithm with the pre-redesign engine trace, the pure init/step core under
lax.scan, compressor stage properties, and full-FLState checkpointing."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # graceful tier-1 degradation (see tests/_hyp.py)
    from _hyp import given, settings, st

from repro import checkpoint
from repro.core import (
    ALGORITHMS,
    COMPRESSORS,
    FLTrainer,
    MIXERS,
    SOLVERS,
    TopologyConfig,
    make_algo,
    make_program,
    make_stages,
)
from repro.core.stages import (
    CentralMixer,
    IdentityCompressor,
    Int8RowCompressor,
    PushSumMixer,
    SamMomentumSolver,
    SymmetricMixer,
    TopKEFCompressor,
)
from repro.data.dirichlet import dirichlet_partition, stack_client_data
from repro.data.synthetic import make_dataset
from repro.models.small import mnist_2nn

N_CLIENTS = 8

_GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                       "round_traces.json")


@pytest.fixture(scope="module")
def setting():
    train, _ = make_dataset("mnist", 1200, 100, seed=0)
    parts = dirichlet_partition(train["y"], N_CLIENTS, alpha=0.3, seed=0)
    cdata = stack_client_data(train, parts, pad_to=128)
    return mnist_2nn(), {k: jnp.asarray(v) for k, v in cdata.items()}


@pytest.fixture(scope="module")
def golden():
    with open(_GOLDEN) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# Every registry algorithm is a stage composition...
# ---------------------------------------------------------------------------

def test_registry_algorithms_resolve_to_stages():
    kinds = {"directed": PushSumMixer, "symmetric": SymmetricMixer,
             "central": CentralMixer}
    for name, algo in ALGORITHMS.items():
        solver, compressor, mixer = make_stages(algo)
        assert isinstance(solver, SamMomentumSolver), name
        assert isinstance(compressor, IdentityCompressor), name
        assert isinstance(mixer, kinds[algo.comm]), name
        assert (solver.rho, solver.alpha) == (algo.rho, algo.alpha)


def test_quantize_gossip_is_int8_rows_composition():
    _, comp, _ = make_stages(make_algo("dfedsgpsm", quantize_gossip=True))
    assert isinstance(comp, Int8RowCompressor)
    _, comp, _ = make_stages(make_algo("dfedsgpsm", compressor="topk_ef",
                                       topk_ratio=0.1))
    assert isinstance(comp, TopKEFCompressor) and comp.ratio == 0.1


def test_unknown_stage_raises():
    with pytest.raises(ValueError, match="unknown stage"):
        make_stages(make_algo("dfedsgpsm", compressor="nope"))


def test_central_rejects_compression(setting):
    """FedAvg has no gossip step — a compressor there would silently
    train uncompressed while claiming communication savings."""
    model, cdata = setting
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    for bad in (make_algo("fedavg", compressor="topk_ef"),
                make_algo("fedavg", quantize_gossip=True)):
        with pytest.raises(ValueError, match="central"):
            FLTrainer(model.loss, model.init, cdata, bad, topo)


# ---------------------------------------------------------------------------
# ...and reproduces the pre-redesign engine's metrics trace (golden).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(ALGORITHMS))
def test_composition_matches_legacy_trace(setting, golden, name):
    model, cdata = setting
    algo = make_algo(name, local_steps=golden["local_steps"],
                     batch_size=golden["batch_size"])
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    tr = FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0,
                   participation=golden["participation"])
    want = golden["traces"][name]
    for r, g in enumerate(want["rounds"]):
        m = tr.run_round()
        np.testing.assert_allclose(float(m["loss"]), g["loss"],
                                   rtol=1e-4, atol=1e-5, err_msg=f"round {r}")
        np.testing.assert_allclose(float(m["acc"]), g["acc"],
                                   rtol=1e-3, atol=1e-4, err_msg=f"round {r}")
    np.testing.assert_allclose(np.asarray(tr.state.w), np.asarray(want["w"]),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Pure functional core: lax.scan whole runs inside one jit, donated state.
# ---------------------------------------------------------------------------

def test_scan_20_rounds_one_jit(setting):
    model, cdata = setting
    algo = make_algo("dfedsgpsm", local_steps=2, batch_size=32)
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    program = make_program(model.loss, model.init, cdata, algo, topo,
                           participation=0.25)
    state = program.init(jax.random.PRNGKey(0))
    run = jax.jit(lambda s: program.run(s, 20), donate_argnums=0)
    state, hist = run(state)
    assert int(state.round) == 20
    assert hist["loss"].shape == (20,)
    assert np.all(np.isfinite(np.asarray(hist["loss"])))
    # training actually progresses inside the scan
    assert float(hist["loss"][-1]) < float(hist["loss"][0])
    # push-sum mass conserved through all 20 fused rounds
    assert np.isclose(float(state.w.sum()), N_CLIENTS, atol=1e-3)


def test_step_matches_trainer_round(setting):
    """program.step == FLTrainer.run_round — the wrapper adds nothing."""
    model, cdata = setting
    algo = make_algo("dfedsgpsm", local_steps=2, batch_size=32)
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    tr = FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0,
                   participation=0.25)
    program = tr.program
    state = program.init(jax.random.PRNGKey(0))
    for _ in range(2):
        state, m_prog = program.step(state)
        m_tr = tr.run_round()
        np.testing.assert_allclose(float(m_prog["loss"]),
                                   float(m_tr["loss"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(state.params),
                               np.asarray(tr.state.params),
                               rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# Compressor stage properties.
# ---------------------------------------------------------------------------

_COMP_DTYPES = [jnp.float32, jnp.bfloat16]


@given(st.integers(0, 999), st.integers(1, 12), st.integers(1, 64),
       st.sampled_from(sorted(COMPRESSORS)), st.integers(0, 1))
@settings(max_examples=20, deadline=None)
def test_compressor_preserves_shape_dtype(seed, n, d, name, dti):
    dtype = _COMP_DTYPES[dti]
    algo = make_algo("dfedsgpsm", topk_ratio=0.25)
    comp = COMPRESSORS[name](algo)
    X = jax.random.normal(jax.random.PRNGKey(seed), (n, d), dtype)
    state = comp.init_state(n, d)
    state, Xc = comp.apply(state, X)
    assert Xc.shape == X.shape and Xc.dtype == X.dtype
    assert np.all(np.isfinite(np.asarray(Xc, np.float32)))


@given(st.integers(0, 999), st.integers(1, 8), st.integers(2, 50),
       st.floats(0.02, 0.9))
@settings(max_examples=20, deadline=None)
def test_topk_ef_residual_sums_to_signal(seed, n, d, ratio):
    """compressed + residual' == X + residual — error feedback drops
    nothing, it only defers."""
    comp = TopKEFCompressor(ratio=ratio)
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    X = jax.random.normal(ks[0], (n, d), jnp.float32)
    resid = 0.1 * jax.random.normal(ks[1], (n, d), jnp.float32)
    resid2, Xc = comp.apply(resid, X)
    np.testing.assert_array_equal(
        np.asarray(Xc + resid2), np.asarray(X + resid))
    # sparsity: at most ~ratio of coords survive per row (ties aside)
    k = max(int(ratio * d), 1)
    nz = np.count_nonzero(np.asarray(Xc), axis=1)
    assert np.all(nz <= d)
    assert nz.mean() <= max(k + 1, 1) + 1e-9


@given(st.integers(0, 999), st.integers(1, 8), st.integers(2, 50),
       st.floats(0.02, 0.9))
@settings(max_examples=20, deadline=None)
def test_topk_ef_exact_on_bf16_bank(seed, n, d, ratio):
    """The residual is taken against the cast-back payload, so error
    feedback is EXACT for sub-f32 banks: what the bf16 cast rounds off is
    deferred to the residual, never dropped."""
    comp = TopKEFCompressor(ratio=ratio)
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    X = (3.0 * jax.random.normal(ks[0], (n, d), jnp.float32)).astype(
        jnp.bfloat16)
    resid = 0.1 * jax.random.normal(ks[1], (n, d), jnp.float32)
    resid2, Xc = comp.apply(resid, X)
    assert Xc.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(Xc, np.float32) + np.asarray(resid2),
        np.asarray(X, np.float32) + np.asarray(resid))


# ---------------------------------------------------------------------------
# Per-stage dtype policy: bf16 bank, f32 momentum + EF residual.
# ---------------------------------------------------------------------------

_DTYPE_SETTING = []


def _dtype_setting():
    # The _hyp.py fallback shim can't mix @given with pytest fixtures, so
    # the property test memoizes its own module-scoped setting.
    if not _DTYPE_SETTING:
        train, _ = make_dataset("mnist", 1200, 100, seed=0)
        parts = dirichlet_partition(train["y"], N_CLIENTS, alpha=0.3, seed=0)
        cdata = stack_client_data(train, parts, pad_to=128)
        _DTYPE_SETTING.append(
            (mnist_2nn(), {k: jnp.asarray(v) for k, v in cdata.items()}))
    return _DTYPE_SETTING[0]


@given(st.integers(0, 999))
@settings(max_examples=5, deadline=None)
def test_bank_dtype_bf16_keeps_f32_momentum_and_exact_ef(seed):
    """``bank_dtype=bf16`` halves what gossip/EF/checkpoints move, but the
    accumulators must not narrow with it: momentum and the error-feedback
    residual stay float32, so PR 3's exact-EF guarantee (what the bf16
    cast rounds off is deferred to the residual, never dropped) holds on
    the narrow bank, and push-sum mass stays exact."""
    model, cdata = _dtype_setting()
    algo = make_algo("dfedsgpsm", local_steps=2, compressor="topk_ef",
                     topk_ratio=0.25)
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    tr = FLTrainer(model.loss, model.init, cdata, algo, topo, seed=seed,
                   participation=0.5, bank_dtype=jnp.bfloat16)
    for _ in range(2):
        m = tr.run_round()
    state = tr.state
    assert state.params.dtype == jnp.bfloat16
    assert state.mom.dtype == jnp.float32
    for leaf in jax.tree.leaves(state.comp):
        assert leaf.dtype == jnp.float32  # EF residual never narrows
        assert np.all(np.isfinite(np.asarray(leaf)))
    assert np.isfinite(float(m["loss"]))
    assert np.isclose(float(state.w.sum()), N_CLIENTS, atol=1e-2)


def test_bank_dtype_composes_with_delta(setting):
    """bf16 delta bank: adapter rows are stored bf16, expansion happens in
    f32 on top of the f32 base, and the round still trains."""
    model, cdata = setting
    algo = make_algo("dfedsgpsm", local_steps=2)
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    tr = FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0,
                   participation=0.5, delta=8, bank_dtype=jnp.bfloat16)
    m = tr.run_round()
    assert tr.state.params.dtype == jnp.bfloat16
    assert tr.state.params.shape[1] == tr.spec.dim
    assert np.isfinite(float(m["loss"]))
    assert np.isclose(float(tr.state.w.sum()), N_CLIENTS, atol=1e-2)


# ---------------------------------------------------------------------------
# The configured topo.k_out is honored by EVERY sampled mixing family.
# ---------------------------------------------------------------------------

def test_mixing_matrix_honors_k_out(setting):
    """The selective (DFedSGPSM-S) and symmetric branches must use
    ``topo.k_out`` exactly like the plain k-out branch — not a link count
    re-derived from ``participation``."""
    from repro.core import make_program
    from repro.core import topology as topo_mod

    model, cdata = setting
    # participation * n = 5 != k_out = 2: the bug would pick 5 links.
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    tkey = jax.random.PRNGKey(42)
    losses = jnp.arange(N_CLIENTS, dtype=jnp.float32)

    sel = make_program(model.loss, model.init, cdata,
                       make_algo("dfedsgpsm_s"), topo, participation=0.625)
    state = sel.init(jax.random.PRNGKey(0))._replace(losses=losses)
    P = sel.mixing_matrix(tkey, state)
    np.testing.assert_array_equal(
        np.asarray(P),
        np.asarray(topo_mod.sample_kout_selective(
            tkey, losses, N_CLIENTS, topo.k_out)))
    # out-degree per sender column: k_out receivers + the self-loop
    assert np.all(np.count_nonzero(np.asarray(P), axis=0) == topo.k_out + 1)

    sym = make_program(model.loss, model.init, cdata,
                       make_algo("dfedsam"), topo, participation=0.625)
    state = sym.init(jax.random.PRNGKey(0))
    W = sym.mixing_matrix(tkey, state)
    np.testing.assert_array_equal(
        np.asarray(W),
        np.asarray(topo_mod.sample_symmetric_k_regular(
            tkey, N_CLIENTS, topo.k_out)))


def test_topk_ef_converges_end_to_end(setting):
    model, cdata = setting
    algo = make_algo("dfedsgpsm", local_steps=2, batch_size=32,
                     compressor="topk_ef", topk_ratio=0.1)
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    tr = FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0,
                   participation=0.25)
    first = tr.run_round()
    for _ in range(5):
        last = tr.run_round()
    assert float(last["loss"]) < float(first["loss"])
    assert np.isclose(float(tr.state.w.sum()), N_CLIENTS, atol=1e-3)
    assert np.any(np.asarray(tr.state.comp))  # residual bank is live


# ---------------------------------------------------------------------------
# Solver registry variants train.
# ---------------------------------------------------------------------------

def test_proximal_alpha_zero_fast_path_matches_momentum_path(setting):
    """ProximalSolver at alpha == 0 mirrors the SamMomentumSolver fast
    path (no momentum bank in the carry, V0 shared as the kernel's zero
    operand) and must equal the generic momentum-carrying code bitwise."""
    from repro.core.flat import make_spec
    from repro.core.stages import ProximalSolver

    model, cdata = setting
    solver = ProximalSolver(local_steps=3, batch_size=16, rho=0.0,
                            alpha=0.0, mu=0.1)
    spec = make_spec(jax.eval_shape(model.init, jax.random.PRNGKey(0)))
    row = spec.ravel(model.init(jax.random.PRNGKey(0)))
    X = jnp.broadcast_to(row, (N_CLIENTS, spec.dim))
    w = jnp.ones((N_CLIENTS,))
    keys = jax.random.split(jax.random.PRNGKey(1), N_CLIENTS)

    Xf, Vf, lf, af = solver.update(model.loss, spec, X, w, keys, cdata, 0.1)
    grad_one = solver._grad_one(model.loss, spec)
    V0 = jnp.zeros_like(X, jnp.float32)
    Xg, Vg, lg, ag = solver._update_momentum(
        grad_one, spec, X, X, V0, w, keys, cdata, 0.1)
    np.testing.assert_array_equal(np.asarray(Xf), np.asarray(Xg))
    np.testing.assert_array_equal(np.asarray(lf), np.asarray(lg))
    np.testing.assert_array_equal(np.asarray(af), np.asarray(ag))
    # momentum off: the fast path's reported bank is the shared zero bank
    assert Xf is not Vf and not np.any(np.asarray(Vf))


def test_central_round_refreshes_losses(setting):
    """FLState.losses on the central path must pick up the sampled
    clients' end-of-round losses (it rides checkpoints and drives
    selection) — it used to stay zeros forever."""
    model, cdata = setting
    algo = make_algo("fedavg", local_steps=2, batch_size=16)
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    for flat in (True, False):
        tr = FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0,
                       participation=0.25, flat=flat)
        tr.run_round()
        losses = np.asarray(tr.state.losses)
        m = max(int(0.25 * N_CLIENTS), 1)
        assert np.count_nonzero(losses) == m, (flat, losses)
        assert np.all(losses[losses != 0] > 0)


@pytest.mark.parametrize("solver", ["sgd", "proximal"])
def test_alternative_solvers_train(setting, solver):
    model, cdata = setting
    algo = make_algo("dfedsgpsm", local_steps=2, batch_size=32,
                     solver=solver, prox_mu=0.1)
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    tr = FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0,
                   participation=0.25)
    first = tr.run_round()
    for _ in range(3):
        last = tr.run_round()
    assert float(last["loss"]) < float(first["loss"])


# ---------------------------------------------------------------------------
# Full-FLState checkpointing: warm restart is bit-warm, not just params.
# ---------------------------------------------------------------------------

def test_save_restore_full_state_resumes_identically(setting, tmp_path):
    model, cdata = setting
    algo = make_algo("dfedsgpsm", local_steps=2, batch_size=32,
                     compressor="topk_ef")
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)

    def trainer():
        return FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0,
                         participation=0.25)

    tr = trainer()
    tr.run_round()
    tr.run_round()
    path = tr.save(str(tmp_path), 2)
    m_ref = tr.run_round()  # round 3 on the live trainer

    tr2 = trainer()
    state = tr2.restore(path)
    assert int(state.round) == 2
    assert state.comp.shape == tr.state.comp.shape  # EF residual restored
    assert np.any(np.asarray(state.comp))
    m_resumed = tr2.run_round()  # round 3 after a cold-process restart
    np.testing.assert_allclose(float(m_resumed["loss"]),
                               float(m_ref["loss"]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(tr2.state.params),
                               np.asarray(tr.state.params),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(tr2.state.w),
                                  np.asarray(tr.state.w))


def test_restore_rejects_compressor_state_mismatch(setting, tmp_path):
    model, cdata = setting
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)

    def trainer(**kw):
        algo = make_algo("dfedsgpsm", local_steps=1, batch_size=16, **kw)
        return FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0,
                         participation=0.25)

    plain = trainer()
    plain.run_round()
    p_plain = plain.save(str(tmp_path / "plain"), 1)
    ef = trainer(compressor="topk_ef")
    ef.run_round()
    p_ef = ef.save(str(tmp_path / "ef"), 1)

    with pytest.raises(ValueError, match="no compressor state"):
        trainer(compressor="topk_ef").restore(p_plain)
    with pytest.raises(ValueError, match="stateless"):
        trainer().restore(p_ef)


def test_restore_state_rejects_params_only_checkpoint(tmp_path):
    from repro.core import make_spec

    spec = make_spec({"a": jnp.zeros((3,))})
    path = checkpoint.save_bank(str(tmp_path), 0, jnp.zeros((2, 3)), spec)
    with pytest.raises(ValueError, match="full-FLState"):
        checkpoint.restore_state(path, spec)


# ---------------------------------------------------------------------------
# Pod path consumes the same stages.
# ---------------------------------------------------------------------------

def test_pod_round_step_compressor_stages():
    """Stateful compressors are first-class in the pod round (the EF
    residual rides the ``comp`` carry); only genuinely unrepresentable
    combinations are rejected."""
    from repro.configs.registry import get_config
    from repro.launch.steps import (
        StepConfig,
        init_pod_comp_state,
        make_round_step,
        resolve_compressor,
    )
    from repro.models.registry import get_model_api

    api = get_model_api(get_config("xlstm-350m", smoke=True))
    # topk_ef resolves (by object and by StepConfig name) instead of raising
    make_round_step(api, StepConfig(), compressor=TopKEFCompressor())
    make_round_step(api, StepConfig(compressor="topk_ef", topk_ratio=0.1))
    comp = resolve_compressor(StepConfig(compressor="topk_ef"))
    assert comp.stateful
    params = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (2,) + x.shape),
        api.init(jax.random.PRNGKey(0)))
    c0 = init_pod_comp_state(comp, params)
    assert c0.shape[0] == 2 and c0.dtype == jnp.float32
    assert init_pod_comp_state(IdentityCompressor(), params) == ()
    with pytest.raises(ValueError, match="unknown compressor"):
        make_round_step(api, StepConfig(compressor="bogus"))
    with pytest.raises(ValueError, match="flat_mix"):
        make_round_step(api, StepConfig(), flat_mix=False,
                        compressor=Int8RowCompressor())


def test_oracle_path_rejects_unrepresentable_compositions(setting):
    """flat=False must never silently run a different algorithm than the
    stage composition it is supposed to be the oracle for."""
    model, cdata = setting
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    for bad in (make_algo("dfedsgpsm", solver="sgd"),
                make_algo("dfedsgpsm", compressor="topk_ef")):
        with pytest.raises(ValueError, match="oracle"):
            FLTrainer(model.loss, model.init, cdata, bad, topo, flat=False)
