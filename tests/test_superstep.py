"""The jit-resident superstep driver: ``program.run_superstep`` scans whole
supersteps of rounds inside one jit (donated carry, in-scan masked eval) and
must reproduce the golden per-round metrics trace and the per-round Python
loop exactly — including across checkpoint resume."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ALGORITHMS, FLTrainer, TopologyConfig, make_algo
from repro.data.dirichlet import dirichlet_partition, stack_client_data
from repro.data.synthetic import make_dataset
from repro.models.small import mnist_2nn

N_CLIENTS = 8

_GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                       "round_traces.json")


@pytest.fixture(scope="module")
def setting():
    train, test = make_dataset("mnist", 1200, 100, seed=0)
    parts = dirichlet_partition(train["y"], N_CLIENTS, alpha=0.3, seed=0)
    cdata = stack_client_data(train, parts, pad_to=128)
    testj = {k: jnp.asarray(v) for k, v in test.items()}
    return mnist_2nn(), {k: jnp.asarray(v) for k, v in cdata.items()}, testj


@pytest.fixture(scope="module")
def golden():
    with open(_GOLDEN) as f:
        return json.load(f)


def _trainer(setting, name, **kw):
    model, cdata, _ = setting
    algo = make_algo(name, local_steps=3, batch_size=32, **kw)
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    return FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0,
                     participation=0.25)


# ---------------------------------------------------------------------------
# The scanned driver is pinned by the same oracle as the round program:
# tests/golden/round_traces.json, round for round.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(ALGORITHMS))
def test_superstep_reproduces_golden_trace(setting, golden, name):
    model, cdata, _ = setting
    algo = make_algo(name, local_steps=golden["local_steps"],
                     batch_size=golden["batch_size"])
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    tr = FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0,
                   participation=golden["participation"])
    want = golden["traces"][name]
    rounds = len(want["rounds"])
    # Whole run = ONE superstep = one lax.scan inside one jit.
    hist = tr.fit(rounds)
    for r, g in enumerate(want["rounds"]):
        np.testing.assert_allclose(hist[r]["loss"], g["loss"],
                                   rtol=1e-4, atol=1e-5, err_msg=f"round {r}")
        np.testing.assert_allclose(hist[r]["acc"], g["acc"],
                                   rtol=1e-3, atol=1e-4, err_msg=f"round {r}")
    np.testing.assert_allclose(np.asarray(tr.state.w), np.asarray(want["w"]),
                               rtol=1e-5, atol=1e-6)
    assert int(tr.state.round) == rounds


# ---------------------------------------------------------------------------
# fit (superstep-backed) == the per-round Python loop, metric for metric.
# ---------------------------------------------------------------------------

def test_fit_matches_python_loop_stream(setting):
    """`fit` (chunked supersteps, in-scan eval) and a manual
    run_round/evaluate loop produce identical metric streams and states."""
    _, _, testj = setting
    tr_scan = _trainer(setting, "dfedsgpsm")
    hist = tr_scan.fit(5, test_data=testj, eval_every=2, superstep=3)

    tr_loop = _trainer(setting, "dfedsgpsm")
    for r in range(5):
        m = tr_loop.run_round()
        rec = hist[r]
        assert rec["round"] == r
        np.testing.assert_allclose(rec["loss"], float(m["loss"]), rtol=1e-5)
        np.testing.assert_allclose(rec["acc"], float(m["acc"]), rtol=1e-5)
        if (r + 1) % 2 == 0:
            tl, ta = tr_loop.evaluate(testj)
            np.testing.assert_allclose(rec["test_loss"], tl, rtol=1e-5)
            np.testing.assert_allclose(rec["test_acc"], ta, rtol=1e-5)
        else:
            assert "test_acc" not in rec
    np.testing.assert_allclose(np.asarray(tr_scan.state.params),
                               np.asarray(tr_loop.state.params),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(tr_scan.state.w),
                                  np.asarray(tr_loop.state.w))


def test_run_superstep_history_shapes_and_mask(setting):
    """Stacked (rounds,) history with a validity mask: eval slots are zero
    (and masked) on non-eval rounds, populated at the cadence."""
    _, _, testj = setting
    tr = _trainer(setting, "dfedsgpsm")
    state, hist = tr.program.run_superstep(tr.state, 6, eval_every=3,
                                           test_data=testj)
    for key in ("loss", "acc", "test_loss", "test_acc", "eval_mask"):
        assert hist[key].shape == (6,), key
    mask = np.asarray(hist["eval_mask"])
    np.testing.assert_array_equal(
        mask, [False, False, True, False, False, True])
    ta = np.asarray(hist["test_acc"])
    assert np.all(ta[~mask] == 0.0)
    assert np.all(ta[mask] > 0.0)
    assert int(state.round) == 6


def test_superstep_eval_cadence_follows_global_round(setting):
    """The eval schedule is part of the algorithm: it keys on the global
    round counter, so chunked supersteps keep one schedule."""
    _, _, testj = setting
    tr = _trainer(setting, "dfedsgpsm")
    tr.fit(2)  # advance to global round 2 without eval
    _, hist = tr.program.run_superstep(tr.state, 4, eval_every=3,
                                       test_data=testj)
    # global rounds 3,4,5,6 -> eval at 3 and 6
    np.testing.assert_array_equal(np.asarray(hist["eval_mask"]),
                                  [True, False, False, True])


# ---------------------------------------------------------------------------
# Resume: a mid-run full-FLState checkpoint continues the same trajectory.
# ---------------------------------------------------------------------------

def test_superstep_resume_matches_uninterrupted(setting, tmp_path):
    _, _, testj = setting
    # topk_ef: the compressor residual bank must survive the round trip too.
    tr = _trainer(setting, "dfedsgpsm", compressor="topk_ef")
    tr.fit(2)
    path = tr.save(str(tmp_path), 2)
    ref = tr.fit(3, test_data=testj, eval_every=2)  # global rounds 3-5

    tr2 = _trainer(setting, "dfedsgpsm", compressor="topk_ef")
    state = tr2.restore(path)
    assert int(state.round) == 2
    resumed = tr2.fit(3, test_data=testj, eval_every=2)
    for a, b in zip(ref, resumed):
        assert set(a) == set(b)
        np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-5)
        np.testing.assert_allclose(a["acc"], b["acc"], rtol=1e-4)
    np.testing.assert_allclose(np.asarray(tr2.state.params),
                               np.asarray(tr.state.params),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(tr2.state.w),
                                  np.asarray(tr.state.w))


# ---------------------------------------------------------------------------
# Superstep drivers are memoized: same shape -> same executable.
# ---------------------------------------------------------------------------

def test_superstep_jit_cache_reused(setting):
    _, _, testj = setting
    tr = _trainer(setting, "dfedavg")
    program = tr.program
    program._superstep_cache.clear()
    tr.fit(4, test_data=testj, eval_every=2, superstep=2)
    # two chunks of the same (length, cadence, data) signature -> ONE entry
    assert len(program._superstep_cache) == 1
    tr.fit(3, superstep=2)  # lengths 2 and 1, no eval -> two new entries
    assert len(program._superstep_cache) == 3
