"""Graceful degradation when ``hypothesis`` isn't installed.

Test modules import ``given``/``settings``/``st`` via::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hyp import given, settings, st

With hypothesis present the real library runs; without it, this shim
replays each property test over a deterministic seeded sample of the same
strategy space, so the tier-1 suite still collects and exercises the
properties (with less adversarial search) instead of erroring at import.
Only the strategy combinators the suite uses are implemented.
"""
from __future__ import annotations

import functools
import inspect
import random

__all__ = ["given", "settings", "st"]

_FALLBACK_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)


st = _Strategies()


def settings(max_examples: int = _FALLBACK_EXAMPLES, **_ignored):
    """Records max_examples; all other hypothesis knobs are no-ops here."""

    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(*strategies):
    """Run the test over a deterministic seeded sample of the strategies."""

    def deco(fn):
        n = min(getattr(fn, "_max_examples", _FALLBACK_EXAMPLES),
                _FALLBACK_EXAMPLES)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = random.Random(fn.__name__)  # reproducible per test
            for _ in range(n):
                drawn = [s.draw(rng) for s in strategies]
                fn(*args, *drawn, **kwargs)

        # The drawn parameters are supplied here, not by pytest — hide the
        # original signature so pytest doesn't look for fixtures named
        # after them (inspect.signature follows __wrapped__).
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco
