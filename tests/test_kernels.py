"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # graceful tier-1 degradation (see tests/_hyp.py)
    from _hyp import given, settings, st

from repro.kernels import ops, ref
from repro.core import topology as topo


# ---------------------------------------------------------------------------
# gossip_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,D", [(4, 16), (100, 1000), (128, 512), (37, 777)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gossip_matmul_shapes(n, D, dtype):
    P = topo.sample_kout(jax.random.PRNGKey(0), n, max(1, n // 4)).astype(dtype)
    X = jax.random.normal(jax.random.PRNGKey(1), (n, D), dtype)
    got = ops.gossip_matmul(P, X)
    want = ref.gossip_matmul_ref(P, X)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


@given(st.integers(2, 40), st.integers(1, 300), st.integers(0, 999))
@settings(max_examples=10, deadline=None)
def test_gossip_matmul_property(n, D, seed):
    P = topo.sample_kout(jax.random.PRNGKey(seed), n, max(1, n // 3))
    X = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, D))
    got = ops.gossip_matmul(P, X)
    want = ref.gossip_matmul_ref(P, X)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)
    # mass conservation survives the kernel
    np.testing.assert_allclose(
        np.asarray(got.sum(0)), np.asarray(X.sum(0)), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# fused_update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [7, 1024, 65536 + 3])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_update(d, dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (d,), dtype)
    v = jax.random.normal(jax.random.PRNGKey(1), (d,), jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(2), (d,), dtype)
    args = (0.9, 0.05, 1.3)
    got = ops.fused_update(x, v, g, *args, block=1024)
    want = ref.fused_update_ref(x, v, g, *args)
    for a, b in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5, atol=1e-3)


@given(st.integers(1, 5000), st.floats(0, 0.99), st.floats(0.001, 1.0),
       st.floats(0.2, 5.0))
@settings(max_examples=10, deadline=None)
def test_fused_update_property(d, alpha, eta, w):
    x = jax.random.normal(jax.random.PRNGKey(d), (d,))
    v = jnp.zeros((d,))
    g = jax.random.normal(jax.random.PRNGKey(d + 1), (d,))
    xk, vk, zk = ops.fused_update(x, v, g, alpha, eta, w, block=2048)
    xr, vr, zr = ref.fused_update_ref(x, v, g, alpha, eta, w)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(zk), np.asarray(zr), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
@pytest.mark.parametrize("h,kv", [(4, 4), (4, 2), (8, 1)])
def test_flash_attention_modes(causal, window, h, kv):
    B, S, hd = 2, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, h, S, hd))
    k = jax.random.normal(ks[1], (B, kv, S, hd))
    v = jax.random.normal(ks[2], (B, kv, S, hd))
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=128, block_k=128)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    B, H, S, hd = 1, 2, 128, 128
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, H, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, H, S, hd), dtype)
    got = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


def test_flash_attention_block_shape_sweep():
    B, H, S, hd = 1, 2, 256, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (jax.random.normal(kk, (B, H, S, hd)) for kk in ks)
    want = ref.flash_attention_ref(q, k, v)
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        got = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
