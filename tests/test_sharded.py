"""Sharded-vs-single-device equivalence for the GSPMD row-sharded bank.

Each case runs in a subprocess with ``--xla_force_host_platform_device_count=8``
(the device count must be forced before jax initializes, so the parent
pytest process cannot host these itself): a mesh-sharded n=64 round and
superstep must match the unsharded program to float tolerance for the
ring, k_out, and hierarchical two-tier families — including a stateful
composition (top-k error feedback + delayed links) whose EF residual and
in-flight link buffers are row-sharded too — with push-sum mass conserved
and a sharded checkpoint save/restore roundtrip continuing bitwise.

The halo case pins the ``gossip="halo"`` executor (the ``shard_map``
halo exchange shipping only the CommPlan's rows instead of the full-bank
all-gather) against BOTH the all-gather lowering and the unsharded
program, for the static (ring) and dynamic (k_out / two-tier) transports
composed with top-k error feedback, link drops, bounded delays, and node
churn — exact push-sum mass asserted at every round.
"""
import os
import subprocess
import sys

N = 64
DEV = 8


def _run_case(case: str, timeout: int = 1200):
    env = {**os.environ, "PYTHONPATH": "src",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=%d" % DEV}
    return subprocess.run([sys.executable, __file__, case],
                          capture_output=True, text=True, env=env,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))),
                          timeout=timeout)


def test_sharded_equivalence_all_families():
    r = _run_case("equivalence")
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "EQUIVALENCE OK" in r.stdout


def test_sharded_checkpoint_roundtrip():
    r = _run_case("checkpoint")
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "CHECKPOINT OK" in r.stdout


def test_halo_equals_allgather_equals_unsharded():
    r = _run_case("halo")
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "HALO OK" in r.stdout


# ---------------------------------------------------------------------------
# Subprocess case bodies (run under the forced 8-device CPU platform).
# ---------------------------------------------------------------------------

def _setting():
    import jax
    import jax.numpy as jnp

    def init_fn(key):
        k1, k2 = jax.random.split(key)
        return {"w1": jax.random.normal(k1, (8, 6)) * 0.1,
                "b1": jnp.zeros((6,)),
                "w2": jax.random.normal(k2, (6, 2)) * 0.1}

    def loss_fn(params, batch):
        h = jnp.tanh(batch["x"] @ params["w1"] + params["b1"])
        logits = h @ params["w2"]
        ll = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(ll, batch["y"][:, None], 1))
        acc = jnp.mean(jnp.argmax(logits, -1) == batch["y"])
        return loss, acc

    data = {"x": jax.random.normal(jax.random.PRNGKey(3), (N, 20, 8)),
            "y": jax.random.randint(jax.random.PRNGKey(4), (N, 20), 0, 2)}
    return loss_fn, init_fn, data


def _assert_rows_on_clients(x):
    spec = tuple(x.sharding.spec)
    assert spec and spec[0] == "clients", f"rows not on clients axis: {spec}"


def _case_equivalence():
    import jax
    import jax.numpy as jnp

    from repro.core import LinkModel, TopologyConfig, make_algo, make_program
    from repro.launch.mesh import make_clients_mesh

    assert jax.device_count() == DEV
    loss_fn, init_fn, data = _setting()
    mesh = make_clients_mesh()
    algo = make_algo("sgp", batch_size=4)
    cases = [
        ("ring", TopologyConfig(kind="ring", n_clients=N, k_out=1),
         "sparse", {}),
        ("kout-dense", TopologyConfig(kind="kout", n_clients=N, k_out=10),
         "dense", {}),
        ("kout-sparse", TopologyConfig(kind="kout", n_clients=N, k_out=10),
         "sparse", {}),
        ("two-tier", TopologyConfig(kind="two_tier", n_clients=N, k_out=10,
                                    n_pods=DEV),
         "sparse", {}),
        ("topk-ef+delay",
         TopologyConfig(kind="kout", n_clients=N, k_out=10), "sparse",
         {"algo": make_algo("dfedsgpsm", local_steps=1, batch_size=4,
                            compressor="topk_ef", topk_ratio=0.25),
          "link": LinkModel(delay=1)}),
    ]
    for name, topo, gossip, extra in cases:
        a = extra.get("algo", algo)
        link = extra.get("link")
        ref = make_program(loss_fn, init_fn, data, a, topo, gossip=gossip,
                           link=link)
        sh = make_program(loss_fn, init_fn, data, a, topo, gossip=gossip,
                          link=link, mesh=mesh)
        s0 = ref.init(jax.random.PRNGKey(0))
        s1 = sh.init(jax.random.PRNGKey(0))
        _assert_rows_on_clients(s1.params)
        # One jitted step AND a 3-round superstep must both match.
        s0a, m0 = jax.jit(ref.step)(s0)
        s1a, m1 = jax.jit(sh.step)(s1)
        perr = float(jnp.max(jnp.abs(
            s0a.params - jax.device_get(s1a.params))))
        assert perr < 1e-5, f"{name}: step diverged by {perr}"
        s0, _ = ref.run_superstep(s0, 3)
        s1, _ = sh.run_superstep(s1, 3)
        _assert_rows_on_clients(s1.params)
        perr = float(jnp.max(jnp.abs(s0.params - jax.device_get(s1.params))))
        werr = float(jnp.max(jnp.abs(s0.w - jax.device_get(s1.w))))
        mass = float(jnp.sum(s1.w))
        if link is not None and link.delay:
            mass += float(jnp.sum(s1.link.bufw))
        assert perr < 1e-5, f"{name}: superstep params diverged by {perr}"
        assert werr < 1e-5, f"{name}: push-sum weights diverged by {werr}"
        assert abs(mass - N) < 1e-3, f"{name}: mass leaked to {mass}"
        if not isinstance(s1.comp, tuple):
            _assert_rows_on_clients(s1.comp)  # EF residual rows sharded
        if s1.link and not isinstance(s1.link.bufx, tuple):
            spec = tuple(s1.link.bufx.sharding.spec)
            assert "clients" in spec, f"link bufx not sharded: {spec}"
        print(f"{name}: params_err={perr:.2e} w_err={werr:.2e} "
              f"mass={mass:.6f}")
    print("EQUIVALENCE OK")


def _case_halo():
    import jax
    import jax.numpy as jnp

    from repro.core import (
        ChurnModel, LinkModel, TopologyConfig, make_algo, make_program,
    )
    from repro.launch.mesh import make_clients_mesh

    assert jax.device_count() == DEV
    loss_fn, init_fn, data = _setting()
    mesh = make_clients_mesh()
    sgp = make_algo("sgp", batch_size=4)
    ef = make_algo("dfedsgpsm", local_steps=1, batch_size=4,
                   compressor="topk_ef", topk_ratio=0.25)
    churn = ChurnModel(fail_prob=0.15, recover_prob=0.3)
    cases = [
        # static ShiftLeg transport (one ppermute per leg)
        ("ring", TopologyConfig(kind="ring", n_clients=N, k_out=1),
         sgp, None, None),
        ("ring+topk_ef+delay",
         TopologyConfig(kind="ring", n_clients=N, k_out=1),
         ef, LinkModel(delay=1), None),
        ("ring+drop", TopologyConfig(kind="ring", n_clients=N, k_out=1),
         sgp, LinkModel(drop=0.3), None),
        # dynamic request/response transport (fixed-capacity all_to_all)
        ("kout+churn", TopologyConfig(kind="kout", n_clients=N, k_out=10),
         sgp, None, churn),
        # (two_tier churn needs the dense operator form — not a halo case)
        ("two_tier+topk_ef",
         TopologyConfig(kind="two_tier", n_clients=N, k_out=10, n_pods=DEV),
         ef, None, None),
    ]
    for name, topo, algo, link, ch in cases:
        ref = make_program(loss_fn, init_fn, data, algo, topo,
                           gossip="sparse", link=link, churn=ch)
        sx = make_program(loss_fn, init_fn, data, algo, topo, gossip="xla",
                          link=link, churn=ch, mesh=mesh)
        sh = make_program(loss_fn, init_fn, data, algo, topo, gossip="halo",
                          link=link, churn=ch, mesh=mesh)
        s0 = ref.init(jax.random.PRNGKey(0))
        s1 = sx.init(jax.random.PRNGKey(0))
        s2 = sh.init(jax.random.PRNGKey(0))
        _assert_rows_on_clients(s2.params)
        step0, step1, step2 = (jax.jit(p.step) for p in (ref, sx, sh))
        for r in range(4):
            s0, _ = step0(s0)
            s1, _ = step1(s1)
            s2, _ = step2(s2)
            # exact mass EVERY round: live + in-flight (+ frozen dead,
            # which stays parked inside w) == N on the halo path
            mass = float(jnp.sum(s2.w))
            if link is not None and link.delay:
                mass += float(jnp.sum(s2.link.bufw))
            assert abs(mass - N) < 1e-3, f"{name} round {r}: mass {mass}"
            e_halo = float(jnp.max(jnp.abs(
                s0.params - jax.device_get(s2.params))))
            e_hx = float(jnp.max(jnp.abs(
                jax.device_get(s1.params) - jax.device_get(s2.params))))
            assert e_halo < 1e-5, f"{name} round {r}: vs unsharded {e_halo}"
            assert e_hx < 1e-5, f"{name} round {r}: vs all-gather {e_hx}"
        werr = float(jnp.max(jnp.abs(s0.w - jax.device_get(s2.w))))
        assert werr < 1e-5, f"{name}: push-sum weights diverged by {werr}"
        print(f"{name}: halo==allgather==unsharded over 4 rounds")
    print("HALO OK")


def _case_checkpoint(tmp: str):
    import jax
    import jax.numpy as jnp

    from repro.core import FLTrainer, TopologyConfig, make_algo
    from repro.launch.mesh import make_clients_mesh

    assert jax.device_count() == DEV
    loss_fn, init_fn, data = _setting()
    mesh = make_clients_mesh()
    algo = make_algo("dfedsgpsm", local_steps=1, batch_size=4)
    topo = TopologyConfig(kind="two_tier", n_clients=N, k_out=10, n_pods=DEV)
    tr = FLTrainer(loss_fn, init_fn, data, algo, topo, seed=0,
                   gossip="sparse", mesh=mesh)
    tr.run_round()
    tr.run_round()
    path = tr.save(tmp, step=2)
    # A fresh trainer restores the host-written checkpoint back onto the
    # mesh and continues bit-identically to the uninterrupted run.
    tr2 = FLTrainer(loss_fn, init_fn, data, algo, topo, seed=0,
                    gossip="sparse", mesh=mesh)
    tr2.restore(path)
    _assert_rows_on_clients(tr2.state.params)
    assert int(tr2.state.round) == 2
    a = tr.run_round()
    b = tr2.run_round()
    perr = float(jnp.max(jnp.abs(jax.device_get(tr.state.params)
                                 - jax.device_get(tr2.state.params))))
    assert perr == 0.0, f"resumed round diverged by {perr}"
    assert abs(float(a["loss"]) - float(b["loss"])) == 0.0
    print("CHECKPOINT OK")


if __name__ == "__main__":
    case = sys.argv[1]
    if case == "equivalence":
        _case_equivalence()
    elif case == "halo":
        _case_halo()
    elif case == "checkpoint":
        import tempfile

        with tempfile.TemporaryDirectory() as tmp:
            _case_checkpoint(tmp)
    else:
        raise SystemExit(f"unknown case {case!r}")
