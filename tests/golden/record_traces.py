"""Record the golden per-round metrics trace for every registry algorithm.

Run from the repo root to (re)generate ``round_traces.json``::

    PYTHONPATH=src python tests/golden/record_traces.py

The recorded traces pin the round engine's numerics: the stage-composition
test (``tests/test_stages.py``) replays every algorithm and requires the
per-round loss/acc to match these values to float tolerance.  The file in
git was recorded from the pre-redesign monolithic engine (PR 1), so it is
the ground truth that the composable round program reproduces the legacy
engine bit-for-bit (up to float reassociation).
"""
import json
import os

import jax.numpy as jnp

from repro.core import ALGORITHMS, FLTrainer, TopologyConfig, make_algo
from repro.data.dirichlet import dirichlet_partition, stack_client_data
from repro.data.synthetic import make_dataset
from repro.models.small import mnist_2nn

N_CLIENTS = 8
ROUNDS = 3


def build_setting():
    train, _ = make_dataset("mnist", 1200, 100, seed=0)
    parts = dirichlet_partition(train["y"], N_CLIENTS, alpha=0.3, seed=0)
    cdata = stack_client_data(train, parts, pad_to=128)
    return mnist_2nn(), {k: jnp.asarray(v) for k, v in cdata.items()}


def main():
    model, cdata = build_setting()
    topo = TopologyConfig(kind="kout", n_clients=N_CLIENTS, k_out=2)
    traces = {}
    for name in sorted(ALGORITHMS):
        algo = make_algo(name, local_steps=3, batch_size=32)
        tr = FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0,
                       participation=0.25)
        rounds = []
        for _ in range(ROUNDS):
            m = tr.run_round()
            rounds.append({"loss": float(m["loss"]), "acc": float(m["acc"])})
        traces[name] = {
            "rounds": rounds,
            "w": [float(x) for x in jnp.ravel(tr.state.w)],
        }
    out = os.path.join(os.path.dirname(__file__), "round_traces.json")
    with open(out, "w") as f:
        json.dump(
            {"n_clients": N_CLIENTS, "local_steps": 3, "batch_size": 32,
             "participation": 0.25, "topology": "kout/k=2", "seed": 0,
             "traces": traces},
            f, indent=1)
    print("wrote", out)


if __name__ == "__main__":
    main()
