import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # graceful tier-1 degradation (see tests/_hyp.py)
    from _hyp import given, settings, st

from repro.core import pushsum, topology as topo


def _stacked(key, n, shapes=((3, 4), (7,))):
    ks = jax.random.split(key, len(shapes))
    return {
        f"p{i}": jax.random.normal(k, (n,) + s)
        for i, (k, s) in enumerate(zip(ks, shapes))
    }


@given(st.integers(3, 24), st.integers(0, 9999))
@settings(max_examples=20, deadline=None)
def test_mass_conservation(n, seed):
    """Column-stochastic mixing conserves sum_i x_i exactly (paper §B)."""
    key = jax.random.PRNGKey(seed)
    P = topo.sample_kout(key, n, max(1, n // 4))
    x = _stacked(key, n)
    x2 = pushsum.gossip(P, x)
    for k in x:
        np.testing.assert_allclose(
            np.asarray(x[k].sum(0)), np.asarray(x2[k].sum(0)), rtol=2e-5, atol=2e-5
        )


@given(st.integers(3, 20), st.integers(0, 9999))
@settings(max_examples=15, deadline=None)
def test_weight_mass(n, seed):
    P = topo.sample_kout(jax.random.PRNGKey(seed), n, max(1, n // 4))
    w = jnp.ones((n,))
    for _ in range(5):
        w = pushsum.gossip_weights(P, w)
    assert np.isclose(float(w.sum()), n, atol=1e-3)
    assert np.all(np.asarray(w) > 0)


def test_pushsum_consensus_converges_to_average():
    """z_i = x_i / w_i -> mean(x^0) under repeated directed mixing: the
    fundamental push-sum correctness property the de-bias step relies on."""
    n = 32
    key = jax.random.PRNGKey(0)
    x = _stacked(key, n)
    target = {k: np.asarray(v.mean(0)) for k, v in x.items()}
    w = jnp.ones((n,))
    for t in range(60):
        P = topo.sample_kout(jax.random.PRNGKey(t), n, 4)
        x = pushsum.gossip(P, x)
        w = pushsum.gossip_weights(P, w)
    z = pushsum.debias(x, w)
    for k in x:
        zi = np.asarray(z[k])
        for i in range(n):
            np.testing.assert_allclose(zi[i], target[k], rtol=5e-4, atol=5e-4)


def test_consensus_error_decreases():
    n = 16
    x = _stacked(jax.random.PRNGKey(3), n)
    w = jnp.ones((n,))
    errs = []
    for t in range(30):
        errs.append(float(pushsum.consensus_error(x, w)))
        P = topo.sample_kout(jax.random.PRNGKey(100 + t), n, 3)
        x = pushsum.gossip(P, x)
        w = pushsum.gossip_weights(P, w)
    assert errs[-1] < 1e-4 * errs[0]


def test_better_connectivity_tighter_consensus():
    """Remark 1: better connectivity => faster consensus (smaller error
    after a fixed number of rounds)."""
    n, rounds = 32, 8

    def run(k_out):
        x = _stacked(jax.random.PRNGKey(7), n)
        w = jnp.ones((n,))
        for t in range(rounds):
            P = topo.sample_kout(jax.random.PRNGKey(500 + t), n, k_out)
            x = pushsum.gossip(P, x)
            w = pushsum.gossip_weights(P, w)
        return float(pushsum.consensus_error(x, w))

    sparse, dense = run(2), run(16)
    assert dense < sparse


def test_debias_identity_when_weights_one():
    x = _stacked(jax.random.PRNGKey(1), 5)
    z = pushsum.debias(x, jnp.ones((5,)))
    for k in x:
        np.testing.assert_array_equal(np.asarray(x[k]), np.asarray(z[k]))


@given(st.integers(4, 40), st.integers(0, 9999))
@settings(max_examples=20, deadline=None)
def test_weight_mixing_sparse_dense_agree(n, seed):
    """gossip_weights must compute the SAME w' through the neighbor-list
    gather and the (now HIGHEST-precision, like the bank matmul) dense
    path — the de-bias ratio z = x / w may not depend on the mixing
    representation."""
    k = max(1, n // 4)
    nl = topo.sample_kout_neighbors(jax.random.PRNGKey(seed), n, k)
    P = topo.dense_from_neighbors(nl, n)
    w = jax.random.uniform(jax.random.PRNGKey(seed + 1), (n,),
                           minval=0.25, maxval=2.0)
    np.testing.assert_allclose(
        np.asarray(pushsum.gossip_weights(nl, w)),
        np.asarray(pushsum.gossip_weights(P, w)),
        rtol=1e-6, atol=1e-7)
    # and both agree with the bank path's einsum on a (n, 1) column
    bank = pushsum.gossip_bank(P, w[:, None], use_kernel=False)
    np.testing.assert_allclose(np.asarray(pushsum.gossip_weights(P, w)),
                               np.asarray(bank[:, 0]), rtol=1e-6, atol=1e-7)
