import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # graceful tier-1 degradation (see tests/_hyp.py)
    from _hyp import given, settings, st

from repro.core import topology as topo


@given(st.integers(3, 40), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_kout_column_stochastic(n, seed):
    k = max(1, min(n - 1, n // 3))
    P = topo.sample_kout(jax.random.PRNGKey(seed), n, k)
    assert topo.is_column_stochastic(P)
    # self loops present
    assert np.all(np.diag(np.asarray(P)) > 0)


@given(st.integers(3, 30), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_symmetric_doubly_stochastic(n, seed):
    k = max(1, n // 3)
    W = np.asarray(topo.sample_symmetric_k_regular(jax.random.PRNGKey(seed), n, k))
    assert np.allclose(W, W.T, atol=1e-6)
    assert np.allclose(W.sum(0), 1.0, atol=1e-5)
    assert np.allclose(W.sum(1), 1.0, atol=1e-5)
    assert np.all(W >= -1e-6)


def test_ring_and_exponential():
    for n in (4, 7, 16):
        assert topo.is_column_stochastic(topo.directed_ring(n))
        for t in range(5):
            assert topo.is_column_stochastic(topo.directed_exponential(n, t))


def test_ring_strongly_connected_single_round():
    P = topo.directed_ring(8)
    assert topo.union_strongly_connected([P])


def test_exponential_union_connected():
    # One-peer exponential graphs: union over log2(n) rounds is connected.
    n = 16
    mats = [topo.directed_exponential(n, t) for t in range(4)]
    assert topo.union_strongly_connected(mats)
    # a single hop-2 graph (two disjoint cycles over even/odd nodes) is NOT
    # strongly connected; connectivity needs the union (Assumption 1).
    assert not topo.union_strongly_connected(mats[1:2])


def test_kout_B_connectivity():
    # Assumption 1: union over a window of random k-out graphs is strongly
    # connected with overwhelming probability.
    n, k = 50, 5
    mats = [topo.sample_kout(jax.random.PRNGKey(s), n, k) for s in range(3)]
    assert topo.union_strongly_connected(mats)


def test_selective_prefers_divergent_losses():
    n, k = 20, 4
    losses = jnp.zeros((n,)).at[7].set(100.0)  # client 7 is the outlier
    cnt = 0
    trials = 30
    for s in range(trials):
        P = np.asarray(
            topo.sample_kout_selective(jax.random.PRNGKey(s), losses, n, k)
        )
        # did client 0 send to client 7? (P[7, 0] > 0, beyond self-loop)
        cnt += P[7, 0] > 0
    # Under uniform sampling the hit rate would be ~k/(n-1) ≈ 0.21.
    assert cnt / trials > 0.8
    assert topo.is_column_stochastic(P)


def test_selection_column_stochastic_property():
    for s in range(5):
        losses = jax.random.normal(jax.random.PRNGKey(s), (12,))
        P = topo.sample_kout_selective(jax.random.PRNGKey(s + 99), losses, 12, 3)
        assert topo.is_column_stochastic(P)


# ---------------------------------------------------------------------------
# Hierarchical two-tier family (dense intra-pod + sparse cross-pod edges).
# ---------------------------------------------------------------------------

@given(st.integers(2, 6), st.integers(2, 8), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_two_tier_column_stochastic(n_pods, ps, seed):
    n = n_pods * ps
    k = max(1, min(n - ps, n // 4))
    op = topo.sample_two_tier(jax.random.PRNGKey(seed), n, n_pods, k)
    P = topo.dense_from_two_tier(op)
    assert topo.is_column_stochastic(P)
    # Self-loops live on the intra diagonal; the inter self slot is a pad.
    assert np.all(np.diag(np.asarray(P)) > 0)
    assert np.all(np.asarray(op.inter.wgt)[:, 0] == 0.0)


def test_two_tier_cross_edges_leave_the_pod():
    n, n_pods, k = 48, 6, 7
    ps = n // n_pods
    op = topo.sample_two_tier(jax.random.PRNGKey(3), n, n_pods, k)
    pod = np.arange(n) // ps
    picks = np.asarray(op.inter.idx)[:, 1:]  # slot 0 is the self pad
    assert np.all(pod[picks] != pod[:, None])
    # Every receiver has exactly k distinct external senders.
    assert all(len(set(row)) == k for row in picks)


def test_two_tier_matches_dense_twin_and_conserves_mass():
    n, n_pods, k = 32, 4, 5
    cfg = topo.TopologyConfig(kind="two_tier", n_clients=n, k_out=k,
                              n_pods=n_pods)
    key = jax.random.PRNGKey(11)
    op = topo.sample_neighbors(key, cfg)
    assert isinstance(op, topo.TwoTierOp)
    P = topo.sample_mixing(key, cfg)
    assert np.allclose(np.asarray(topo.dense_from_two_tier(op)),
                       np.asarray(P))
    from repro.core import pushsum

    X = jax.random.normal(jax.random.PRNGKey(1), (n, 19))
    w = jnp.ones((n,), jnp.float32)
    Xs = pushsum.gossip_bank(op, X)
    Xd = pushsum.gossip_bank(P, X, use_kernel=False)
    assert np.allclose(np.asarray(Xs), np.asarray(Xd), atol=1e-5)
    ws = pushsum.gossip_weights(op, w)
    assert np.allclose(np.asarray(ws), np.asarray(pushsum.gossip_weights(P, w)),
                       atol=1e-6)
    assert abs(float(ws.sum()) - n) < 1e-3  # push-sum mass
    assert topo.neighbor_k_max(cfg, "directed") == n // n_pods + k


def test_two_tier_union_strongly_connected():
    cfg = topo.TopologyConfig(kind="two_tier", n_clients=40, k_out=4,
                              n_pods=5)
    mats = [topo.sample_mixing(jax.random.PRNGKey(s), cfg) for s in range(3)]
    assert topo.union_strongly_connected(mats)


def test_two_tier_config_validation():
    with pytest.raises(ValueError, match="n_pods >= 2"):
        topo.TopologyConfig(kind="two_tier", n_clients=16, k_out=2, n_pods=1)
    with pytest.raises(ValueError, match="divisible"):
        topo.TopologyConfig(kind="two_tier", n_clients=15, k_out=2, n_pods=4)
    with pytest.raises(ValueError, match="pod_size"):
        # k_out > n - pod_size: not enough external senders to pick from.
        topo.TopologyConfig(kind="two_tier", n_clients=16, k_out=13, n_pods=2)
    with pytest.raises(ValueError, match="two_tier-only"):
        topo.TopologyConfig(kind="kout", n_clients=16, k_out=2, n_pods=4)


def test_two_tier_drop_links_rejected():
    op = topo.sample_two_tier(jax.random.PRNGKey(0), 16, 4, 3)
    lm = topo.LinkModel(drop=0.3)
    with pytest.raises(ValueError, match="two-tier"):
        lm.drop_links(jax.random.PRNGKey(1), op)
