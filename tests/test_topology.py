import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # graceful tier-1 degradation (see tests/_hyp.py)
    from _hyp import given, settings, st

from repro.core import topology as topo


@given(st.integers(3, 40), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_kout_column_stochastic(n, seed):
    k = max(1, min(n - 1, n // 3))
    P = topo.sample_kout(jax.random.PRNGKey(seed), n, k)
    assert topo.is_column_stochastic(P)
    # self loops present
    assert np.all(np.diag(np.asarray(P)) > 0)


@given(st.integers(3, 30), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_symmetric_doubly_stochastic(n, seed):
    k = max(1, n // 3)
    W = np.asarray(topo.sample_symmetric_k_regular(jax.random.PRNGKey(seed), n, k))
    assert np.allclose(W, W.T, atol=1e-6)
    assert np.allclose(W.sum(0), 1.0, atol=1e-5)
    assert np.allclose(W.sum(1), 1.0, atol=1e-5)
    assert np.all(W >= -1e-6)


def test_ring_and_exponential():
    for n in (4, 7, 16):
        assert topo.is_column_stochastic(topo.directed_ring(n))
        for t in range(5):
            assert topo.is_column_stochastic(topo.directed_exponential(n, t))


def test_ring_strongly_connected_single_round():
    P = topo.directed_ring(8)
    assert topo.union_strongly_connected([P])


def test_exponential_union_connected():
    # One-peer exponential graphs: union over log2(n) rounds is connected.
    n = 16
    mats = [topo.directed_exponential(n, t) for t in range(4)]
    assert topo.union_strongly_connected(mats)
    # a single hop-2 graph (two disjoint cycles over even/odd nodes) is NOT
    # strongly connected; connectivity needs the union (Assumption 1).
    assert not topo.union_strongly_connected(mats[1:2])


def test_kout_B_connectivity():
    # Assumption 1: union over a window of random k-out graphs is strongly
    # connected with overwhelming probability.
    n, k = 50, 5
    mats = [topo.sample_kout(jax.random.PRNGKey(s), n, k) for s in range(3)]
    assert topo.union_strongly_connected(mats)


def test_selective_prefers_divergent_losses():
    n, k = 20, 4
    losses = jnp.zeros((n,)).at[7].set(100.0)  # client 7 is the outlier
    cnt = 0
    trials = 30
    for s in range(trials):
        P = np.asarray(
            topo.sample_kout_selective(jax.random.PRNGKey(s), losses, n, k)
        )
        # did client 0 send to client 7? (P[7, 0] > 0, beyond self-loop)
        cnt += P[7, 0] > 0
    # Under uniform sampling the hit rate would be ~k/(n-1) ≈ 0.21.
    assert cnt / trials > 0.8
    assert topo.is_column_stochastic(P)


def test_selection_column_stochastic_property():
    for s in range(5):
        losses = jax.random.normal(jax.random.PRNGKey(s), (12,))
        P = topo.sample_kout_selective(jax.random.PRNGKey(s + 99), losses, 12, 3)
        assert topo.is_column_stochastic(P)
