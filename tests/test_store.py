"""Virtual client population: the disk-backed ClientStore, fault-in
closure planning, and the paged trainer's three contracts — (1) the
resident set is exactly sampled ∪ in-neighbors and all buffers scale with
its bound (never n), (2) the compact slot-remapped operator embeds into the
dense column-stochastic reference so paged == fully-resident to float
tolerance on the identical PRNG chain, and (3) the checkpoint IS the store:
a committed manifest re-opens bit-identically."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # graceful tier-1 degradation (see tests/_hyp.py)
    from _hyp import given, settings, st

from repro.core import (
    FLTrainer,
    LinkModel,
    TopologyConfig,
    make_algo,
    make_program,
)
from repro.core import topology
from repro.data.dirichlet import dirichlet_partition, stack_client_data
from repro.data.synthetic import DatasetSpec, make_dataset
from repro.models.small import tiny_mlp
from repro.store import (
    ClientStore,
    FieldSpec,
    PagedRunner,
    ResidentDriver,
    RowCache,
    closure_bound,
    dense_partial_operator,
    make_plan,
)

N = 16
_DATA_CACHE: dict = {}


def _client_data(n):
    if n not in _DATA_CACHE:
        spec = DatasetSpec("toy", (16,), 4, margin=3.0)
        train, _ = make_dataset(spec, n * 16, 64, seed=0)
        parts = dirichlet_partition(train["y"], n, alpha=10.0, seed=0)
        _DATA_CACHE[n] = stack_client_data(train, parts, pad_to=32)
    return _DATA_CACHE[n]


def _program(n=N, kind="kout", k_out=2, compressor=None, algo_name="dfedsgpsm",
             link=None, **topo_kw):
    model = tiny_mlp(in_dim=16, n_classes=4)
    kw = dict(local_steps=2, batch_size=8)
    if compressor:
        kw["compressor"] = compressor
    algo = make_algo(algo_name, **kw)
    topo = TopologyConfig(kind=kind, n_clients=n, k_out=k_out, **topo_kw)
    return make_program(model.loss, model.init, _client_data(n), algo, topo,
                        gossip="dense", link=link)


def _topo(kind, n=24, k_active=5):
    k_out = 1 if kind in ("ring", "exponential") else 2
    kw = {"n_pods": 4} if kind == "two_tier" else {}
    tv = kind == "exponential"  # the time-varying family the paper sweeps
    cfg = TopologyConfig(kind=kind, n_clients=n, k_out=k_out,
                         time_varying=tv, **kw)
    k_in = topology.active_k_in(cfg)
    return cfg, k_active, closure_bound(n, k_active, k_in)


# ---------------------------------------------------------------------------
# ClientStore: chunked row I/O, lazy materialization, durability.
# ---------------------------------------------------------------------------

def _toy_fields():
    return {
        "params": FieldSpec("params", (6,), "float32"),
        "w": FieldSpec("w", (), "float32", default=1.0),
    }


def test_store_creation_is_lazy_and_roundtrips(tmp_path):
    """Creation is O(1) in n: unwritten chunks synthesize from templates /
    defaults; written rows come back exactly, across a reopen."""
    import os

    tpl = np.arange(6, dtype=np.float32)
    s = ClientStore.create(str(tmp_path / "s"), 1000, _toy_fields(),
                           rows_per_chunk=64, templates={"params": tpl})
    assert not [f for f in os.listdir(s.path) if f.startswith("chunk")]
    got = s.read_rows([0, 999, 500])
    np.testing.assert_array_equal(got["params"],
                                  np.broadcast_to(tpl, (3, 6)))
    np.testing.assert_array_equal(got["w"], np.ones(3, np.float32))

    ids = np.asarray([3, 64, 65, 999])  # spans three chunks
    vals = {"params": np.random.default_rng(0).standard_normal(
        (4, 6)).astype(np.float32),
        "w": np.asarray([2.0, 3.0, 4.0, 5.0], np.float32)}
    s.write_rows(ids, vals)
    assert s.chunks_written == 3 and s.bytes_written > 0
    s.update_meta()  # commit: format 2 rolls back uncommitted gens on open
    s2 = ClientStore.open(str(tmp_path / "s"))
    back = s2.read_rows(ids[::-1])  # any order
    np.testing.assert_array_equal(back["params"], vals["params"][::-1])
    np.testing.assert_array_equal(back["w"], vals["w"][::-1])
    # neighbors in a written chunk keep the template
    np.testing.assert_array_equal(s2.read_rows([4])["params"][0], tpl)


def test_store_validation_and_clobber_guard(tmp_path):
    s = ClientStore.create(str(tmp_path / "s"), 10, _toy_fields(),
                           rows_per_chunk=4)
    with pytest.raises(FileExistsError):
        ClientStore.create(str(tmp_path / "s"), 10, _toy_fields())
    with pytest.raises(IndexError):
        s.read_rows([10])
    with pytest.raises(ValueError, match="unique"):
        s.write_rows([1, 1], {"w": np.ones(2, np.float32)})
    with pytest.raises(KeyError):
        s.write_rows([1], {"nope": np.ones(1)})
    # a future-format manifest is refused, not misread
    with pytest.raises(ValueError, match="format"):
        ClientStore(str(tmp_path / "s"),
                    {"format": 99, "n": 10, "rows_per_chunk": 4,
                     "fields": {}})


def test_store_streaming_reductions_and_meta_commit(tmp_path):
    """field_sum / iter_chunks stream the whole population (lazy chunks
    synthesized) exactly; update_meta commits durably."""
    s = ClientStore.create(str(tmp_path / "s"), 100, _toy_fields(),
                           rows_per_chunk=8)
    assert float(s.field_sum("w")) == 100.0  # all-lazy population
    s.write_rows([7, 50], {"w": np.asarray([3.0, 0.5], np.float32)})
    assert float(s.field_sum("w")) == pytest.approx(100.0 + 2.0 + 0.5 - 1.0)
    seen = sum(c["w"].shape[0] for _, c in s.iter_chunks(fields=["w"]))
    assert seen == 100
    s.update_meta(round=5, key=[1, 2])
    s2 = ClientStore.open(str(tmp_path / "s"))
    assert s2.meta["round"] == 5 and s2.meta["key"] == [1, 2]


def test_row_cache_consistency_rules():
    """pending (dirty) rows are never evicted and shadow clean puts; settle
    atomically moves them to the bounded LRU tier."""
    c = RowCache(capacity=2)
    c.put_pending(1, {"w": 1.0})
    c.put_clean(1, {"w": 99.0})      # stale clean copy must lose
    assert c.get(1) == {"w": 1.0}
    for g in (2, 3, 4):
        c.put_clean(g, {"w": float(g)})
    assert c.get(2) is None          # LRU-evicted at capacity 2
    assert c.get(1) == {"w": 1.0}    # pending survives any pressure
    c.settle(1)
    assert c.pending_count == 0
    assert c.get(1) == {"w": 1.0}    # now served from LRU


# ---------------------------------------------------------------------------
# Fault-in closure planning: resident set == sampled ∪ in-neighbors, and
# the compact operator embeds into the dense column-stochastic reference.
# ---------------------------------------------------------------------------

@given(st.sampled_from(["ring", "exponential", "kout", "two_tier"]),
       st.integers(0, 999), st.integers(0, 6))
@settings(max_examples=40, deadline=None)
def test_closure_is_exactly_active_union_inneighbors(kind, seed, t):
    cfg, k_active, c_max = _topo(kind)
    plan = make_plan(cfg, k_active, c_max, jax.random.PRNGKey(seed), t)
    want = set(plan.active.tolist()) | set(plan.picks.ravel().tolist())
    assert set(plan.closure.tolist()) == want
    assert plan.c == len(want) <= c_max
    # active rows lead the layout (the trained slots are [:k_active])
    np.testing.assert_array_equal(plan.closure[:k_active], plan.active)
    # pads are inert identity self-loops
    np.testing.assert_array_equal(plan.wgt[plan.c:, 0],
                                  np.ones(c_max - plan.c, np.float32))
    np.testing.assert_array_equal(plan.wgt[plan.c:, 1:], 0.0)


@given(st.sampled_from(["ring", "exponential", "kout", "two_tier"]),
       st.integers(0, 999), st.integers(0, 6))
@settings(max_examples=25, deadline=None)
def test_compact_operator_embeds_into_dense_reference(kind, seed, t):
    """Scatter the slot-remapped NeighborList back to (n, n): it must be
    the active-receiver-masked column-stochastic operator bit-for-bit —
    identity columns for every row that was not paged in."""
    cfg, k_active, c_max = _topo(kind)
    n = cfg.n_clients
    plan = make_plan(cfg, k_active, c_max, jax.random.PRNGKey(seed), t)
    M = np.zeros((n, n), np.float64)
    noncl = np.setdiff1d(np.arange(n), plan.closure)
    M[noncl, noncl] = 1.0
    for s in range(plan.c):
        for l in range(plan.idx.shape[1]):
            M[plan.ids[s], plan.ids[plan.idx[s, l]]] += plan.wgt[s, l]
    ref = np.asarray(dense_partial_operator(plan.active, plan.picks, n),
                     np.float64)
    np.testing.assert_allclose(M, ref, atol=1e-7)
    np.testing.assert_allclose(M.sum(axis=0), 1.0, atol=1e-6)


def test_closure_bound_is_tight_and_population_capped():
    assert closure_bound(1000, 8, 3) == 32
    assert closure_bound(16, 8, 3) == 16  # never exceeds the population


# ---------------------------------------------------------------------------
# Paged == fully-resident equivalence on the identical PRNG chain.
# ---------------------------------------------------------------------------

_FAMILIES = [("ring", {}), ("exponential", {"time_varying": True}),
             ("kout", {}), ("two_tier", {"n_pods": 4})]


@pytest.mark.parametrize("kind,kw", _FAMILIES)
def test_paged_matches_resident_per_family(kind, kw, tmp_path):
    k_out = 1 if kind in ("ring", "exponential") else 2
    program = _program(kind=kind, k_out=k_out, **kw)
    runner = PagedRunner(program, str(tmp_path / "store"), k_active=4,
                         seed=3, rows_per_chunk=4)
    twin = ResidentDriver(program, k_active=4, seed=3)
    for _ in range(4):
        mp, mt = runner.run_round(), twin.run_round()
        assert abs(mp["loss"] - mt["loss"]) < 1e-5
        assert mp["w_mass_closure_err"] < 1e-4
    rows = runner.read_rows(np.arange(N))
    np.testing.assert_allclose(rows["params"],
                               np.asarray(twin.state.params), atol=5e-5)
    np.testing.assert_allclose(rows["w"], np.asarray(twin.state.w),
                               atol=1e-5)
    assert abs(runner.total_mass() - N) < 1e-4
    assert abs(twin.total_mass() - N) < 1e-4
    runner.close()


@pytest.mark.parametrize("compressor", ["topk_ef", "int8_rows"])
def test_paged_matches_resident_compressed(compressor, tmp_path):
    """Closure-restricted compression: only transmitting rows compress (and
    commit EF residuals), so the compact round still matches the masked
    full-bank reference."""
    program = _program(compressor=compressor)
    runner = PagedRunner(program, str(tmp_path / "store"), k_active=4,
                         seed=5, rows_per_chunk=4)
    twin = ResidentDriver(program, k_active=4, seed=5)
    for _ in range(4):
        mp, mt = runner.run_round(), twin.run_round()
        assert abs(mp["loss"] - mt["loss"]) < 1e-5
    rows = runner.read_rows(np.arange(N))
    np.testing.assert_allclose(rows["params"],
                               np.asarray(twin.state.params), atol=5e-5)
    if compressor == "topk_ef":  # the EF residual is store-resident state
        assert "ef" in rows and np.abs(rows["ef"]).max() > 0
    assert abs(runner.total_mass() - N) < 1e-4
    runner.close()


def test_paged_mass_conserved_with_cold_population(tmp_path):
    """sum_i w_i == n over the WHOLE store after many partial rounds —
    cold (never-sampled) clients included, the exact push-sum invariant."""
    n = 64
    program = _program(n=n, k_out=2)
    runner = PagedRunner(program, str(tmp_path / "store"), k_active=4,
                         seed=0, rows_per_chunk=8)
    for _ in range(6):
        rec = runner.run_round()
        assert rec["w_mass_closure_err"] < 1e-4
    assert abs(runner.total_mass() - n) < 1e-3
    # with k_active=4 of 64, plenty of clients never ran: they still hold
    # exactly their (scaled) share of mass and the unit template params
    runner.close()


# ---------------------------------------------------------------------------
# Allocation accounting: buffers scale with the closure bound, never n.
# ---------------------------------------------------------------------------

def test_paged_buffers_scale_with_closure_not_population(tmp_path):
    n, k_active, k_out = 64, 4, 2
    program = _program(n=n, k_out=k_out)
    runner = PagedRunner(program, str(tmp_path / "store"), k_active=k_active,
                         seed=0, rows_per_chunk=8)
    c_max = k_active * (k_out + 1)
    assert runner.resident_rows == c_max < n
    assert runner.staging_rows == 2 * c_max
    for buf in runner._staging:
        assert buf["params"].shape == (c_max, program.spec.dim)
        assert buf["w"].shape == (c_max,)
    runner.run_round()
    rec = runner.run_round()
    assert rec["rows_resident"] <= c_max
    stats = runner.stats.as_dict()
    assert stats["rows_needed_per_round"] <= c_max
    assert 0.0 <= stats["prefetch_hit_rate"] <= 1.0
    # round 2's closure is served by carry/prefetch/cache, not all faults
    assert stats["rows_faulted_per_round"] < stats["rows_needed_per_round"]
    runner.close()


# ---------------------------------------------------------------------------
# The checkpoint IS the store: manifest commit + bit-identical reopen.
# ---------------------------------------------------------------------------

def test_paged_store_resume_is_bit_identical(tmp_path):
    """save() commits (round, key) into the manifest; a fresh runner opened
    on a snapshot of the committed store replays the continuation
    bit-for-bit (its seed argument must be ignored on resume)."""
    program = _program()
    runner = PagedRunner(program, str(tmp_path / "store"), k_active=4,
                         seed=3, rows_per_chunk=4)
    for _ in range(2):
        runner.run_round()
    runner.save()
    shutil.copytree(str(tmp_path / "store"), str(tmp_path / "snap"))
    a = [runner.run_round() for _ in range(2)]
    rows_a = runner.read_rows(np.arange(N))
    runner.close()

    resumed = PagedRunner(program, str(tmp_path / "snap"), k_active=4,
                          seed=999, rows_per_chunk=4)
    assert resumed.round_index == 2
    b = [resumed.run_round() for _ in range(2)]
    rows_b = resumed.read_rows(np.arange(N))
    resumed.close()
    assert a == b
    for k in rows_a:
        np.testing.assert_array_equal(rows_a[k], rows_b[k])


def test_paged_restore_resyncs_to_committed_manifest(tmp_path):
    program = _program()
    runner = PagedRunner(program, str(tmp_path / "store"), k_active=4,
                         seed=3, rows_per_chunk=4)
    runner.run_round()
    runner.save()
    assert ClientStore.open(runner.store.path).meta["round"] == 1
    runner.restore()
    assert runner.round_index == 1
    rec = runner.run_round()
    assert np.isfinite(rec["loss"])
    other = PagedRunner(_program(), str(tmp_path / "other"), k_active=4)
    other.close()
    with pytest.raises(ValueError, match="own store"):
        runner.restore(str(tmp_path / "other"))  # not this runner's store
    runner.close()


def test_store_rejects_mismatched_program(tmp_path):
    """A store created under one composition refuses a different one up
    front: different model structure, or a stage set with different
    per-row state (EF residual)."""
    runner = PagedRunner(_program(), str(tmp_path / "store"), k_active=4)
    runner.save()
    runner.close()
    other_model = tiny_mlp(in_dim=16, hidden=8, n_classes=4)
    algo = make_algo("dfedsgpsm", local_steps=2, batch_size=8)
    topo = TopologyConfig(kind="kout", n_clients=N, k_out=2)
    other = make_program(other_model.loss, other_model.init, _client_data(N),
                         algo, topo, gossip="dense")
    with pytest.raises(ValueError, match="structure"):
        PagedRunner(other, str(tmp_path / "store"), k_active=4)
    with pytest.raises(ValueError, match="fields"):
        PagedRunner(_program(compressor="topk_ef"), str(tmp_path / "store"),
                    k_active=4)


# ---------------------------------------------------------------------------
# Composition guards: what has no paged form is refused loudly.
# ---------------------------------------------------------------------------

def test_paged_rejects_unsupported_compositions(tmp_path):
    with pytest.raises(ValueError, match="push-sum"):
        PagedRunner(_program(algo_name="dfedsam"), str(tmp_path / "a"),
                    k_active=4)
    with pytest.raises(ValueError, match="push-sum"):
        PagedRunner(_program(link=LinkModel(drop=0.3)), str(tmp_path / "b"),
                    k_active=4)
    with pytest.raises(ValueError, match="k_active"):
        PagedRunner(_program(), str(tmp_path / "c"), k_active=0)
    with pytest.raises(ValueError, match="k_active"):
        PagedRunner(_program(), str(tmp_path / "d"), k_active=N + 1)


# ---------------------------------------------------------------------------
# FLTrainer integration: paged=True end to end.
# ---------------------------------------------------------------------------

def test_trainer_paged_mode_end_to_end(tmp_path):
    model = tiny_mlp(in_dim=16, n_classes=4)
    algo = make_algo("dfedsgpsm", local_steps=2, batch_size=8)
    topo = TopologyConfig(kind="kout", n_clients=N, k_out=2)
    tr = FLTrainer(model.loss, model.init, _client_data(N), algo, topo,
                   seed=0, paged=True, store_dir=str(tmp_path / "s"),
                   k_active=4)
    hist = tr.fit(3)
    assert len(hist) == 3
    assert all(np.isfinite(rec["loss"]) for rec in hist)
    avg = tr.average_model()  # streamed consensus mean, unraveled
    assert avg["fc1"]["w"].shape == (16, 32)
    assert np.isfinite(tr.consensus_error())
    with pytest.raises(ValueError, match="n, D"):
        tr.debiased_models()  # would materialize the full bank
    path = tr.save()
    assert ClientStore.exists(path)
    tr.restore(path)
    assert np.isfinite(tr.run_round()["loss"])
    tr.runner.close()


def test_trainer_paged_validations(tmp_path):
    model = tiny_mlp(in_dim=16, n_classes=4)
    algo = make_algo("dfedsgpsm", local_steps=2, batch_size=8)
    topo = TopologyConfig(kind="kout", n_clients=N, k_out=2)
    common = (model.loss, model.init, _client_data(N), algo, topo)
    with pytest.raises(ValueError, match="store_dir"):
        FLTrainer(*common, paged=True, k_active=4)
    with pytest.raises(ValueError, match="k_active"):
        FLTrainer(*common, paged=True, store_dir=str(tmp_path / "s"))
    with pytest.raises(ValueError, match="flat"):
        FLTrainer(*common, paged=True, flat=False,
                  store_dir=str(tmp_path / "s"), k_active=4)
    with pytest.raises(ValueError, match="link"):
        FLTrainer(*common, paged=True, store_dir=str(tmp_path / "s"),
                  k_active=4, link=LinkModel(drop=0.2))


# ---------------------------------------------------------------------------
# Fault tolerance: checksums, quarantine, crash points, retry accounting.
# ---------------------------------------------------------------------------

from repro.store import (  # noqa: E402  (grouped with the section they test)
    FaultInjector,
    InjectedCrash,
    Prefetcher,
    StoreCorruptionError,
    StoreIOError,
)


def _fault_store(tmp_path, name="s", faults=None, n=128):
    tpl = np.arange(6, dtype=np.float32)
    s = ClientStore.create(str(tmp_path / name), n, _toy_fields(),
                           rows_per_chunk=16, templates={"params": tpl},
                           faults=faults)
    return s, tpl


def test_store_open_removes_stale_tmp(tmp_path):
    """Stale tmp droppings from a died-mid-write process (both the
    store's own rename-staging names and the injector's crash residue)
    are removed on open; committed files survive."""
    import os

    s, _ = _fault_store(tmp_path)
    ids = np.arange(8)
    s.write_rows(ids, {"params": np.ones((8, 6), np.float32)})
    s.update_meta()
    committed = s._chunks[0]["file"]
    for junk in ("manifest.json.tmp", committed + ".crashed.tmp",
                 "rows_00000016.g000099.npz.tmp"):
        with open(os.path.join(s.path, junk), "wb") as f:
            f.write(b"partial")
    s2 = ClientStore.open(s.path)
    names = os.listdir(s2.path)
    assert not [x for x in names if x.endswith(".tmp")]
    assert committed in names
    np.testing.assert_array_equal(
        s2.read_rows(ids)["params"], np.ones((8, 6), np.float32))


def test_open_rolls_back_uncommitted_generations(tmp_path):
    """Writes after the last commit are invisible after a reopen: their
    generation files are GC'd and reads return the committed bytes —
    the crash-recovery contract the chaos harness leans on."""
    s, _ = _fault_store(tmp_path)
    ids = np.arange(4)
    s.write_rows(ids, {"params": np.full((4, 6), 1.0, np.float32)})
    s.update_meta(round=1)
    s.write_rows(ids, {"params": np.full((4, 6), 9.0, np.float32)})
    s2 = ClientStore.open(s.path)
    assert s2.meta["round"] == 1
    np.testing.assert_array_equal(
        s2.read_rows(ids)["params"], np.full((4, 6), 1.0, np.float32))


def test_corrupt_dirty_chunk_quarantines_and_raises(tmp_path):
    """A checksum mismatch on rows that ever held trained data is a loud
    StoreCorruptionError carrying chunk id, quarantine path, committed
    round, and the rows at stake — never silently consumed."""
    import os

    s, _ = _fault_store(tmp_path)
    ids = np.arange(16, 24)
    s.write_rows(ids, {"params": np.ones((8, 6), np.float32)})
    s.update_meta(round=7)
    fname = s._chunks[16]["file"]
    with open(os.path.join(s.path, fname), "r+b") as f:
        f.seek(30)
        b = f.read(1)
        f.seek(30)
        f.write(bytes([b[0] ^ 0x10]))
    with pytest.raises(StoreCorruptionError) as ei:
        s.read_rows(ids)
    e = ei.value
    assert e.chunk_start == 16
    assert e.round_no == 7
    assert set(e.dirty_rows) == set(range(16, 24))
    assert "quarantine" in e.path and os.path.exists(e.path)
    assert not os.path.exists(os.path.join(s.path, fname))
    assert s.corrupt_chunks == 1


def test_corrupt_clean_chunk_rebuilds_from_template(tmp_path):
    """A mismatching chunk whose rows never held trained data self-heals:
    quarantined and rebuilt from the field templates/defaults."""
    import os

    s, tpl = _fault_store(tmp_path)
    ids = np.arange(16)
    s.write_rows(ids, {"params": np.ones((16, 6), np.float32)})
    # Reclassify the rows as template-only (the store tracks dirtiness to
    # make exactly this call): corruption must then rebuild, not raise.
    s._chunks[0]["dirty"].clear()
    s.update_meta()
    fname = s._chunks[0]["file"]
    with open(os.path.join(s.path, fname), "r+b") as f:
        f.seek(30)
        b = f.read(1)
        f.seek(30)
        f.write(bytes([b[0] ^ 0x10]))
    got = s.read_rows(ids)
    np.testing.assert_array_equal(got["params"],
                                  np.broadcast_to(tpl, (16, 6)))
    np.testing.assert_array_equal(got["w"], np.ones(16, np.float32))
    assert s.rebuilt_rows == 16 and s.corrupt_chunks == 1


def test_transient_eio_is_retried_and_accounted(tmp_path):
    """Bounded-transient read faults are absorbed by backoff + retry and
    show up in io_retries / backoff_seconds, not as errors."""
    fi = FaultInjector(seed=3, eio_prob=1.0, eio_max_per_path=2)
    s, _ = _fault_store(tmp_path, faults=fi)
    ids = np.arange(8)
    s.write_rows(ids, {"params": np.ones((8, 6), np.float32)})
    s.update_meta()
    got = s.read_rows(ids)
    np.testing.assert_array_equal(got["params"], np.ones((8, 6), np.float32))
    assert s.io_retries >= 2
    assert s.backoff_seconds > 0.0


def test_torn_write_is_retried_to_durability(tmp_path):
    """A torn write (partial tmp dumped, EIO before the rename) is healed
    by the bounded write retry; the committed bytes verify clean."""
    fi = FaultInjector(seed=5, torn_write_prob=1.0, torn_max_per_path=1)
    s, _ = _fault_store(tmp_path, faults=fi)
    ids = np.arange(8)
    s.write_rows(ids, {"params": np.full((8, 6), 2.0, np.float32)})
    s.update_meta()
    assert fi.faults_injected >= 1
    v = s.verify_chunks()
    assert v["verified"] >= 1
    s2 = ClientStore.open(s.path)
    np.testing.assert_array_equal(
        s2.read_rows(ids)["params"], np.full((8, 6), 2.0, np.float32))


@pytest.mark.parametrize("crash_on", ["chunk-write", "manifest-commit"])
def test_crash_points_reopen_bit_identical(tmp_path, crash_on):
    """Kill the process mid-chunk-write / mid-manifest-commit: the reopened
    store is bit-identical to the last committed round."""
    import os

    s, _ = _fault_store(tmp_path)
    ids = np.arange(8)
    s.write_rows(ids, {"params": np.full((8, 6), 1.0, np.float32)})
    s.update_meta(round=1)
    committed_bytes = {
        ent["file"]: open(os.path.join(s.path, ent["file"]), "rb").read()
        for ent in s._chunks.values()
    }
    s.faults = FaultInjector(seed=0, crash_on=crash_on)
    with pytest.raises(InjectedCrash):
        s.write_rows(ids, {"params": np.full((8, 6), 5.0, np.float32)})
        s.update_meta(round=2)  # reached only for the manifest crash point
    s2 = ClientStore.open(s.path)
    assert s2.meta["round"] == 1
    np.testing.assert_array_equal(
        s2.read_rows(ids)["params"], np.full((8, 6), 1.0, np.float32))
    for fname, data in committed_bytes.items():
        assert open(os.path.join(s2.path, fname), "rb").read() == data
    assert not [x for x in os.listdir(s2.path) if x.endswith(".tmp")]


def test_manifest_self_checksum_detects_corruption(tmp_path):
    """The manifest is the recovery root: a flipped bit inside it fails
    the embedded self-seal loudly instead of mis-reading the store."""
    import json
    import os

    s, _ = _fault_store(tmp_path)
    s.write_rows(np.arange(4), {"params": np.ones((4, 6), np.float32)})
    s.update_meta(round=3)
    assert s.verify_chunks()["verified"] >= 2  # chunk + sealed manifest
    mpath = os.path.join(s.path, "manifest.json")
    m = json.load(open(mpath))
    m["meta"]["round"] = 999  # tampered commit record, stale seal
    with open(mpath, "w") as f:
        json.dump(m, f)
    with pytest.raises(StoreCorruptionError, match="self-checksum"):
        ClientStore.open(s.path)
    with pytest.raises(StoreCorruptionError):
        s.verify_chunks()


def test_blob_roundtrip_and_corruption_raises(tmp_path):
    import os

    s, _ = _fault_store(tmp_path)
    live = np.array([1, 0, -1, 1], dtype=np.int8)
    s.write_blob("churn_live", live)
    s.update_meta()
    np.testing.assert_array_equal(s.read_blob("churn_live"), live)
    assert s.read_blob("never_written") is None
    fname = s._blobs["churn_live"]["file"]
    with open(os.path.join(s.path, fname), "r+b") as f:
        f.seek(-1, 2)
        b = f.read(1)
        f.seek(-1, 2)
        f.write(bytes([b[0] ^ 1]))
    with pytest.raises(StoreCorruptionError, match="churn_live"):
        s.read_blob("churn_live")


def test_prefetch_error_carries_round_and_path_context(tmp_path):
    """A background prefetch failure re-raises at wait() as StoreIOError
    naming the round, operation, and file — not a bare OSError from a
    daemon thread."""
    import os

    s, _ = _fault_store(tmp_path)
    ids = np.arange(16, 24)
    s.write_rows(ids, {"params": np.ones((8, 6), np.float32)})
    s.update_meta()
    os.remove(os.path.join(s.path, s._chunks[16]["file"]))
    p = Prefetcher(s, RowCache(32))
    try:
        with pytest.raises(StoreIOError) as ei:
            p.submit(ids, round_no=11).wait()
    finally:
        p.close()
    e = ei.value
    assert e.op == "prefetch" and e.round_no == 11
    assert e.path and "rows_" in e.path
    assert isinstance(e.__cause__, FileNotFoundError)
    assert "round 11" in str(e)


def test_writeback_error_carries_context(tmp_path):
    """Write-back failures surface at flush() with the same context
    wrapping (satellite: no silent background-thread deaths).  The
    injected tear outlives the bounded retry budget, so the write is a
    hard failure, not an absorbed transient."""
    from repro.store import Writeback

    fi = FaultInjector(seed=9, torn_write_prob=1.0, torn_max_per_path=100)
    s, _ = _fault_store(tmp_path, faults=fi)
    wb = Writeback(s, RowCache(32))
    try:
        ids = np.arange(4)
        rows = {"params": np.ones((4, 6), np.float32)}
        for gid in ids:
            wb.cache.put_pending(int(gid),
                                 {k: v[gid] for k, v in rows.items()})
        wb.enqueue(ids, rows, round_no=5)
        with pytest.raises(StoreIOError) as ei:
            wb.flush()
        assert ei.value.op == "write-back" and ei.value.round_no == 5
        assert isinstance(ei.value.__cause__, OSError)
    finally:
        wb.close()


def test_fault_injector_validation():
    with pytest.raises(ValueError, match="probability in \\[0, 1\\]"):
        FaultInjector(eio_prob=1.5)
    with pytest.raises(ValueError, match="crash_on"):
        FaultInjector(crash_on="power-loss")
    with pytest.raises(ValueError, match="faults.*paged"):
        model = tiny_mlp(in_dim=16, n_classes=4)
        algo = make_algo("dfedsgpsm", local_steps=2, batch_size=8)
        topo = TopologyConfig(kind="kout", n_clients=N, k_out=2)
        FLTrainer(model.loss, model.init, _client_data(N), algo, topo,
                  faults=FaultInjector(eio_prob=0.1))
