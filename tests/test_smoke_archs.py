"""Per-architecture smoke tests (task spec): a REDUCED variant of each family
(2 layers, d_model<=512, <=4 experts) runs one forward + one train step on
CPU; asserts output shapes and absence of NaNs.  The FULL configs are only
exercised via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCH_IDS, get_config, input_specs, make_batch
from repro.models.registry import get_model_api

B, S = 2, 16


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_reduced_config_limits(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.n_layers <= 2 or cfg.block_kind == "xlstm" and cfg.n_layers <= 12
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4
    full = get_config(arch)
    assert full.family == cfg.family and full.block_kind == cfg.block_kind


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch, smoke=True)
    api = get_model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, S, seed=0)
    logits, _ = jax.jit(api.forward)(params, batch)
    n_txt = batch["tokens"].shape[1] if "tokens" in batch else S
    if cfg.task == "vlm":
        expect_s = batch["image_feats"].shape[1] + n_txt
    elif cfg.task == "masked_lm":
        expect_s = S
    else:
        expect_s = n_txt
    assert logits.shape == (B, expect_s, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_one_train_step(arch):
    cfg = get_config(arch, smoke=True)
    api = get_model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, S, seed=1)

    @jax.jit
    def step(p):
        (l, (_, acc)), g = jax.value_and_grad(api.loss, has_aux=True)(p, batch)
        new = jax.tree.map(lambda a, b: a - 0.01 * b.astype(a.dtype), p, g)
        return l, new

    loss, new_params = step(params)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(new_params):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_decode_step_shapes(arch):
    cfg = get_config(arch, smoke=True)
    if not cfg.supports_decode():
        pytest.skip("encoder-only")
    api = get_model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    cache = api.init_cache(jax.random.PRNGKey(1), B, 32)
    toks = jnp.zeros((B,), jnp.int32)
    logits, new_cache = jax.jit(api.decode_step)(params, cache, toks, jnp.int32(3))
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
@pytest.mark.parametrize("shape", sorted(INPUT_SHAPES))
def test_input_specs_cover_all_shapes(arch, shape):
    cfg = get_config(arch)
    specs = input_specs(cfg, shape)
    assert specs, "input_specs must be non-empty"
    for sds in specs.values():
        assert isinstance(sds, jax.ShapeDtypeStruct)
        assert all(d > 0 for d in sds.shape) or sds.shape == ()
