import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # graceful tier-1 degradation (see tests/_hyp.py)
    from _hyp import given, settings, st

from repro import checkpoint
from repro.data.dirichlet import dirichlet_partition, partition_summary, stack_client_data
from repro.data.synthetic import make_dataset, make_lm_stream


@given(st.integers(2, 20), st.floats(0.05, 5.0), st.integers(0, 999))
@settings(max_examples=10, deadline=None)
def test_dirichlet_is_partition(n_clients, alpha, seed):
    """Every sample index appears exactly once across clients."""
    labels = np.random.default_rng(seed).integers(0, 10, size=503)
    parts = dirichlet_partition(labels, n_clients, alpha, seed=seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == len(labels)
    assert len(np.unique(allidx)) == len(labels)
    assert all(len(p) >= 2 for p in parts)


def test_dirichlet_skew_monotone_in_alpha():
    labels = np.random.default_rng(0).integers(0, 10, size=5000)
    skews = {}
    for alpha in (0.1, 1.0, 100.0):
        parts = dirichlet_partition(labels, 20, alpha, seed=1)
        skews[alpha] = partition_summary(labels, parts)["mean_tv_from_uniform"]
    assert skews[0.1] > skews[1.0] > skews[100.0]


def test_iid_partition():
    labels = np.random.default_rng(0).integers(0, 10, size=1000)
    parts = dirichlet_partition(labels, 10, alpha=0.0, seed=0)
    sizes = [len(p) for p in parts]
    assert max(sizes) - min(sizes) <= 1


def test_stack_client_data_shapes():
    labels = np.random.default_rng(0).integers(0, 10, size=300)
    data = {"x": np.random.default_rng(1).standard_normal((300, 5)), "y": labels}
    parts = dirichlet_partition(labels, 7, 0.5, seed=0)
    stacked = stack_client_data(data, parts, pad_to=64)
    assert stacked["x"].shape == (7, 64, 5)
    assert stacked["y"].shape == (7, 64)


def test_synthetic_dataset_learnable():
    """A linear probe separates the synthetic classes far above chance."""
    train, test = make_dataset("mnist", 2000, 500, seed=0)
    x = train["x"].reshape(len(train["x"]), -1)
    # one-shot ridge classifier
    y = np.eye(10)[train["y"]]
    w = np.linalg.lstsq(x.T @ x + 10 * np.eye(x.shape[1]), x.T @ y, rcond=None)[0]
    xt = test["x"].reshape(len(test["x"]), -1)
    acc = (np.argmax(xt @ w, 1) == test["y"]).mean()
    assert acc > 0.5


def test_lm_stream_has_structure():
    toks = np.asarray(make_lm_stream(512, 128, 16, seed=0))
    assert toks.shape == (16, 128)
    assert toks.min() >= 0 and toks.max() < 512
    # Markov structure: repeated bigrams occur far more often than uniform
    big = {}
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            big[(a, b)] = big.get((a, b), 0) + 1
    top = max(big.values())
    assert top >= 3  # uniform expectation ~0.008 repeats per pair


def test_checkpoint_roundtrip_and_retention(tmp_path):
    tree = {"layer": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "step": jnp.int32(7)}
    for step in range(5):
        checkpoint.save(str(tmp_path), step, tree, keep=2)
    latest = checkpoint.latest_checkpoint(str(tmp_path))
    assert latest.endswith("ckpt_4.npz")
    restored = checkpoint.restore(latest, like=tree)
    np.testing.assert_array_equal(np.asarray(restored["layer"]["w"]),
                                  np.asarray(tree["layer"]["w"]))
    import os
    kept = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert len(kept) == 2


def test_checkpoint_structure_mismatch(tmp_path):
    path = checkpoint.save(str(tmp_path), 0, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        checkpoint.restore(path, like={"b": jnp.zeros(3)})
