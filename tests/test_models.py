"""Model-zoo correctness: per-family math checks + prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import reduced
from repro.configs.registry import ARCH_IDS, get_config, make_batch
from repro.models import xlstm
from repro.models.moe import _moe_dense, _moe_gshard, _router_probs, moe_defs
from repro.models.pdefs import init_tree
from repro.models.registry import get_model_api


def _api(arch):
    import dataclasses

    cfg = get_config(arch, smoke=True)
    if cfg.n_experts:
        # ample capacity: MoE token-dropping is a batching policy and would
        # (correctly) make prefill vs decode outputs diverge at tiny S.
        cfg = dataclasses.replace(cfg, capacity_factor=32.0)
    return get_model_api(cfg)


# ---------------------------------------------------------------------------
# Decode == prefill logits (causal archs): the KV-cache/state path must agree
# with the parallel path.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "arch", [a for a in ARCH_IDS if get_config(a, smoke=True).supports_decode()
             and get_config(a, smoke=True).task == "lm"]
)
def test_decode_matches_prefill(arch):
    api = _api(arch)
    cfg = api.cfg
    B, S = 2, 10
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, S, seed=1)
    full_logits, _ = jax.jit(api.forward)(params, batch)

    logits_pre, cache = jax.jit(lambda p, b: api.prefill(p, b, S + 4))(params, batch)
    np.testing.assert_allclose(
        np.asarray(full_logits, np.float32), np.asarray(logits_pre, np.float32),
        rtol=2e-2, atol=2e-2)

    # decode the last token again from the cache state at position S-1:
    # rebuild cache from a prefill of the first S-1 tokens, then one decode.
    short = {"tokens": batch["tokens"][:, : S - 1]}
    _, cache2 = jax.jit(lambda p, b: api.prefill(p, b, S + 4))(params, short)
    logits_step, _ = jax.jit(api.decode_step)(
        params, cache2, batch["tokens"][:, S - 1], jnp.int32(S - 1))
    np.testing.assert_allclose(
        np.asarray(full_logits[:, -1], np.float32),
        np.asarray(logits_step, np.float32), rtol=3e-2, atol=3e-2)


def test_vlm_decode_matches_prefill():
    api = _api("llava-next-mistral-7b")
    cfg = api.cfg
    B, S = 2, 12
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, S, seed=2)
    full_logits, _ = jax.jit(api.forward)(params, batch)
    n_img = batch["image_feats"].shape[1]
    st = batch["tokens"].shape[1]
    short = {"tokens": batch["tokens"][:, : st - 1], "image_feats": batch["image_feats"]}
    _, cache = jax.jit(lambda p, b: api.prefill(p, b, S + 4))(params, short)
    pos = n_img + st - 1
    logits_step, _ = jax.jit(api.decode_step)(
        params, cache, batch["tokens"][:, -1], jnp.int32(pos))
    np.testing.assert_allclose(
        np.asarray(full_logits[:, -1], np.float32),
        np.asarray(logits_step, np.float32), rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# mLSTM parallel form == recurrent form.
# ---------------------------------------------------------------------------

def test_mlstm_parallel_equals_recurrent():
    B, S, H, hd = 2, 12, 3, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    i_pre = jax.random.normal(ks[3], (B, S, H))
    f_pre = jax.random.normal(ks[4], (B, S, H)) + 1.0

    par = xlstm.mlstm_parallel(q, k, v, i_pre, f_pre)

    state = (
        jnp.zeros((B, H, hd, hd)), jnp.zeros((B, H, hd)), jnp.zeros((B, H)))
    outs = []
    for t in range(S):
        state, h = xlstm.mlstm_step(
            state, q[:, t], k[:, t], v[:, t], i_pre[:, t], f_pre[:, t])
        outs.append(h)
    rec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(par), np.asarray(rec), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# MoE: gshard capacity dispatch == exact dense reference (ample capacity).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["dbrx-132b", "deepseek-v3-671b"])
def test_moe_gshard_matches_dense(arch):
    import dataclasses

    cfg = dataclasses.replace(get_config(arch, smoke=True), capacity_factor=8.0)
    defs = moe_defs(cfg)
    p = init_tree(jax.random.PRNGKey(1), defs)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model), cfg.dtype)
    w, sel, _ = _router_probs(p, x, cfg)
    dense = _moe_dense(p, x, w, sel, cfg)
    gshard = _moe_gshard(p, x, w, sel, cfg)
    np.testing.assert_allclose(
        np.asarray(dense, np.float32), np.asarray(gshard, np.float32),
        rtol=2e-2, atol=2e-2)


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 the gshard path must drop load (not crash)
    and still return finite outputs."""
    import dataclasses

    cfg = dataclasses.replace(get_config("dbrx-132b", smoke=True), capacity_factor=0.25)
    p = init_tree(jax.random.PRNGKey(1), moe_defs(cfg))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model), cfg.dtype)
    w, sel, _ = _router_probs(p, x, cfg)
    out = _moe_gshard(p, x, w, sel, cfg)
    assert np.all(np.isfinite(np.asarray(out, np.float32)))


# ---------------------------------------------------------------------------
# Sliding-window masking really restricts attention.
# ---------------------------------------------------------------------------

def test_sliding_window_blocks_distant_tokens():
    from repro.models.attention import _full_mask

    pos = jnp.arange(10)[None]
    m = np.asarray(_full_mask(pos, pos, 4, True))[0]
    # window=4 -> attend to distances 0..3 (mistral convention)
    assert m[9, 6] == 0.0  # within window (distance 3)
    assert m[9, 5] < -1e30  # outside window (distance 4)
    assert m[4, 9] < -1e30  # future masked
    full = np.asarray(_full_mask(pos, pos, 0, True))[0]
    assert full[9, 0] == 0.0  # window=0 -> unbounded causal


def test_encoder_attends_bidirectionally():
    api = _api("hubert-xlarge")
    cfg = api.cfg
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 8, seed=0)
    logits, _ = jax.jit(api.forward)(params, batch)
    # flipping a late frame must change logits of an early position
    b2 = dict(batch)
    feats = np.asarray(batch["features"]).copy()
    feats[:, -1] += 10.0
    b2["features"] = jnp.asarray(feats)
    logits2, _ = jax.jit(api.forward)(params, b2)
    assert not np.allclose(np.asarray(logits[:, 0]), np.asarray(logits2[:, 0]))


# ---------------------------------------------------------------------------
# Trainability: a few SGD steps reduce every arch's loss.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", sorted(ARCH_IDS))
def test_few_steps_reduce_loss(arch):
    api = _api(arch)
    cfg = api.cfg
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 16, seed=3)

    @jax.jit
    def step(p):
        (l, _), g = jax.value_and_grad(api.loss, has_aux=True)(p, batch)
        return l, jax.tree.map(lambda a, b: a - 0.05 * b.astype(a.dtype), p, g)

    l0, params = step(params)
    for _ in range(8):
        l1, params = step(params)
    assert np.isfinite(float(l1))
    assert float(l1) < float(l0)
