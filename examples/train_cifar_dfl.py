"""End-to-end driver: the paper's CIFAR-10 experiment.

CNN backbone, Dirichlet non-IID partition, 9 selectable algorithms,
checkpointing, and JSON logging.  Scaled to CPU by default (~1 min/round on
a 1-core container); --paper approaches the paper's setting (100 clients,
500 rounds, ResNet-18-GN) on real hardware.

  PYTHONPATH=src python examples/train_cifar_dfl.py --algo dfedsgpsm --rounds 15
"""
import argparse
import json
import os

import jax.numpy as jnp

from repro import checkpoint
from repro.core import ALGORITHMS, FLTrainer, TopologyConfig, make_algo
from repro.data.dirichlet import dirichlet_partition, partition_summary, stack_client_data
from repro.data.synthetic import make_dataset
from repro.models.small import get_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="dfedsgpsm", choices=sorted(ALGORITHMS))
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=0.3, help="Dirichlet (<=0 = IID)")
    ap.add_argument("--model", default="cifar_cnn",
                    choices=["cifar_cnn", "resnet18_gn", "mnist_2nn"])
    ap.add_argument("--local-steps", type=int, default=3)
    ap.add_argument("--participation", type=float, default=0.25)
    ap.add_argument("--paper", action="store_true",
                    help="paper scale: 100 clients, 500 rounds, resnet18_gn")
    ap.add_argument("--superstep", type=int, default=5,
                    help="rounds per jit-resident lax.scan chunk; eval runs "
                         "in-scan every 5 (global) rounds and checkpoints "
                         "land at superstep boundaries")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true",
                    help="warm-restart the full FLState (params + momentum "
                         "bank + push-sum weights + round) from --ckpt-dir")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.paper:
        args.clients, args.rounds, args.model = 100, 500, "resnet18_gn"
        args.participation = 0.1

    train, test = make_dataset("cifar10", 4000, 1000, seed=0)
    parts = dirichlet_partition(train["y"], args.clients, args.alpha, seed=0)
    print("partition:", partition_summary(train["y"], parts))
    cdata = {k: jnp.asarray(v) for k, v in
             stack_client_data(train, parts, pad_to=256).items()}
    testj = {k: jnp.asarray(v) for k, v in test.items()}

    model = get_model(args.model, n_classes=10)
    algo = make_algo(args.algo, local_steps=args.local_steps, batch_size=32)
    topo = TopologyConfig(
        kind="kout", n_clients=args.clients,
        k_out=max(int(args.participation * args.clients), 1))
    tr = FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0,
                   participation=args.participation)

    start = 0
    history = []
    if args.resume:
        path = checkpoint.latest_checkpoint(args.ckpt_dir)
        if path is not None:
            state = tr.restore(path)
            start = int(state.round)
            print(f"resumed {path} at round {start}")
            if args.out and os.path.exists(args.out):
                with open(args.out) as f:  # keep the pre-resume curve
                    history = [r for r in json.load(f) if r["round"] < start]
    # Jit-resident supersteps: each chunk of rounds is one lax.scan inside
    # one jit with donated state; eval happens in-scan (cadence keyed on the
    # global round counter, so it is stable across chunks and --resume) and
    # the host only sees superstep boundaries — where logs and the full
    # warm-restartable FLState checkpoint land.
    for r0 in range(start, args.rounds, max(args.superstep, 1)):
        chunk = min(max(args.superstep, 1), args.rounds - r0)
        for raw in tr.fit(chunk, test_data=testj, eval_every=5):
            rec = {"round": r0 + raw["round"], "train_loss": raw["loss"],
                   "train_acc": raw["acc"]}
            if "test_acc" in raw:
                rec.update(test_loss=raw["test_loss"],
                           test_acc=raw["test_acc"])
                print(f"round {rec['round']:4d} "
                      f"loss={rec['train_loss']:.3f} "
                      f"test_acc={rec['test_acc']:.3f}")
            else:
                print(f"round {rec['round']:4d} "
                      f"loss={rec['train_loss']:.3f}")
            history.append(rec)
        tr.save(args.ckpt_dir, r0 + chunk)  # full FLState at the boundary
        print(f"superstep [{r0}, {r0 + chunk}) done (ckpt saved)")
    if history and "test_acc" not in history[-1]:
        tl, ta = tr.evaluate(testj)
        history[-1].update(test_loss=tl, test_acc=ta)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(history, f, indent=1)
    if history:
        print("final:", history[-1])
    print("latest ckpt:", checkpoint.latest_checkpoint(args.ckpt_dir))


if __name__ == "__main__":
    main()
