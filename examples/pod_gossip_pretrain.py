"""Pods-as-clients DFL pretraining (the paper's technique at datacenter
scale): every "pod" holds a full model replica; pods run K local
SAM-momentum steps on their own data shard and exchange parameters via
directed push-sum gossip — no cross-pod all-reduce.

On this CPU container the "pods" are host devices on a (pod, data, model)
mesh; on a real v5e deployment the same code runs with
make_production_mesh(multi_pod=True).

  JAX_PLATFORMS=cpu XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/pod_gossip_pretrain.py --rounds 10
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.registry import get_config  # noqa: E402
from repro.data.synthetic import make_lm_stream  # noqa: E402
from repro.launch import sharding as shlib  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.launch.steps import StepConfig, make_round_step, pod_mixing_matrix  # noqa: E402
from repro.models.pdefs import PDef  # noqa: E402
from repro.models.registry import get_model_api  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8, help="per-pod batch")
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    mesh = make_host_mesh((2, 2, 2), ("pod", "data", "model"))
    n_pods = mesh.shape["pod"]
    cfg = dataclasses.replace(get_config(args.arch, smoke=True))
    api = get_model_api(cfg)
    step_cfg = StepConfig(lr=0.05, alpha=0.9, rho=0.05,
                          local_steps=args.local_steps)
    round_step = jax.jit(make_round_step(api, step_cfg), donate_argnums=(0, 1))

    with shlib.use_mesh(mesh, fsdp=False):
        def stack_init(key):
            p = api.init(key)
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_pods,) + x.shape), p)

        params = stack_init(jax.random.PRNGKey(0))
        defs = api.param_defs()

        def shard(x, d: PDef):
            spec = shlib.spec_for(d, mesh, fsdp=False)
            return jax.device_put(x, NamedSharding(mesh, P("pod", *spec)))

        params = jax.tree.map(shard, params, defs,
                              is_leaf=lambda x: isinstance(x, PDef))
        v = jax.tree.map(lambda x: jnp.zeros_like(x), params)
        w = jnp.ones((n_pods,))
        P_pod = pod_mixing_matrix(n_pods)
        tokens = make_lm_stream(cfg.vocab_size, args.seq,
                                n_pods * args.local_steps * args.batch * args.rounds)
        tokens = tokens.reshape(args.rounds, n_pods, args.local_steps,
                                args.batch, args.seq)

        print(f"{cfg.name} reduced | {n_pods} pods | K={args.local_steps} "
              f"| push-sum ring gossip")
        for r in range(args.rounds):
            t0 = time.time()
            batch = {"tokens": tokens[r]}
            params, v, w, _, _, m = round_step(params, v, w, (), (), batch,
                                               P_pod)
            print(f"round {r:3d} loss={float(m['loss']):.4f} "
                  f"acc={float(m['acc']):.4f} "
                  f"w={[round(float(x), 3) for x in w]} "
                  f"({time.time() - t0:.2f}s)")
        assert abs(float(w.sum()) - n_pods) < 1e-3, "push-sum mass conserved"
        print("done — consensus mass conserved:", float(w.sum()))


if __name__ == "__main__":
    main()
