"""Serve a reduced model from the assigned-architecture zoo with batched
requests: prefill the prompt batch into the KV cache, then decode greedily.

  PYTHONPATH=src python examples/serve_decode.py --arch hymba-1.5b --new-tokens 8
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config
from repro.models.registry import get_model_api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b",
                    choices=[a for a in ARCH_IDS if a != "hubert-xlarge"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)  # reduced variant runs on CPU
    api = get_model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    print(f"{cfg.name}: reduced variant, {api.num_params() / 1e6:.2f}M params")

    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(
        rng, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    cache_len = args.prompt_len + args.new_tokens

    t0 = time.time()
    batch = {"tokens": prompts}
    if cfg.task == "vlm":
        batch["image_feats"] = jax.random.normal(
            rng, (args.batch, 8, cfg.frontend_dim))
    logits, cache = jax.jit(
        lambda p, b: api.prefill(p, b, cache_len))(params, batch)
    print(f"prefill: {args.batch}x{args.prompt_len} in {time.time() - t0:.2f}s")

    step = jax.jit(api.decode_step)
    toks = logits[:, -1].argmax(-1).astype(jnp.int32)
    out = [toks]
    n_prefix = 8 if cfg.task == "vlm" else 0
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        pos = jnp.int32(n_prefix + args.prompt_len + i)
        logits_i, cache = step(params, cache, toks, pos)
        toks = logits_i.argmax(-1).astype(jnp.int32)
        out.append(toks)
    dt = time.time() - t0
    gen = jnp.stack(out, axis=1)
    print(f"decoded {args.new_tokens - 1} steps x {args.batch} seqs "
          f"in {dt:.2f}s ({1e3 * dt / max(args.new_tokens - 1, 1):.1f} ms/step)")
    print("generated token ids:\n", gen)


if __name__ == "__main__":
    main()
