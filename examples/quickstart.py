"""Quickstart: train DFedSGPSM (the paper's algorithm) on a synthetic
non-IID MNIST-shaped task with 16 clients over a directed time-varying
topology, and compare against OSGP (the asymmetric baseline it extends).

Because an algorithm is just a (LocalSolver, Compressor, Mixer) stage
composition, a third run swaps in top-k sparsification with error feedback
via a one-line override — ~5% of coordinates on the wire per round, same
push-sum mixing.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import FLTrainer, TopologyConfig, make_algo
from repro.data.dirichlet import dirichlet_partition, stack_client_data
from repro.data.synthetic import make_dataset
from repro.models.small import mnist_2nn


def main():
    n_clients, rounds = 16, 20
    train, test = make_dataset("mnist", 4000, 1000, seed=0)
    parts = dirichlet_partition(train["y"], n_clients, alpha=0.3, seed=0)
    cdata = {k: jnp.asarray(v) for k, v in
             stack_client_data(train, parts, pad_to=256).items()}
    testj = {k: jnp.asarray(v) for k, v in test.items()}
    model = mnist_2nn()
    topo = TopologyConfig(kind="kout", n_clients=n_clients, k_out=4)

    runs = [
        ("osgp", make_algo("osgp", local_steps=5, batch_size=32)),
        ("dfedsgpsm", make_algo("dfedsgpsm", local_steps=5, batch_size=32)),
        # Same round program, compressed gossip: top-k + error feedback.
        ("dfedsgpsm+topk_ef",
         make_algo("dfedsgpsm", local_steps=5, batch_size=32,
                   compressor="topk_ef")),
    ]
    for name, algo in runs:
        tr = FLTrainer(model.loss, model.init, cdata, algo, topo, seed=0,
                       participation=0.25)
        # superstep=10: each 10-round chunk is ONE lax.scan inside one jit
        # (donated state, eval in-scan every 5 rounds); the log callback
        # fires at superstep boundaries.
        tr.fit(rounds, test_data=testj, eval_every=5, superstep=10,
               log=lambda r: print(f"  [{name}] round {r['round']:3d} "
                                   f"loss={r['loss']:.3f}"
                                   + (f" test_acc={r['test_acc']:.3f}"
                                      if "test_acc" in r else "")))
        loss, acc = tr.evaluate(testj)
        w = tr.state.w
        print(f"{name}: final test acc={acc:.3f} loss={loss:.3f} "
              f"(push-sum mass {float(w.sum()):.3f} == n_clients)")


if __name__ == "__main__":
    main()
