"""End-to-end round timing: flat (n, D) bank path vs the seed pytree path,
the jit-resident scanned superstep driver vs the per-round Python loop, and
sparse neighbor-list gossip vs the dense mixing matmul across client counts.

The flat path runs the whole round through the Pallas kernels — one
``gossip_matmul`` for the entire model and one ``fused_update`` per inner
step — versus the seed's per-leaf einsum + three tree-mapped elementwise
passes.  Benchmarks the paper's 16-client setting for the flagship
DFedSGPSM and the DFedSAM baseline (Algorithm 1 with/without push-sum);
their two-pass SAM gradients are the paper's hot path and amortize the
bank <-> pytree boundary.  The scanned comparison times
``program.run_superstep`` (all rounds in ONE dispatch, donated carry)
against the same number of per-round jit dispatches.  The ``--n-clients``
sweep scales the round from 16 to hundreds of clients at fixed ``k_out``
and times the O(n * k_max * D) neighbor-gather gossip against the
O(n^2 * D) dense matmul (gossip-dominated SGP config, K=1).  ``--shard``
row-shards the whole round over a forced 8-device ``clients`` mesh
(GSPMD) with both the all-gather and the halo-exchange executor, pins
sharded-vs-single-device equivalence + the push-sum mass invariant, and
records round times plus the CommPlan halo-rows/bytes-moved-per-round
counters against the all-gather baseline (``bench-shard.json``).  All
timings are median-of-k after explicit warmup (robust to container
scheduling noise) via ``common.emit``.

Tuned-launcher environment for quiet, repeatable CPU numbers (mirrors the
production run.sh recipe):

    # thread-caching malloc: first-touch page faults dominate the big-bank
    # paths without it
    export LD_PRELOAD=/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4
    # pin XLA's host thread pool to the physical cores (oversubscription
    # adds multi-ms jitter per dispatch)
    export XLA_FLAGS="--xla_cpu_multi_thread_eigen=true \
        --xla_force_host_platform_device_count=8"   # --shard runs only
    # persistent compilation cache (benchmarks.common enables it; point it
    # at a kept path to reuse executables across CI runs)
    export JAX_COMPILATION_CACHE_DIR=~/.cache/jax
"""
from __future__ import annotations

import os
import sys

if "--shard" in sys.argv and "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    # Must happen before jax initializes its platform (any jax import
    # below): the sharded bench simulates an 8-device CPU mesh.
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import json
import statistics
import time

import jax

from benchmarks.common import build_setting, emit
from repro.core import FLTrainer, TopologyConfig, make_algo

N_CLIENTS = 16

# CI regression gate: the flat path must not lose more than this factor of
# its recorded pytree-relative speedup, and the scanned superstep driver no
# more than this factor of its recorded loop-relative speedup (machine
# speed cancels in both ratios).
SMOKE_TOLERANCE = 1.3
# Explicit warmup runs (beyond the compile call) before any timed window.
WARMUP = 2
BASELINE = os.path.join(os.path.dirname(__file__), "round_baseline.json")


def _time_rounds(tr: FLTrainer, rounds: int, warmup: int = WARMUP) -> float:
    """Median microseconds per round after compile + ``warmup`` rounds."""
    for _ in range(1 + warmup):  # compile, then populate caches/allocator
        tr.run_round()
    jax.block_until_ready(tr.state.params)
    times = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        tr.run_round()
        jax.block_until_ready(tr.state.params)
        times.append(1e6 * (time.perf_counter() - t0))
    return statistics.median(times)


def _time_loop(tr: FLTrainer, rounds: int, repeats: int = 5,
               warmup: int = WARMUP) -> float:
    """Median us/round over ``repeats`` timed windows of ``rounds``
    per-round jit dispatches — the Python-loop driver's amortized cost."""
    for _ in range(1 + warmup):
        tr.run_round()
    jax.block_until_ready(tr.state.params)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(rounds):
            tr.run_round()
        jax.block_until_ready(tr.state.params)
        times.append(1e6 * (time.perf_counter() - t0) / rounds)
    return statistics.median(times)


def _time_scanned(tr: FLTrainer, rounds: int, repeats: int = 5,
                  warmup: int = WARMUP) -> float:
    """Median us/round for ``program.run_superstep`` — the whole window of
    rounds is one ``lax.scan`` inside one jit with donated carry."""
    program = tr.program
    state = program.init(jax.random.PRNGKey(0))
    for _ in range(1 + warmup):  # compile + warmup supersteps
        state, _ = program.run_superstep(state, rounds)
    jax.block_until_ready(state.params)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        state, _ = program.run_superstep(state, rounds)
        jax.block_until_ready(state.params)
        times.append(1e6 * (time.perf_counter() - t0) / rounds)
    return statistics.median(times)


def main(fast: bool = False):
    rounds = 8 if fast else 20
    net, cdata, _ = build_setting(
        dataset="mnist", n_clients=N_CLIENTS, samples_per_client=128)
    topo = TopologyConfig(
        kind="kout", n_clients=N_CLIENTS, k_out=max(N_CLIENTS // 4, 1))

    for name in ("dfedsgpsm", "dfedsam"):
        algo = make_algo(name, local_steps=3, batch_size=32)
        timings = {}
        for path in ("flat", "pytree"):
            tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                           participation=0.25, flat=(path == "flat"))
            timings[path] = _time_rounds(tr, rounds)
            d = tr.spec.dim
            emit(f"round/{name}/{path}", timings[path],
                 f"n={N_CLIENTS},D={d},rounds={rounds},median")
        emit(f"round/{name}/speedup", timings["pytree"] / timings["flat"],
             "pytree_us/flat_us (>=1 means flat is no slower)")

    # Scanned superstep driver vs the per-round Python loop (flagship algo).
    algo = make_algo("dfedsgpsm", local_steps=3, batch_size=32)
    tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                   participation=0.25)
    loop_us = _time_loop(tr, rounds)
    scan_us = _time_scanned(tr, rounds)
    emit("round/dfedsgpsm/loop", loop_us,
         f"n={N_CLIENTS},rounds={rounds},median")
    emit("round/dfedsgpsm/scanned", scan_us,
         f"n={N_CLIENTS},rounds={rounds},median,one-jit")
    emit("round/dfedsgpsm/scan_speedup", loop_us / scan_us,
         "loop_us/scanned_us (>=1 means the superstep driver is no slower)")


# ---------------------------------------------------------------------------
# Degraded-link scenario (--link-drop [--link-delay/--event-threshold]).
# ---------------------------------------------------------------------------

def degraded(drop: float, delay: int = 0, event_threshold: float = 0.0,
             rounds: int = 5, json_out: str | None = None) -> dict:
    """Time the flagship round under the unreliable-link scenario vs
    perfect links (same seed, same family) and verify the two invariants
    the subsystem is pinned by: the dropped mixing operator stays exactly
    column-stochastic (no mass leak), and total push-sum mass — in-flight
    shares included under delays — equals n every round.  The link model
    costs one drop-mask renormalization per round (plus B+1 sliced mixes
    when delayed), so the overhead ratio is the number to watch.
    """
    from repro.core import LinkModel, make_algo

    net, cdata, _ = build_setting(
        dataset="mnist", n_clients=N_CLIENTS, samples_per_client=128)
    topo = TopologyConfig(
        kind="kout", n_clients=N_CLIENTS, k_out=max(N_CLIENTS // 4, 1))
    algo = make_algo("dfedsgpsm", local_steps=3, batch_size=32)
    link = LinkModel(drop=drop, delay=delay,
                     event_threshold=event_threshold)
    timings, mass_err = {}, 0.0
    for scenario in ("clean", "degraded"):
        tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                       participation=0.25,
                       link=link if scenario == "degraded" else None)
        timings[scenario] = _time_rounds(tr, rounds)
        emit(f"round/link/{scenario}", timings[scenario],
             f"n={N_CLIENTS},drop={drop},delay={delay},rounds={rounds}")
        if scenario == "degraded":
            state, hist = tr.program.run_superstep(tr.state, rounds)
            import numpy as np

            # An all-zero model is the (valid) perfect-link control: the
            # program carries no per-round w_mass metric, so check the
            # final node mass instead.
            mass = (np.asarray(hist["w_mass"]) if "w_mass" in hist
                    else np.asarray(state.w.sum())[None])
            mass_err = float(np.abs(mass - N_CLIENTS).max())
            emit("round/link/mass_err", mass_err,
                 f"max |sum w - n| over {rounds} degraded rounds "
                 "(in-flight mass included)")
            assert mass_err < 1e-3, (
                f"push-sum mass leaked under drops/delays: {mass_err}")
    overhead = timings["degraded"] / timings["clean"]
    emit("round/link/overhead", overhead,
         "degraded_us/clean_us (link-model cost per round)")
    results = {"drop": drop, "delay": delay,
               "event_threshold": event_threshold,
               "clean_us": round(timings["clean"], 1),
               "degraded_us": round(timings["degraded"], 1),
               "overhead": round(overhead, 3),
               "mass_err": mass_err}
    if json_out:
        with open(json_out, "w") as f:
            json.dump({"degraded_link": results}, f, indent=1)
        print(f"# wrote degraded-link results -> {json_out}")
    return results


# ---------------------------------------------------------------------------
# Sparse-vs-dense gossip scaling sweep (--n-clients).
# ---------------------------------------------------------------------------

def scaling(ns: list[int], k_out: int = 10, rounds: int = 5,
            record: bool = False, json_out: str | None = None) -> dict:
    """Time one full round AND the isolated gossip phase per client count
    with the mixing representation forced dense vs sparse (same family,
    same ``k_out``): the paper-scale claim is that the O(n * k_max * D)
    neighbor gather keeps the communication step near-flat in n where the
    O(n^2 * D) matmul grows quadratically.

    Uses the gossip-dominated SGP composition (K=1, batch 1) so the round
    ratio is as close to the communication step as an honest full round
    gets; the ``gossip_*`` columns time one ``mixer.mix`` (bank + push-sum
    weights) on the live bank — the kernel-level number.  ``record``
    merges the table into ``round_baseline.json`` under ``"scaling"``;
    ``json_out`` writes it standalone (the CI artifact).
    """
    from repro.core import topology as topo_mod

    results = {}
    for n in ns:
        net, cdata, _ = build_setting(
            dataset="mnist", n_clients=n, samples_per_client=64)
        k = min(k_out, n - 1)
        topo = TopologyConfig(kind="kout", n_clients=n, k_out=k)
        algo = make_algo("sgp", batch_size=1)  # K=1: gossip-dominated
        t, tg = {}, {}
        for mode in ("dense", "sparse"):
            tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                           participation=0.25, gossip=mode)
            t[mode] = _time_rounds(tr, rounds)
            emit(f"round/scaling/n{n}/{mode}", t[mode],
                 f"k_out={k},D={tr.spec.dim},rounds={rounds},median")
            # Isolated gossip phase: one sampled operator, one mixer.mix
            # (bank + weights) on the trained bank.
            key = jax.random.PRNGKey(7)
            P = (topo_mod.sample_kout_neighbors(key, n, k)
                 if mode == "sparse" else topo_mod.sample_kout(key, n, k))
            mix = jax.jit(tr.program.mixer.mix)
            X, w = tr.state.params, tr.state.w
            out = mix(P, X, w)
            jax.block_until_ready(out[0])
            times = []
            for _ in range(max(rounds, 5)):
                t0 = time.perf_counter()
                out = mix(P, X, w)
                jax.block_until_ready(out[0])
                times.append(1e6 * (time.perf_counter() - t0))
            tg[mode] = statistics.median(times)
            emit(f"gossip/scaling/n{n}/{mode}", tg[mode],
                 f"k_out={k},one mixer.mix,median")
        ratio = t["dense"] / t["sparse"]
        gratio = tg["dense"] / tg["sparse"]
        emit(f"round/scaling/n{n}/speedup", ratio,
             "dense_us/sparse_us (>=1 means sparse gossip wins)")
        emit(f"gossip/scaling/n{n}/speedup", gratio,
             "gossip-phase dense_us/sparse_us")
        results[str(n)] = {"k_out": k,
                           "dense_us": round(t["dense"], 1),
                           "sparse_us": round(t["sparse"], 1),
                           "speedup": round(ratio, 3),
                           "gossip_dense_us": round(tg["dense"], 1),
                           "gossip_sparse_us": round(tg["sparse"], 1),
                           "gossip_speedup": round(gratio, 3)}
    if record:
        base = {}
        if os.path.exists(BASELINE):
            with open(BASELINE) as f:
                base = json.load(f)
        base.setdefault("scaling", {}).update(results)
        base["scaling_note"] = (
            "dense_us/sparse_us per round, median-of-%d after %d warmup "
            "rounds; kout family, sgp (K=1) gossip-dominated config"
            % (rounds, WARMUP))
        with open(BASELINE, "w") as f:
            json.dump(base, f, indent=1)
        print(f"# recorded scaling table -> {BASELINE}")
    if json_out:
        with open(json_out, "w") as f:
            json.dump({"scaling": results}, f, indent=1)
        print(f"# wrote scaling results -> {json_out}")
    return results


# ---------------------------------------------------------------------------
# GSPMD row-sharded round (--shard): 8 simulated devices, clients mesh.
# ---------------------------------------------------------------------------

def shard_bench(n: int = 512, k_out: int = 10, n_pods: int = 8,
                rounds: int = 3, json_out: str | None = None) -> dict:
    """Run the n-client round single-device, GSPMD row-sharded with the
    all-gather executor, and sharded with the halo-exchange executor over
    the forced 8-device ``clients`` mesh — for the static ring family, the
    flat k_out family, and the hierarchical two-tier family (dense
    intra-pod gossip + ``k_out`` cross-pod edges, pods aligned with
    shards).

    Pins the tentpole invariants: BOTH sharded supersteps match the
    single-device program to float tolerance, bank rows live on the
    ``clients`` axis end to end, and push-sum mass stays n.  Each family's
    ``comm`` block records the CommPlan traffic accounting — halo rows /
    bytes received per shard per mix vs the full-bank all-gather's, plus
    the measured distinct remote rows under a sampled realization — and
    the CI gates ride on it: the static ring's halo bytes must be at most
    ``(k_max + 1) / n`` of the all-gather's, and no family's halo may
    exceed all-gather parity.  On CI's single physical core the 8
    simulated devices timeshare, so ``ratio`` is collective-overhead-only
    — a *lower bound* on real multi-device scaling (``rows_per_device``
    and ``halo_rows`` are the quantities that matter off-box).  Uses the
    gossip-dominated SGP config (K=1, batch 1), same as the scaling sweep.
    """
    from repro.comm.plan import CommPlan
    from repro.core import make_program
    from repro.core import topology as topo_mod
    from repro.launch.mesh import make_clients_mesh

    n_dev = jax.device_count()
    assert n_dev >= 2, (
        f"--shard needs forced host devices (got {n_dev}); the module-top "
        "XLA_FLAGS hook only works when --shard is on the command line")
    mesh = make_clients_mesh()
    net, cdata, _ = build_setting(
        dataset="mnist", n_clients=n, samples_per_client=16)
    algo = make_algo("sgp", batch_size=1)  # K=1: gossip-dominated

    results = {"n_clients": n, "n_devices": n_dev,
               "rows_per_device": n // n_dev}
    ok = True
    for fam in ("ring", "kout", "two_tier"):
        kw = {"n_pods": n_pods} if fam == "two_tier" else {}
        topo = TopologyConfig(kind=fam, n_clients=n, k_out=k_out,
                              time_varying=False, **kw)
        progs = {
            "single": make_program(net.loss, net.init, cdata, algo, topo),
            "sharded": make_program(net.loss, net.init, cdata, algo, topo,
                                    gossip="xla", mesh=mesh),
            "halo": make_program(net.loss, net.init, cdata, algo, topo,
                                 gossip="halo", mesh=mesh),
        }
        t, states = {}, {}
        for mode, prog in progs.items():
            state = prog.init(jax.random.PRNGKey(0))
            state, _ = prog.run_superstep(state, rounds)  # compile + warm
            jax.block_until_ready(state.params)
            times = []
            for _ in range(3):
                t0 = time.perf_counter()
                state, _ = prog.run_superstep(state, rounds)
                jax.block_until_ready(state.params)
                times.append(1e6 * (time.perf_counter() - t0) / rounds)
            t[mode] = statistics.median(times)
            states[mode] = state
            emit(f"round/shard/{fam}/{mode}", t[mode],
                 f"n={n},k_out={k_out},rounds={rounds},median")
        fam_ok = True
        equiv = {}
        for mode in ("sharded", "halo"):
            sh = states[mode]
            # Rows must still live on the clients axis after the superstep.
            axis_spec = getattr(sh.params.sharding, "spec", None)
            on_axis = axis_spec is not None and "clients" in tuple(axis_spec)
            equiv_err = float(jax.numpy.max(jax.numpy.abs(
                states["single"].params - jax.device_get(sh.params))))
            mass_err = abs(float(jax.numpy.sum(sh.w)) - n)
            emit(f"round/shard/{fam}/{mode}/equiv_err", equiv_err,
                 "max |sharded - single| over the final bank")
            emit(f"round/shard/{fam}/{mode}/mass_err", mass_err,
                 "|sum w - n|")
            fam_ok = fam_ok and (on_axis and equiv_err < 5e-4 * rounds
                                 and mass_err < 1e-3 * n / 64)
            equiv[mode] = {"equiv_err": equiv_err, "mass_err": mass_err,
                           "rows_on_clients_axis": bool(on_axis)}
        ratio = t["single"] / t["sharded"]
        emit(f"round/shard/{fam}/ratio", ratio,
             "single_us/sharded_us (1-core CI: collective overhead only)")

        # -- CommPlan traffic accounting: what each executor ships ---------
        plan = CommPlan.build(topo, n_shards=mesh.shape["clients"])
        d = progs["single"].spec.dim
        comm = {
            "static": plan.static,
            "halo_rows": plan.halo_rows(),
            "allgather_rows": plan.allgather_rows(),
            "halo_bytes": plan.halo_bytes(d),
            "allgather_bytes": plan.allgather_bytes(d),
            "bytes_ratio": round(
                plan.halo_bytes(d) / plan.allgather_bytes(d), 6),
        }
        if not plan.static:
            # the fixed-capacity transport's PHYSICAL traffic is reported
            # above; also record the distinct rows actually needed under a
            # sampled realization (what a zero-waste transport would ship)
            op = topo_mod.sample_neighbors(jax.random.PRNGKey(7), topo)
            comm["measured"] = plan.measured_rows(op)
        emit(f"round/shard/{fam}/halo_rows", comm["halo_rows"],
             "remote rows received per shard per mix (halo executor)")
        emit(f"round/shard/{fam}/halo_bytes", comm["halo_bytes"],
             f"bytes per shard per mix at D={d} (indices included)")
        emit(f"round/shard/{fam}/bytes_ratio", comm["bytes_ratio"],
             "halo_bytes/allgather_bytes (<1 means halo ships less)")
        if fam == "ring":
            # the static-plan gate: a shift family's halo is O(k) rows,
            # at most (k_max+1)/n of the all-gather's O(n) rows
            bound = (plan.k_max + 1) / n * plan.allgather_bytes(d)
            assert comm["halo_bytes"] <= bound, (
                f"ring halo ships {comm['halo_bytes']}B > (k_max+1)/n "
                f"bound {bound:.0f}B")
            comm["bytes_bound"] = int(bound)
        # Row-payload parity for every family: the halo never ships more
        # bank rows than the all-gather it replaces.  Dynamic transports at
        # worst-case capacity (= m rows per peer) hit exact parity on the
        # payload and pay a small integer-index overhead on top, so the
        # strict byte gate applies to static plans only; the "measured"
        # counter records the distinct rows a zero-waste transport would
        # ship under a sampled realization.
        assert comm["halo_rows"] <= comm["allgather_rows"], (
            f"{fam}: halo ships more rows than the all-gather it replaces")
        if plan.static:
            assert comm["halo_bytes"] <= comm["allgather_bytes"], (
                f"{fam}: static halo traffic exceeds the all-gather")
        ok = ok and fam_ok
        results[fam] = {
            "single_us": round(t["single"], 1),
            "sharded_us": round(t["sharded"], 1),
            "halo_us": round(t["halo"], 1),
            "ratio": round(ratio, 3),
            **equiv["sharded"],
            "halo": equiv["halo"],
            "comm": comm,
            "ok": bool(fam_ok),
        }
        del progs, states, sh
    if json_out:
        with open(json_out, "w") as f:
            json.dump({"shard": results}, f, indent=1)
        print(f"# wrote sharded-round results -> {json_out}")
    assert ok, f"sharded round violated an invariant: {results}"
    return results


# ---------------------------------------------------------------------------
# Virtual client population (--paged): disk-backed store + prefetch paging.
# ---------------------------------------------------------------------------

def paged_bench(n: int = 4096, k_active: int = 256, k_out: int = 4,
                rounds: int = 3, json_out: str | None = None) -> dict:
    """Run the paged trainer over an n-client disk-backed population with
    only the round's fault-in closure resident, and pin the subsystem's
    three contracts: (1) allocation proportionality — device/staging
    buffers hold ``c_max = min(n, k_active*(k_in+1))`` rows, never n;
    (2) exact push-sum mass over the whole store, cold clients included;
    (3) paged == fully-resident float-tolerance equivalence on the same
    PRNG chain, checked at a twin-feasible size (the dense reference
    materializes an (n, n) operator, so it runs at 512 clients while the
    paged run itself goes to ``n``).

    Uses the deliberately tiny ``tiny_mlp`` backbone (a row is ~5 KB) so
    thousands of clients cycle through the store in CI seconds; what the
    bench measures is the paging machinery, not the matmuls.  The JSON
    artifact records wall time per round plus the pager counters —
    faulted-rows/round, prefetch hit rate, and the background prefetch
    overlap the async pipeline buys (satellite metrics the README quotes).
    """
    import shutil
    import tempfile

    import numpy as np

    from repro.core import make_program, topology
    from repro.data.dirichlet import dirichlet_partition, stack_client_data
    from repro.data.synthetic import DatasetSpec, make_dataset
    from repro.models.small import tiny_mlp
    from repro.store import PagedRunner, ResidentDriver

    def setting(n_pop):
        spec = DatasetSpec("toy", (32,), 10, margin=3.0)
        train, _ = make_dataset(spec, n_pop * 8, 256, seed=0)
        parts = dirichlet_partition(train["y"], n_pop, alpha=0.3, seed=0)
        cdata = stack_client_data(train, parts, pad_to=16)
        net = tiny_mlp(in_dim=32, n_classes=10)
        algo = make_algo("dfedsgpsm", local_steps=2, batch_size=8)
        topo = TopologyConfig(kind="kout", n_clients=n_pop,
                              k_out=min(k_out, n_pop - 1))
        return make_program(net.loss, net.init, cdata, algo, topo,
                            gossip="dense")

    work = tempfile.mkdtemp(prefix="paged_bench_")
    results: dict = {"n": n, "k_active": k_active, "k_out": k_out,
                     "rounds": rounds}
    try:
        # -- the population-scale paged run --------------------------------
        program = setting(n)
        runner = PagedRunner(program, os.path.join(work, "store"),
                             k_active=k_active, seed=0)
        k_in = topology.active_k_in(program.topo)
        c_max = min(n, k_active * (k_in + 1))
        assert runner.resident_rows == c_max, (
            f"resident bank is {runner.resident_rows} rows, closure bound "
            f"is {c_max}")
        assert runner.resident_rows < n, (
            "paged bank must be smaller than the population")
        assert runner.staging_rows == 2 * c_max
        row_b = runner.store.row_nbytes
        results.update({
            "k_in": k_in, "c_max": c_max,
            "resident_rows": runner.resident_rows,
            "staging_rows": runner.staging_rows,
            "resident_fraction": round(c_max / n, 4),
            "bank_bytes_resident": c_max * row_b,
            "bank_bytes_full": n * row_b,
        })
        runner.run_round()  # compile + first (cold, all-fault) round
        times, max_mass_err = [], 0.0
        for _ in range(rounds):
            t0 = time.perf_counter()
            rec = runner.run_round()
            times.append(1e6 * (time.perf_counter() - t0))
            max_mass_err = max(max_mass_err, rec["w_mass_closure_err"])
        us = statistics.median(times)
        mass = runner.total_mass()
        mass_err = abs(mass - n)
        stats = runner.stats.as_dict()
        runner.close()
        emit("round/paged/us", us,
             f"n={n},k_active={k_active},c_max={c_max},rounds={rounds},"
             "median")
        emit("round/paged/resident_fraction", c_max / n,
             "resident rows / population (buffers scale with this, not n)")
        emit("round/paged/fault_rows", stats["rows_faulted_per_round"],
             "synchronous store reads per round (prefetch misses)")
        emit("round/paged/hit_rate", stats["prefetch_hit_rate"],
             "closure rows served without a synchronous fault")
        emit("round/paged/overlap_s", stats["prefetch_overlap_s"],
             "background load time hidden behind device compute")
        emit("round/paged/mass_err", mass_err,
             f"|sum w - n| over the whole {n}-row store")
        assert max_mass_err < 1e-3, (
            f"closure mass leaked in-round: {max_mass_err}")
        assert mass_err < 1e-3 * n, (
            f"push-sum mass drifted over the store: {mass}")
        results.update({"us_per_round": round(us, 1), "mass": mass,
                        "mass_err": mass_err,
                        "max_round_mass_err": max_mass_err,
                        "stats": {k: (round(v, 6)
                                      if isinstance(v, float) else v)
                                  for k, v in stats.items()}})

        # -- paged == resident equivalence (twin-feasible size) ------------
        n_twin, k_twin, r_twin = 512, 64, 3
        program_t = setting(n_twin)
        paged = PagedRunner(program_t, os.path.join(work, "twin_store"),
                            k_active=k_twin, seed=7)
        twin = ResidentDriver(program_t, k_active=k_twin, seed=7)
        loss_err = 0.0
        for _ in range(r_twin):
            mp, mt = paged.run_round(), twin.run_round()
            loss_err = max(loss_err, abs(mp["loss"] - mt["loss"]))
        rows = paged.read_rows(np.arange(n_twin))
        row_err = float(np.abs(rows["params"]
                               - np.asarray(twin.state.params)).max())
        w_err = float(np.abs(rows["w"] - np.asarray(twin.state.w)).max())
        # Checksum-verify everything the twin run committed: every
        # materialized chunk re-reads clean against its recorded CRC.
        paged.save()
        verify = paged.store.verify_chunks()
        results["verify"] = verify
        emit("round/paged/verified_chunks", verify["verified"],
             f"chunks+blobs re-read clean against {verify['bytes']} "
             "recorded-checksum bytes")
        paged.close()
        equiv_ok = loss_err < 1e-4 and row_err < 5e-4 and w_err < 1e-4
        emit("round/paged/equiv_row_err", row_err,
             f"max |paged - resident| over all {n_twin} rows, "
             f"{r_twin} rounds")
        results["equivalence"] = {
            "n": n_twin, "k_active": k_twin, "rounds": r_twin,
            "loss_err": loss_err, "row_err": row_err, "w_err": w_err,
            "ok": bool(equiv_ok),
        }
        assert equiv_ok, (
            f"paged diverged from the fully-resident reference: "
            f"{results['equivalence']}")
    finally:
        shutil.rmtree(work, ignore_errors=True)
    if json_out:
        with open(json_out, "w") as f:
            json.dump({"paged": results}, f, indent=1)
        print(f"# wrote paged-population results -> {json_out}")
    return results


# ---------------------------------------------------------------------------
# Chaos harness (--chaos): churn + injected store faults, exact recovery.
# ---------------------------------------------------------------------------

def chaos_bench(n: int = 4096, k_active: int = 256, k_out: int = 4,
                rounds: int = 24, segment: int = 6, smoke: bool = False,
                json_out: str | None = None) -> dict:
    """Train the paged population under a seeded fault schedule — node
    churn (transient + permanent failures, cold resurrection) composed
    with chaos-injected store IO (transient EIO, slow reads, torn writes,
    post-write bit flips) — and prove the robustness contracts end to end:

    1. **Exact mass accounting**: live + frozen-dead push-sum mass over
       the whole store equals n at the end, to float tolerance.
    2. **Corruption is never silently consumed**: every chunk is verified
       against its recorded checksum before each commit; a flipped bit
       either never reaches a read (superseded generation) or raises
       ``StoreCorruptionError``, upon which the harness rolls back to the
       last committed round and replays — the deterministic round/churn
       key chains make the replay reproduce the identical trajectory.
       A targeted post-run probe corrupts a committed dirty chunk and
       asserts the read raises rather than returning flipped rows.
    3. **Convergence no worse than clean**: a clean twin (same seed, same
       churn, no faults) runs the same number of rounds; the chaos run's
       final loss must match it (rollback + replay means the *committed*
       trajectory is the clean trajectory).

    ``segment`` is the commit cadence (rounds per ``save()``); ``smoke``
    shrinks the population for the CI job.
    """
    import shutil
    import tempfile

    import numpy as np

    from repro.core import make_program, topology
    from repro.data.dirichlet import dirichlet_partition, stack_client_data
    from repro.data.synthetic import DatasetSpec, make_dataset
    from repro.models.small import tiny_mlp
    from repro.store import (
        FaultInjector,
        PagedRunner,
        StoreCorruptionError,
        StoreIOError,
    )

    if smoke:
        n, k_active, rounds, segment = 512, 64, 10, 3

    def setting(n_pop):
        spec = DatasetSpec("toy", (32,), 10, margin=3.0)
        train, _ = make_dataset(spec, n_pop * 8, 256, seed=0)
        parts = dirichlet_partition(train["y"], n_pop, alpha=0.3, seed=0)
        cdata = stack_client_data(train, parts, pad_to=16)
        net = tiny_mlp(in_dim=32, n_classes=10)
        algo = make_algo("dfedsgpsm", local_steps=2, batch_size=8)
        topo = TopologyConfig(kind="kout", n_clients=n_pop,
                              k_out=min(k_out, n_pop - 1))
        return make_program(net.loss, net.init, cdata, algo, topo,
                            gossip="dense")

    churn = topology.ChurnModel(fail_prob=0.05, recover_prob=0.25,
                                permanent_frac=0.1, resurrect="cold")
    fi = FaultInjector(seed=1234, eio_prob=0.05, slow_prob=0.05,
                       slow_seconds=0.001, torn_write_prob=0.05,
                       corrupt_prob=0.02)
    work = tempfile.mkdtemp(prefix="chaos_bench_")
    results: dict = {"n": n, "k_active": k_active, "k_out": k_out,
                     "rounds": rounds, "segment": segment,
                     "churn": {"fail": churn.fail_prob,
                               "recover": churn.recover_prob,
                               "permanent": churn.permanent_frac,
                               "resurrect": churn.resurrect},
                     "faults": {"eio": fi.eio_prob, "slow": fi.slow_prob,
                                "torn": fi.torn_write_prob,
                                "corrupt": fi.corrupt_prob}}
    try:
        program = setting(n)
        store_dir = os.path.join(work, "store")
        runner = PagedRunner(program, store_dir, k_active=k_active,
                             seed=0, rows_per_chunk=64, churn=churn,
                             faults=fi)
        recoveries = 0
        max_recoveries = 8 * (rounds // segment + 1)
        last_rec = None
        t0 = time.perf_counter()
        while runner.round_index < rounds:
            try:
                last_rec = runner.run_round()
                due = (runner.round_index % segment == 0
                       or runner.round_index >= rounds)
                if due:
                    runner.flush()
                    # Verify BEFORE committing: a commit must never
                    # publish a checksum-failing chunk as durable truth.
                    runner.store.verify_chunks()
                    for attempt in range(5):
                        runner.save()
                        try:
                            # Post-commit verify covers the commit's OWN
                            # writes (liveness blob + sealed manifest); a
                            # bit flip there is healed by re-committing
                            # fresh generations, not by rollback.
                            runner.store.verify_chunks()
                            break
                        except StoreCorruptionError:
                            if attempt == 4:
                                raise
            except (StoreCorruptionError, StoreIOError) as e:
                recoveries += 1
                if recoveries > max_recoveries:
                    raise RuntimeError(
                        f"chaos run could not make progress after "
                        f"{recoveries} recoveries; last: {e}"
                    ) from e
                print(f"# recovery {recoveries}: {type(e).__name__} at "
                      f"round {runner.round_index} -> rollback + replay")
                for attempt in range(3):
                    try:
                        runner.restore()
                        break
                    except (StoreCorruptionError, StoreIOError):
                        if attempt == 2:
                            raise
        wall_s = time.perf_counter() - t0
        mass = runner.total_mass()
        mass_err = abs(mass - n)
        final_verify = runner.store.verify_chunks()
        stats = runner.stats.as_dict()
        live_frac = float(last_rec.get("live_frac", 1.0))

        # Targeted probe: flip one bit of a committed dirty chunk and
        # prove the corruption is DETECTED, never consumed.
        ent = next(
            (e for e in runner.store._chunks.values()
             if e["dirty"] and e["crc"] is not None), None
        )
        probe_ok = False
        if ent is not None:
            p = os.path.join(runner.store.path, ent["file"])
            with open(p, "r+b") as f:
                f.seek(20)
                b = f.read(1)
                f.seek(20)
                f.write(bytes([b[0] ^ 1]))
            start = next(s for s, e in runner.store._chunks.items()
                         if e is ent)
            try:
                runner.store.read_rows([start])
            except StoreCorruptionError:
                probe_ok = True
        runner.close()
        assert probe_ok, (
            "a committed dirty chunk with flipped bits was read without "
            "raising StoreCorruptionError"
        )

        # Clean twin: same seed + churn schedule, zero injected faults.
        clean = PagedRunner(setting(n), os.path.join(work, "clean"),
                            k_active=k_active, seed=0, rows_per_chunk=64,
                            churn=churn)
        clean_rec = None
        while clean.round_index < rounds:
            clean_rec = clean.run_round()
        clean_mass = clean.total_mass()
        clean.close()

        loss_gap = abs(last_rec["loss"] - clean_rec["loss"])
        results.update({
            "wall_s": round(wall_s, 2),
            "recoveries": recoveries,
            "faults_injected": fi.faults_injected,
            "files_corrupted": len(fi.corrupted),
            "mass": mass, "mass_err": mass_err,
            "clean_mass": clean_mass,
            "live_frac": live_frac,
            "verify": final_verify,
            "loss_chaos": last_rec["loss"],
            "loss_clean": clean_rec["loss"],
            "loss_gap": loss_gap,
            "stats": {k: (round(v, 6) if isinstance(v, float) else v)
                      for k, v in stats.items()},
        })
        emit("chaos/mass_err", mass_err,
             f"|sum w - n| over the whole {n}-row store, churn included")
        emit("chaos/recoveries", recoveries,
             f"rollback+replay recoveries over {rounds} rounds "
             f"({fi.faults_injected} faults, {len(fi.corrupted)} files "
             "bit-flipped)")
        emit("chaos/io_retries", stats["io_retries"],
             f"transient faults absorbed "
             f"({stats['backoff_seconds']:.3f}s total backoff)")
        emit("chaos/loss_gap", loss_gap,
             "|chaos final loss - clean twin final loss| (rollback+replay "
             "must reproduce the clean trajectory)")
        assert mass_err < 1e-3 * max(n / 64, 1), (
            f"chaos run leaked push-sum mass: sum w = {mass}, n = {n}")
        assert abs(clean_mass - n) < 1e-3 * max(n / 64, 1)
        assert loss_gap < 1e-3, (
            f"chaos run converged worse than the clean twin: "
            f"{last_rec['loss']} vs {clean_rec['loss']}")
    finally:
        shutil.rmtree(work, ignore_errors=True)
    if json_out:
        with open(json_out, "w") as f:
            json.dump({"chaos": results}, f, indent=1)
        print(f"# wrote chaos results -> {json_out}")
    print(f"# chaos: {rounds} rounds, {recoveries} recoveries, "
          f"{fi.faults_injected} faults injected, mass_err={mass_err:.2e}, "
          f"loss_gap={loss_gap:.2e} -> OK")
    return results


def _smoke_speedups() -> dict:
    """Both gate ratios for the flagship algorithm at the recorded sizes:
    ``speedup`` = pytree_us/flat_us (the flat bank must not regress) and
    ``scan_speedup`` = loop_us/scanned_us (the superstep driver must not be
    slower than the per-round Python loop)."""
    net, cdata, _ = build_setting(
        dataset="mnist", n_clients=N_CLIENTS, samples_per_client=128)
    topo = TopologyConfig(
        kind="kout", n_clients=N_CLIENTS, k_out=max(N_CLIENTS // 4, 1))
    algo = make_algo("dfedsgpsm", local_steps=3, batch_size=32)
    timings = {}
    for path in ("flat", "pytree"):
        tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                       participation=0.25, flat=(path == "flat"))
        timings[path] = _time_rounds(tr, 8)
        emit(f"round/smoke/{path}", timings[path], "n=16,rounds=8,median")
    tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                   participation=0.25)
    loop_us = _time_loop(tr, 8)
    scan_us = _time_scanned(tr, 8)
    emit("round/smoke/loop", loop_us, "n=16,rounds=8,median")
    emit("round/smoke/scanned", scan_us, "n=16,rounds=8,median,one-jit")
    # Low-rank delta bank (rank-8 adapters on the frozen base) vs the dense
    # full-width bank: gossip / EF / paging all move d_delta-wide rows.
    tr_delta = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                         participation=0.25, delta=8)
    delta_us = _time_rounds(tr_delta, 8)
    d_delta = tr_delta.spec.dim
    d_full = tr_delta.spec.delta.full.dim
    emit("round/smoke/delta", delta_us,
         f"n=16,rounds=8,median,rank=8,d_delta={d_delta}"
         f"({100 * d_delta / d_full:.1f}% of D)")
    return {"speedup": timings["pytree"] / timings["flat"],
            "scan_speedup": loop_us / scan_us,
            "delta_speedup": timings["flat"] / delta_us}


def smoke(record: bool = False, json_out: str | None = None) -> int:
    """CI gate: compare the flat path's pytree-relative speedup AND the
    scanned superstep driver's loop-relative speedup against the recorded
    baselines.  Absolute round times vary wildly across runners; ratios of
    two paths measured back-to-back on the same box do not — and each
    ratio is a median-of-k with explicit warmup, so a single scheduler
    hiccup can no longer define the measurement.  A >SMOKE_TOLERANCE drop
    of either median means the path itself regressed.  ``record`` rewrites
    the baseline instead (run on a quiet machine; repeated --record runs
    keep the minimum, widening the gate floor); ``json_out`` additionally
    writes the measured ratios + verdicts as JSON (uploaded as a CI
    artifact)."""
    measured = _smoke_speedups()
    emit("round/smoke/speedup", measured["speedup"], "pytree_us/flat_us")
    emit("round/smoke/scan_speedup", measured["scan_speedup"],
         "loop_us/scanned_us")
    emit("round/smoke/delta_speedup", measured["delta_speedup"],
         "dense_flat_us/delta_us (rank-8 delta-bank round vs full-width)")
    if record:
        # Keep the MINIMUM of this and any previously recorded ratio —
        # the gate floor must clear runner noise; repeat --record to widen.
        note = ("pytree_us/flat_us + loop_us/scanned_us + "
                "dense_flat_us/delta_us, each a median-of-8 rounds after "
                "%d warmup rounds; min over recorded runs - repeat "
                "--record to widen" % WARMUP)
        recorded = dict(measured)
        extra = {}
        if os.path.exists(BASELINE):
            with open(BASELINE) as f:
                prev = json.load(f)
            for key in recorded:
                recorded[key] = min(recorded[key],
                                    prev.get(key, recorded[key]))
            extra = {k: prev[k] for k in ("scaling", "scaling_note")
                     if k in prev}
        with open(BASELINE, "w") as f:
            json.dump({"algo": "dfedsgpsm", "n_clients": N_CLIENTS,
                       **{k: round(v, 4) for k, v in recorded.items()},
                       "tolerance": SMOKE_TOLERANCE, "note": note, **extra},
                      f, indent=1)
        print(f"# recorded baseline {recorded} -> {BASELINE}")
        if json_out:
            _write_smoke_json(json_out, measured, recorded, {})
        return 0
    with open(BASELINE) as f:
        base = json.load(f)
    verdicts = {}
    ok = True
    for key, label in (("speedup", "flat-path"),
                       ("scan_speedup", "scanned-driver"),
                       ("delta_speedup", "delta-bank")):
        # Baselines recorded before a gate existed fall back to parity.
        floor = base.get(key, 1.0) / SMOKE_TOLERANCE
        verdicts[key] = "OK" if measured[key] >= floor else "REGRESSION"
        ok = ok and measured[key] >= floor
        print(f"# {label} gate: {key}={measured[key]:.3f} "
              f"baseline={base.get(key, 1.0):.3f} floor={floor:.3f} "
              f"-> {verdicts[key]}")
    if json_out:
        _write_smoke_json(json_out, measured, base, verdicts)
    return 0 if ok else 1


def _write_smoke_json(path: str, measured: dict, baseline: dict,
                      verdicts: dict):
    with open(path, "w") as f:
        json.dump({"measured": {k: round(v, 4) for k, v in measured.items()},
                   "baseline": {k: round(float(v), 4)
                                for k, v in baseline.items()
                                if isinstance(v, (int, float))},
                   "tolerance": SMOKE_TOLERANCE, "verdicts": verdicts},
                  f, indent=1)
    print(f"# wrote smoke results -> {path}")


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="regression gate vs round_baseline.json (exit 1 on "
                         ">%.1fx flat-path OR scanned-driver slowdown)"
                         % SMOKE_TOLERANCE)
    ap.add_argument("--record", action="store_true",
                    help="re-record the baseline (smoke ratios, or the "
                         "scaling table when --n-clients is given) instead "
                         "of gating")
    ap.add_argument("--link-drop", type=float, default=None, metavar="P",
                    help="degraded-link scenario: time the round with "
                         "per-edge drop probability P (vs perfect links) "
                         "and assert exact push-sum mass conservation")
    ap.add_argument("--link-delay", type=int, default=0,
                    help="staleness bound B for the --link-drop scenario")
    ap.add_argument("--event-threshold", type=float, default=0.0,
                    help="event-trigger threshold for the --link-drop "
                         "scenario (0 = always transmit)")
    ap.add_argument("--shard", action="store_true",
                    help="GSPMD row-sharded round over 8 forced host "
                         "devices: equivalence + mass invariants and "
                         "single-vs-sharded round times at --n-clients "
                         "(default 512); writes --json as bench-shard.json")
    ap.add_argument("--n-pods", type=int, default=8,
                    help="pod count for the two-tier family in --shard")
    ap.add_argument("--paged", action="store_true",
                    help="virtual-client-population bench: run the "
                         "disk-backed paged trainer at --n-clients "
                         "(default 4096) with --k-active sampled clients, "
                         "assert closure-proportional buffers + exact mass "
                         "+ paged==resident equivalence; writes --json as "
                         "bench-paged.json")
    ap.add_argument("--k-active", type=int, default=256,
                    help="sampled clients per round for --paged / --chaos")
    ap.add_argument("--chaos", action="store_true",
                    help="chaos harness: paged training under client churn "
                         "+ injected store faults (transient EIO, torn "
                         "writes, bit flips); asserts exact mass, "
                         "corruption-never-consumed, and convergence equal "
                         "to a clean twin. Compose with --smoke for the "
                         "reduced CI sizing; writes --json as "
                         "bench-chaos.json")
    ap.add_argument("--n-clients", default=None, metavar="N[,N...]",
                    help="sparse-vs-dense gossip scaling sweep over these "
                         "client counts (e.g. 16,64,256) at fixed --k-out; "
                         "with --shard, the single sharded client count")
    ap.add_argument("--k-out", type=int, default=10,
                    help="out-degree for the --n-clients sweep (paper "
                         "setting: 10); clipped to n-1 per point")
    ap.add_argument("--rounds", type=int, default=5,
                    help="timed rounds per --n-clients point (median)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the smoke ratios + verdicts (or the "
                         "scaling table) as JSON (CI uploads this as an "
                         "artifact)")
    ap.add_argument("--fast", action="store_true",
                    help="fewer timing rounds for the full benchmark")
    args = ap.parse_args()
    if args.chaos:
        chaos_bench(smoke=args.smoke, json_out=args.json)
        sys.exit(0)
    if args.paged:
        n = int(args.n_clients.split(",")[0]) if args.n_clients else 4096
        paged_bench(n=n, k_active=args.k_active, rounds=args.rounds,
                    json_out=args.json)
        sys.exit(0)
    if args.shard:
        n = int(args.n_clients.split(",")[0]) if args.n_clients else 512
        shard_bench(n, k_out=args.k_out, n_pods=args.n_pods,
                    rounds=args.rounds, json_out=args.json)
        sys.exit(0)
    if args.link_drop is not None:
        degraded(args.link_drop, delay=args.link_delay,
                 event_threshold=args.event_threshold,
                 rounds=args.rounds, json_out=args.json)
        sys.exit(0)
    if args.n_clients:
        ns = [int(x) for x in args.n_clients.split(",") if x]
        scaling(ns, k_out=args.k_out, rounds=args.rounds,
                record=args.record, json_out=args.json)
        sys.exit(0)
    if args.smoke or args.record:
        sys.exit(smoke(record=args.record, json_out=args.json))
    main(fast=args.fast)
