"""End-to-end round timing: flat (n, D) bank path vs the seed pytree path.

The flat path runs the whole round through the Pallas kernels — one
``gossip_matmul`` for the entire model and one ``fused_update`` per inner
step — versus the seed's per-leaf einsum + three tree-mapped elementwise
passes.  Benchmarks the paper's 16-client setting for the flagship
DFedSGPSM and the DFedSAM baseline (Algorithm 1 with/without push-sum);
their two-pass SAM gradients are the paper's hot path and amortize the
bank <-> pytree boundary.  Emits min-of-N round times (robust to container
scheduling noise) via ``common.emit``.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import build_setting, emit
from repro.core import FLTrainer, TopologyConfig, make_algo

N_CLIENTS = 16


def _time_rounds(tr: FLTrainer, rounds: int) -> float:
    """Best (min) microseconds per round after a compile+warmup round."""
    tr.run_round()
    jax.block_until_ready(tr.state.params)
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        tr.run_round()
        jax.block_until_ready(tr.state.params)
        best = min(best, 1e6 * (time.perf_counter() - t0))
    return best


def main(fast: bool = False):
    rounds = 8 if fast else 20
    net, cdata, _ = build_setting(
        dataset="mnist", n_clients=N_CLIENTS, samples_per_client=128)
    topo = TopologyConfig(
        kind="kout", n_clients=N_CLIENTS, k_out=max(N_CLIENTS // 4, 1))

    for name in ("dfedsgpsm", "dfedsam"):
        algo = make_algo(name, local_steps=3, batch_size=32)
        timings = {}
        for path in ("flat", "pytree"):
            tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                           participation=0.25, flat=(path == "flat"))
            timings[path] = _time_rounds(tr, rounds)
            d = tr.spec.dim
            emit(f"round/{name}/{path}", timings[path],
                 f"n={N_CLIENTS},D={d},rounds={rounds},min")
        emit(f"round/{name}/speedup", timings["pytree"] / timings["flat"],
             "pytree_us/flat_us (>=1 means flat is no slower)")


if __name__ == "__main__":
    main()
