"""End-to-end round timing: flat (n, D) bank path vs the seed pytree path,
and the jit-resident scanned superstep driver vs the per-round Python loop.

The flat path runs the whole round through the Pallas kernels — one
``gossip_matmul`` for the entire model and one ``fused_update`` per inner
step — versus the seed's per-leaf einsum + three tree-mapped elementwise
passes.  Benchmarks the paper's 16-client setting for the flagship
DFedSGPSM and the DFedSAM baseline (Algorithm 1 with/without push-sum);
their two-pass SAM gradients are the paper's hot path and amortize the
bank <-> pytree boundary.  The scanned comparison times
``program.run_superstep`` (all rounds in ONE dispatch, donated carry)
against the same number of per-round jit dispatches.  Emits min-of-N round
times (robust to container scheduling noise) via ``common.emit``.
"""
from __future__ import annotations

import json
import os
import time

import jax

from benchmarks.common import build_setting, emit
from repro.core import FLTrainer, TopologyConfig, make_algo

N_CLIENTS = 16

# CI regression gate: the flat path must not lose more than this factor of
# its recorded pytree-relative speedup, and the scanned superstep driver no
# more than this factor of its recorded loop-relative speedup (machine
# speed cancels in both ratios).
SMOKE_TOLERANCE = 1.3
BASELINE = os.path.join(os.path.dirname(__file__), "round_baseline.json")


def _time_rounds(tr: FLTrainer, rounds: int) -> float:
    """Best (min) microseconds per round after a compile+warmup round."""
    tr.run_round()
    jax.block_until_ready(tr.state.params)
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        tr.run_round()
        jax.block_until_ready(tr.state.params)
        best = min(best, 1e6 * (time.perf_counter() - t0))
    return best


def _time_loop(tr: FLTrainer, rounds: int, repeats: int = 3) -> float:
    """Best us/round over ``repeats`` timed windows of ``rounds`` per-round
    jit dispatches — the Python-loop driver's amortized cost."""
    tr.run_round()
    jax.block_until_ready(tr.state.params)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(rounds):
            tr.run_round()
        jax.block_until_ready(tr.state.params)
        best = min(best, 1e6 * (time.perf_counter() - t0) / rounds)
    return best


def _time_scanned(tr: FLTrainer, rounds: int, repeats: int = 3) -> float:
    """Best us/round for ``program.run_superstep`` — the whole window of
    rounds is one ``lax.scan`` inside one jit with donated carry."""
    program = tr.program
    state = program.init(jax.random.PRNGKey(0))
    state, _ = program.run_superstep(state, rounds)  # compile + warmup
    jax.block_until_ready(state.params)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        state, _ = program.run_superstep(state, rounds)
        jax.block_until_ready(state.params)
        best = min(best, 1e6 * (time.perf_counter() - t0) / rounds)
    return best


def main(fast: bool = False):
    rounds = 8 if fast else 20
    net, cdata, _ = build_setting(
        dataset="mnist", n_clients=N_CLIENTS, samples_per_client=128)
    topo = TopologyConfig(
        kind="kout", n_clients=N_CLIENTS, k_out=max(N_CLIENTS // 4, 1))

    for name in ("dfedsgpsm", "dfedsam"):
        algo = make_algo(name, local_steps=3, batch_size=32)
        timings = {}
        for path in ("flat", "pytree"):
            tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                           participation=0.25, flat=(path == "flat"))
            timings[path] = _time_rounds(tr, rounds)
            d = tr.spec.dim
            emit(f"round/{name}/{path}", timings[path],
                 f"n={N_CLIENTS},D={d},rounds={rounds},min")
        emit(f"round/{name}/speedup", timings["pytree"] / timings["flat"],
             "pytree_us/flat_us (>=1 means flat is no slower)")

    # Scanned superstep driver vs the per-round Python loop (flagship algo).
    algo = make_algo("dfedsgpsm", local_steps=3, batch_size=32)
    tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                   participation=0.25)
    loop_us = _time_loop(tr, rounds)
    scan_us = _time_scanned(tr, rounds)
    emit("round/dfedsgpsm/loop", loop_us, f"n={N_CLIENTS},rounds={rounds},min")
    emit("round/dfedsgpsm/scanned", scan_us,
         f"n={N_CLIENTS},rounds={rounds},min,one-jit")
    emit("round/dfedsgpsm/scan_speedup", loop_us / scan_us,
         "loop_us/scanned_us (>=1 means the superstep driver is no slower)")


def _smoke_speedups() -> dict:
    """Both gate ratios for the flagship algorithm at the recorded sizes:
    ``speedup`` = pytree_us/flat_us (the flat bank must not regress) and
    ``scan_speedup`` = loop_us/scanned_us (the superstep driver must not be
    slower than the per-round Python loop)."""
    net, cdata, _ = build_setting(
        dataset="mnist", n_clients=N_CLIENTS, samples_per_client=128)
    topo = TopologyConfig(
        kind="kout", n_clients=N_CLIENTS, k_out=max(N_CLIENTS // 4, 1))
    algo = make_algo("dfedsgpsm", local_steps=3, batch_size=32)
    timings = {}
    for path in ("flat", "pytree"):
        tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                       participation=0.25, flat=(path == "flat"))
        timings[path] = _time_rounds(tr, 8)
        emit(f"round/smoke/{path}", timings[path], "n=16,rounds=8,min")
    tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                   participation=0.25)
    loop_us = _time_loop(tr, 8)
    scan_us = _time_scanned(tr, 8)
    emit("round/smoke/loop", loop_us, "n=16,rounds=8,min")
    emit("round/smoke/scanned", scan_us, "n=16,rounds=8,min,one-jit")
    return {"speedup": timings["pytree"] / timings["flat"],
            "scan_speedup": loop_us / scan_us}


def smoke(record: bool = False, json_out: str | None = None) -> int:
    """CI gate: compare the flat path's pytree-relative speedup AND the
    scanned superstep driver's loop-relative speedup against the recorded
    baselines.  Absolute round times vary wildly across runners; ratios of
    two paths measured back-to-back on the same box do not, so a
    >SMOKE_TOLERANCE drop means the path itself regressed.  ``record``
    rewrites the baseline instead (run on a quiet machine); ``json_out``
    additionally writes the measured ratios + verdicts as JSON (uploaded as
    a CI artifact)."""
    measured = _smoke_speedups()
    emit("round/smoke/speedup", measured["speedup"], "pytree_us/flat_us")
    emit("round/smoke/scan_speedup", measured["scan_speedup"],
         "loop_us/scanned_us")
    if record:
        # Record the MINIMUM of this and any previously recorded ratio —
        # the gate floor must clear runner noise, and a single quiet-box
        # run would otherwise tighten it to the point of flaking.
        note = ("pytree_us/flat_us + loop_us/scanned_us, min over recorded "
                "runs; each gate floor is ratio/tolerance - repeat --record "
                "to widen")
        recorded = dict(measured)
        if os.path.exists(BASELINE):
            with open(BASELINE) as f:
                prev = json.load(f)
            for key in recorded:
                recorded[key] = min(recorded[key],
                                    prev.get(key, recorded[key]))
            note = prev.get("note", note)
        with open(BASELINE, "w") as f:
            json.dump({"algo": "dfedsgpsm", "n_clients": N_CLIENTS,
                       **{k: round(v, 4) for k, v in recorded.items()},
                       "tolerance": SMOKE_TOLERANCE, "note": note},
                      f, indent=1)
        print(f"# recorded baseline {recorded} -> {BASELINE}")
        if json_out:
            _write_smoke_json(json_out, measured, recorded, {})
        return 0
    with open(BASELINE) as f:
        base = json.load(f)
    verdicts = {}
    ok = True
    for key, label in (("speedup", "flat-path"),
                       ("scan_speedup", "scanned-driver")):
        # Baselines recorded before a gate existed fall back to parity.
        floor = base.get(key, 1.0) / SMOKE_TOLERANCE
        verdicts[key] = "OK" if measured[key] >= floor else "REGRESSION"
        ok = ok and measured[key] >= floor
        print(f"# {label} gate: {key}={measured[key]:.3f} "
              f"baseline={base.get(key, 1.0):.3f} floor={floor:.3f} "
              f"-> {verdicts[key]}")
    if json_out:
        _write_smoke_json(json_out, measured, base, verdicts)
    return 0 if ok else 1


def _write_smoke_json(path: str, measured: dict, baseline: dict,
                      verdicts: dict):
    with open(path, "w") as f:
        json.dump({"measured": {k: round(v, 4) for k, v in measured.items()},
                   "baseline": {k: round(float(v), 4)
                                for k, v in baseline.items()
                                if isinstance(v, (int, float))},
                   "tolerance": SMOKE_TOLERANCE, "verdicts": verdicts},
                  f, indent=1)
    print(f"# wrote smoke results -> {path}")


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="regression gate vs round_baseline.json (exit 1 on "
                         ">%.1fx flat-path OR scanned-driver slowdown)"
                         % SMOKE_TOLERANCE)
    ap.add_argument("--record", action="store_true",
                    help="re-record the baseline instead of gating")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the smoke ratios + verdicts as JSON "
                         "(CI uploads this as an artifact)")
    ap.add_argument("--fast", action="store_true",
                    help="fewer timing rounds for the full benchmark")
    args = ap.parse_args()
    if args.smoke or args.record:
        sys.exit(smoke(record=args.record, json_out=args.json))
    main(fast=args.fast)
