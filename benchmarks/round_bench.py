"""End-to-end round timing: flat (n, D) bank path vs the seed pytree path,
the jit-resident scanned superstep driver vs the per-round Python loop, and
sparse neighbor-list gossip vs the dense mixing matmul across client counts.

The flat path runs the whole round through the Pallas kernels — one
``gossip_matmul`` for the entire model and one ``fused_update`` per inner
step — versus the seed's per-leaf einsum + three tree-mapped elementwise
passes.  Benchmarks the paper's 16-client setting for the flagship
DFedSGPSM and the DFedSAM baseline (Algorithm 1 with/without push-sum);
their two-pass SAM gradients are the paper's hot path and amortize the
bank <-> pytree boundary.  The scanned comparison times
``program.run_superstep`` (all rounds in ONE dispatch, donated carry)
against the same number of per-round jit dispatches.  The ``--n-clients``
sweep scales the round from 16 to hundreds of clients at fixed ``k_out``
and times the O(n * k_max * D) neighbor-gather gossip against the
O(n^2 * D) dense matmul (gossip-dominated SGP config, K=1).  All timings
are median-of-k after explicit warmup (robust to container scheduling
noise) via ``common.emit``.
"""
from __future__ import annotations

import json
import os
import statistics
import time

import jax

from benchmarks.common import build_setting, emit
from repro.core import FLTrainer, TopologyConfig, make_algo

N_CLIENTS = 16

# CI regression gate: the flat path must not lose more than this factor of
# its recorded pytree-relative speedup, and the scanned superstep driver no
# more than this factor of its recorded loop-relative speedup (machine
# speed cancels in both ratios).
SMOKE_TOLERANCE = 1.3
# Explicit warmup runs (beyond the compile call) before any timed window.
WARMUP = 2
BASELINE = os.path.join(os.path.dirname(__file__), "round_baseline.json")


def _time_rounds(tr: FLTrainer, rounds: int, warmup: int = WARMUP) -> float:
    """Median microseconds per round after compile + ``warmup`` rounds."""
    for _ in range(1 + warmup):  # compile, then populate caches/allocator
        tr.run_round()
    jax.block_until_ready(tr.state.params)
    times = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        tr.run_round()
        jax.block_until_ready(tr.state.params)
        times.append(1e6 * (time.perf_counter() - t0))
    return statistics.median(times)


def _time_loop(tr: FLTrainer, rounds: int, repeats: int = 5,
               warmup: int = WARMUP) -> float:
    """Median us/round over ``repeats`` timed windows of ``rounds``
    per-round jit dispatches — the Python-loop driver's amortized cost."""
    for _ in range(1 + warmup):
        tr.run_round()
    jax.block_until_ready(tr.state.params)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(rounds):
            tr.run_round()
        jax.block_until_ready(tr.state.params)
        times.append(1e6 * (time.perf_counter() - t0) / rounds)
    return statistics.median(times)


def _time_scanned(tr: FLTrainer, rounds: int, repeats: int = 5,
                  warmup: int = WARMUP) -> float:
    """Median us/round for ``program.run_superstep`` — the whole window of
    rounds is one ``lax.scan`` inside one jit with donated carry."""
    program = tr.program
    state = program.init(jax.random.PRNGKey(0))
    for _ in range(1 + warmup):  # compile + warmup supersteps
        state, _ = program.run_superstep(state, rounds)
    jax.block_until_ready(state.params)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        state, _ = program.run_superstep(state, rounds)
        jax.block_until_ready(state.params)
        times.append(1e6 * (time.perf_counter() - t0) / rounds)
    return statistics.median(times)


def main(fast: bool = False):
    rounds = 8 if fast else 20
    net, cdata, _ = build_setting(
        dataset="mnist", n_clients=N_CLIENTS, samples_per_client=128)
    topo = TopologyConfig(
        kind="kout", n_clients=N_CLIENTS, k_out=max(N_CLIENTS // 4, 1))

    for name in ("dfedsgpsm", "dfedsam"):
        algo = make_algo(name, local_steps=3, batch_size=32)
        timings = {}
        for path in ("flat", "pytree"):
            tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                           participation=0.25, flat=(path == "flat"))
            timings[path] = _time_rounds(tr, rounds)
            d = tr.spec.dim
            emit(f"round/{name}/{path}", timings[path],
                 f"n={N_CLIENTS},D={d},rounds={rounds},median")
        emit(f"round/{name}/speedup", timings["pytree"] / timings["flat"],
             "pytree_us/flat_us (>=1 means flat is no slower)")

    # Scanned superstep driver vs the per-round Python loop (flagship algo).
    algo = make_algo("dfedsgpsm", local_steps=3, batch_size=32)
    tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                   participation=0.25)
    loop_us = _time_loop(tr, rounds)
    scan_us = _time_scanned(tr, rounds)
    emit("round/dfedsgpsm/loop", loop_us,
         f"n={N_CLIENTS},rounds={rounds},median")
    emit("round/dfedsgpsm/scanned", scan_us,
         f"n={N_CLIENTS},rounds={rounds},median,one-jit")
    emit("round/dfedsgpsm/scan_speedup", loop_us / scan_us,
         "loop_us/scanned_us (>=1 means the superstep driver is no slower)")


# ---------------------------------------------------------------------------
# Degraded-link scenario (--link-drop [--link-delay/--event-threshold]).
# ---------------------------------------------------------------------------

def degraded(drop: float, delay: int = 0, event_threshold: float = 0.0,
             rounds: int = 5, json_out: str | None = None) -> dict:
    """Time the flagship round under the unreliable-link scenario vs
    perfect links (same seed, same family) and verify the two invariants
    the subsystem is pinned by: the dropped mixing operator stays exactly
    column-stochastic (no mass leak), and total push-sum mass — in-flight
    shares included under delays — equals n every round.  The link model
    costs one drop-mask renormalization per round (plus B+1 sliced mixes
    when delayed), so the overhead ratio is the number to watch.
    """
    from repro.core import LinkModel, make_algo

    net, cdata, _ = build_setting(
        dataset="mnist", n_clients=N_CLIENTS, samples_per_client=128)
    topo = TopologyConfig(
        kind="kout", n_clients=N_CLIENTS, k_out=max(N_CLIENTS // 4, 1))
    algo = make_algo("dfedsgpsm", local_steps=3, batch_size=32)
    link = LinkModel(drop=drop, delay=delay,
                     event_threshold=event_threshold)
    timings, mass_err = {}, 0.0
    for scenario in ("clean", "degraded"):
        tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                       participation=0.25,
                       link=link if scenario == "degraded" else None)
        timings[scenario] = _time_rounds(tr, rounds)
        emit(f"round/link/{scenario}", timings[scenario],
             f"n={N_CLIENTS},drop={drop},delay={delay},rounds={rounds}")
        if scenario == "degraded":
            state, hist = tr.program.run_superstep(tr.state, rounds)
            import numpy as np

            # An all-zero model is the (valid) perfect-link control: the
            # program carries no per-round w_mass metric, so check the
            # final node mass instead.
            mass = (np.asarray(hist["w_mass"]) if "w_mass" in hist
                    else np.asarray(state.w.sum())[None])
            mass_err = float(np.abs(mass - N_CLIENTS).max())
            emit("round/link/mass_err", mass_err,
                 f"max |sum w - n| over {rounds} degraded rounds "
                 "(in-flight mass included)")
            assert mass_err < 1e-3, (
                f"push-sum mass leaked under drops/delays: {mass_err}")
    overhead = timings["degraded"] / timings["clean"]
    emit("round/link/overhead", overhead,
         "degraded_us/clean_us (link-model cost per round)")
    results = {"drop": drop, "delay": delay,
               "event_threshold": event_threshold,
               "clean_us": round(timings["clean"], 1),
               "degraded_us": round(timings["degraded"], 1),
               "overhead": round(overhead, 3),
               "mass_err": mass_err}
    if json_out:
        with open(json_out, "w") as f:
            json.dump({"degraded_link": results}, f, indent=1)
        print(f"# wrote degraded-link results -> {json_out}")
    return results


# ---------------------------------------------------------------------------
# Sparse-vs-dense gossip scaling sweep (--n-clients).
# ---------------------------------------------------------------------------

def scaling(ns: list[int], k_out: int = 10, rounds: int = 5,
            record: bool = False, json_out: str | None = None) -> dict:
    """Time one full round AND the isolated gossip phase per client count
    with the mixing representation forced dense vs sparse (same family,
    same ``k_out``): the paper-scale claim is that the O(n * k_max * D)
    neighbor gather keeps the communication step near-flat in n where the
    O(n^2 * D) matmul grows quadratically.

    Uses the gossip-dominated SGP composition (K=1, batch 1) so the round
    ratio is as close to the communication step as an honest full round
    gets; the ``gossip_*`` columns time one ``mixer.mix`` (bank + push-sum
    weights) on the live bank — the kernel-level number.  ``record``
    merges the table into ``round_baseline.json`` under ``"scaling"``;
    ``json_out`` writes it standalone (the CI artifact).
    """
    from repro.core import topology as topo_mod

    results = {}
    for n in ns:
        net, cdata, _ = build_setting(
            dataset="mnist", n_clients=n, samples_per_client=64)
        k = min(k_out, n - 1)
        topo = TopologyConfig(kind="kout", n_clients=n, k_out=k)
        algo = make_algo("sgp", batch_size=1)  # K=1: gossip-dominated
        t, tg = {}, {}
        for mode in ("dense", "sparse"):
            tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                           participation=0.25, gossip=mode)
            t[mode] = _time_rounds(tr, rounds)
            emit(f"round/scaling/n{n}/{mode}", t[mode],
                 f"k_out={k},D={tr.spec.dim},rounds={rounds},median")
            # Isolated gossip phase: one sampled operator, one mixer.mix
            # (bank + weights) on the trained bank.
            key = jax.random.PRNGKey(7)
            P = (topo_mod.sample_kout_neighbors(key, n, k)
                 if mode == "sparse" else topo_mod.sample_kout(key, n, k))
            mix = jax.jit(tr.program.mixer.mix)
            X, w = tr.state.params, tr.state.w
            out = mix(P, X, w)
            jax.block_until_ready(out[0])
            times = []
            for _ in range(max(rounds, 5)):
                t0 = time.perf_counter()
                out = mix(P, X, w)
                jax.block_until_ready(out[0])
                times.append(1e6 * (time.perf_counter() - t0))
            tg[mode] = statistics.median(times)
            emit(f"gossip/scaling/n{n}/{mode}", tg[mode],
                 f"k_out={k},one mixer.mix,median")
        ratio = t["dense"] / t["sparse"]
        gratio = tg["dense"] / tg["sparse"]
        emit(f"round/scaling/n{n}/speedup", ratio,
             "dense_us/sparse_us (>=1 means sparse gossip wins)")
        emit(f"gossip/scaling/n{n}/speedup", gratio,
             "gossip-phase dense_us/sparse_us")
        results[str(n)] = {"k_out": k,
                           "dense_us": round(t["dense"], 1),
                           "sparse_us": round(t["sparse"], 1),
                           "speedup": round(ratio, 3),
                           "gossip_dense_us": round(tg["dense"], 1),
                           "gossip_sparse_us": round(tg["sparse"], 1),
                           "gossip_speedup": round(gratio, 3)}
    if record:
        base = {}
        if os.path.exists(BASELINE):
            with open(BASELINE) as f:
                base = json.load(f)
        base.setdefault("scaling", {}).update(results)
        base["scaling_note"] = (
            "dense_us/sparse_us per round, median-of-%d after %d warmup "
            "rounds; kout family, sgp (K=1) gossip-dominated config"
            % (rounds, WARMUP))
        with open(BASELINE, "w") as f:
            json.dump(base, f, indent=1)
        print(f"# recorded scaling table -> {BASELINE}")
    if json_out:
        with open(json_out, "w") as f:
            json.dump({"scaling": results}, f, indent=1)
        print(f"# wrote scaling results -> {json_out}")
    return results


def _smoke_speedups() -> dict:
    """Both gate ratios for the flagship algorithm at the recorded sizes:
    ``speedup`` = pytree_us/flat_us (the flat bank must not regress) and
    ``scan_speedup`` = loop_us/scanned_us (the superstep driver must not be
    slower than the per-round Python loop)."""
    net, cdata, _ = build_setting(
        dataset="mnist", n_clients=N_CLIENTS, samples_per_client=128)
    topo = TopologyConfig(
        kind="kout", n_clients=N_CLIENTS, k_out=max(N_CLIENTS // 4, 1))
    algo = make_algo("dfedsgpsm", local_steps=3, batch_size=32)
    timings = {}
    for path in ("flat", "pytree"):
        tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                       participation=0.25, flat=(path == "flat"))
        timings[path] = _time_rounds(tr, 8)
        emit(f"round/smoke/{path}", timings[path], "n=16,rounds=8,median")
    tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                   participation=0.25)
    loop_us = _time_loop(tr, 8)
    scan_us = _time_scanned(tr, 8)
    emit("round/smoke/loop", loop_us, "n=16,rounds=8,median")
    emit("round/smoke/scanned", scan_us, "n=16,rounds=8,median,one-jit")
    return {"speedup": timings["pytree"] / timings["flat"],
            "scan_speedup": loop_us / scan_us}


def smoke(record: bool = False, json_out: str | None = None) -> int:
    """CI gate: compare the flat path's pytree-relative speedup AND the
    scanned superstep driver's loop-relative speedup against the recorded
    baselines.  Absolute round times vary wildly across runners; ratios of
    two paths measured back-to-back on the same box do not — and each
    ratio is a median-of-k with explicit warmup, so a single scheduler
    hiccup can no longer define the measurement.  A >SMOKE_TOLERANCE drop
    of either median means the path itself regressed.  ``record`` rewrites
    the baseline instead (run on a quiet machine; repeated --record runs
    keep the minimum, widening the gate floor); ``json_out`` additionally
    writes the measured ratios + verdicts as JSON (uploaded as a CI
    artifact)."""
    measured = _smoke_speedups()
    emit("round/smoke/speedup", measured["speedup"], "pytree_us/flat_us")
    emit("round/smoke/scan_speedup", measured["scan_speedup"],
         "loop_us/scanned_us")
    if record:
        # Keep the MINIMUM of this and any previously recorded ratio —
        # the gate floor must clear runner noise; repeat --record to widen.
        note = ("pytree_us/flat_us + loop_us/scanned_us, each a "
                "median-of-8 rounds after %d warmup rounds; min over "
                "recorded runs - repeat --record to widen" % WARMUP)
        recorded = dict(measured)
        extra = {}
        if os.path.exists(BASELINE):
            with open(BASELINE) as f:
                prev = json.load(f)
            for key in recorded:
                recorded[key] = min(recorded[key],
                                    prev.get(key, recorded[key]))
            extra = {k: prev[k] for k in ("scaling", "scaling_note")
                     if k in prev}
        with open(BASELINE, "w") as f:
            json.dump({"algo": "dfedsgpsm", "n_clients": N_CLIENTS,
                       **{k: round(v, 4) for k, v in recorded.items()},
                       "tolerance": SMOKE_TOLERANCE, "note": note, **extra},
                      f, indent=1)
        print(f"# recorded baseline {recorded} -> {BASELINE}")
        if json_out:
            _write_smoke_json(json_out, measured, recorded, {})
        return 0
    with open(BASELINE) as f:
        base = json.load(f)
    verdicts = {}
    ok = True
    for key, label in (("speedup", "flat-path"),
                       ("scan_speedup", "scanned-driver")):
        # Baselines recorded before a gate existed fall back to parity.
        floor = base.get(key, 1.0) / SMOKE_TOLERANCE
        verdicts[key] = "OK" if measured[key] >= floor else "REGRESSION"
        ok = ok and measured[key] >= floor
        print(f"# {label} gate: {key}={measured[key]:.3f} "
              f"baseline={base.get(key, 1.0):.3f} floor={floor:.3f} "
              f"-> {verdicts[key]}")
    if json_out:
        _write_smoke_json(json_out, measured, base, verdicts)
    return 0 if ok else 1


def _write_smoke_json(path: str, measured: dict, baseline: dict,
                      verdicts: dict):
    with open(path, "w") as f:
        json.dump({"measured": {k: round(v, 4) for k, v in measured.items()},
                   "baseline": {k: round(float(v), 4)
                                for k, v in baseline.items()
                                if isinstance(v, (int, float))},
                   "tolerance": SMOKE_TOLERANCE, "verdicts": verdicts},
                  f, indent=1)
    print(f"# wrote smoke results -> {path}")


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="regression gate vs round_baseline.json (exit 1 on "
                         ">%.1fx flat-path OR scanned-driver slowdown)"
                         % SMOKE_TOLERANCE)
    ap.add_argument("--record", action="store_true",
                    help="re-record the baseline (smoke ratios, or the "
                         "scaling table when --n-clients is given) instead "
                         "of gating")
    ap.add_argument("--link-drop", type=float, default=None, metavar="P",
                    help="degraded-link scenario: time the round with "
                         "per-edge drop probability P (vs perfect links) "
                         "and assert exact push-sum mass conservation")
    ap.add_argument("--link-delay", type=int, default=0,
                    help="staleness bound B for the --link-drop scenario")
    ap.add_argument("--event-threshold", type=float, default=0.0,
                    help="event-trigger threshold for the --link-drop "
                         "scenario (0 = always transmit)")
    ap.add_argument("--n-clients", default=None, metavar="N[,N...]",
                    help="sparse-vs-dense gossip scaling sweep over these "
                         "client counts (e.g. 16,64,256) at fixed --k-out")
    ap.add_argument("--k-out", type=int, default=10,
                    help="out-degree for the --n-clients sweep (paper "
                         "setting: 10); clipped to n-1 per point")
    ap.add_argument("--rounds", type=int, default=5,
                    help="timed rounds per --n-clients point (median)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the smoke ratios + verdicts (or the "
                         "scaling table) as JSON (CI uploads this as an "
                         "artifact)")
    ap.add_argument("--fast", action="store_true",
                    help="fewer timing rounds for the full benchmark")
    args = ap.parse_args()
    if args.link_drop is not None:
        degraded(args.link_drop, delay=args.link_delay,
                 event_threshold=args.event_threshold,
                 rounds=args.rounds, json_out=args.json)
        sys.exit(0)
    if args.n_clients:
        ns = [int(x) for x in args.n_clients.split(",") if x]
        scaling(ns, k_out=args.k_out, rounds=args.rounds,
                record=args.record, json_out=args.json)
        sys.exit(0)
    if args.smoke or args.record:
        sys.exit(smoke(record=args.record, json_out=args.json))
    main(fast=args.fast)
