"""End-to-end round timing: flat (n, D) bank path vs the seed pytree path.

The flat path runs the whole round through the Pallas kernels — one
``gossip_matmul`` for the entire model and one ``fused_update`` per inner
step — versus the seed's per-leaf einsum + three tree-mapped elementwise
passes.  Benchmarks the paper's 16-client setting for the flagship
DFedSGPSM and the DFedSAM baseline (Algorithm 1 with/without push-sum);
their two-pass SAM gradients are the paper's hot path and amortize the
bank <-> pytree boundary.  Emits min-of-N round times (robust to container
scheduling noise) via ``common.emit``.
"""
from __future__ import annotations

import json
import os
import time

import jax

from benchmarks.common import build_setting, emit
from repro.core import FLTrainer, TopologyConfig, make_algo

N_CLIENTS = 16

# CI regression gate: the flat path must not lose more than this factor of
# its recorded pytree-relative speedup (machine speed cancels in the ratio).
SMOKE_TOLERANCE = 1.3
BASELINE = os.path.join(os.path.dirname(__file__), "round_baseline.json")


def _time_rounds(tr: FLTrainer, rounds: int) -> float:
    """Best (min) microseconds per round after a compile+warmup round."""
    tr.run_round()
    jax.block_until_ready(tr.state.params)
    best = float("inf")
    for _ in range(rounds):
        t0 = time.perf_counter()
        tr.run_round()
        jax.block_until_ready(tr.state.params)
        best = min(best, 1e6 * (time.perf_counter() - t0))
    return best


def main(fast: bool = False):
    rounds = 8 if fast else 20
    net, cdata, _ = build_setting(
        dataset="mnist", n_clients=N_CLIENTS, samples_per_client=128)
    topo = TopologyConfig(
        kind="kout", n_clients=N_CLIENTS, k_out=max(N_CLIENTS // 4, 1))

    for name in ("dfedsgpsm", "dfedsam"):
        algo = make_algo(name, local_steps=3, batch_size=32)
        timings = {}
        for path in ("flat", "pytree"):
            tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                           participation=0.25, flat=(path == "flat"))
            timings[path] = _time_rounds(tr, rounds)
            d = tr.spec.dim
            emit(f"round/{name}/{path}", timings[path],
                 f"n={N_CLIENTS},D={d},rounds={rounds},min")
        emit(f"round/{name}/speedup", timings["pytree"] / timings["flat"],
             "pytree_us/flat_us (>=1 means flat is no slower)")


def _smoke_speedup() -> float:
    """pytree_us / flat_us for the flagship algorithm, min-of-N rounds."""
    net, cdata, _ = build_setting(
        dataset="mnist", n_clients=N_CLIENTS, samples_per_client=128)
    topo = TopologyConfig(
        kind="kout", n_clients=N_CLIENTS, k_out=max(N_CLIENTS // 4, 1))
    algo = make_algo("dfedsgpsm", local_steps=3, batch_size=32)
    timings = {}
    for path in ("flat", "pytree"):
        tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                       participation=0.25, flat=(path == "flat"))
        timings[path] = _time_rounds(tr, 8)
        emit(f"round/smoke/{path}", timings[path], "n=16,rounds=8,min")
    return timings["pytree"] / timings["flat"]


def smoke(record: bool = False) -> int:
    """CI gate: compare the flat path's pytree-relative speedup against the
    recorded baseline.  Absolute round times vary wildly across runners;
    the ratio of the two paths measured back-to-back on the same box does
    not, so a >SMOKE_TOLERANCE drop means the flat path itself regressed.
    ``record`` rewrites the baseline instead (run on a quiet machine)."""
    speedup = _smoke_speedup()
    emit("round/smoke/speedup", speedup, "pytree_us/flat_us")
    if record:
        # Record the MINIMUM of this and any previously recorded speedup —
        # the gate floor must clear runner noise, and a single quiet-box
        # run would otherwise tighten it to the point of flaking.
        note = ("pytree_us/flat_us, min over recorded runs; the gate floor "
                "is speedup/tolerance - repeat --record to widen")
        if os.path.exists(BASELINE):
            with open(BASELINE) as f:
                prev = json.load(f)
            speedup = min(speedup, prev.get("speedup", speedup))
            note = prev.get("note", note)
        with open(BASELINE, "w") as f:
            json.dump({"algo": "dfedsgpsm", "n_clients": N_CLIENTS,
                       "speedup": round(speedup, 4),
                       "tolerance": SMOKE_TOLERANCE, "note": note},
                      f, indent=1)
        print(f"# recorded baseline speedup={speedup:.3f} -> {BASELINE}")
        return 0
    with open(BASELINE) as f:
        base = json.load(f)["speedup"]
    floor = base / SMOKE_TOLERANCE
    verdict = "OK" if speedup >= floor else "REGRESSION"
    print(f"# flat-path gate: speedup={speedup:.3f} baseline={base:.3f} "
          f"floor={floor:.3f} -> {verdict}")
    return 0 if speedup >= floor else 1


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="regression gate vs round_baseline.json (exit 1 "
                         "on >%.1fx flat-path slowdown)" % SMOKE_TOLERANCE)
    ap.add_argument("--record", action="store_true",
                    help="re-record the baseline instead of gating")
    ap.add_argument("--fast", action="store_true",
                    help="fewer timing rounds for the full benchmark")
    args = ap.parse_args()
    if args.smoke or args.record:
        sys.exit(smoke(record=args.record))
    main(fast=args.fast)
