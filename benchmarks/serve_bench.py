"""Personalized decode microbenchmark: many clients' delta-bank models in
one batched greedy decode (the serving half of the low-rank delta bank).

Shape follows the decode-microbenchmark convention: prefill once, then time
the steady-state decode step in isolation (median over timed steps after
explicit warmup) and derive

  ms/step          — one token for ALL clients (the whole multi-model batch
                     is a single XLA dispatch),
  tokens/s         — clients / step_time (one token per client per step),
  GB/s/device      — bytes the step must stream (client-stacked weights +
                     KV caches, read once per token) / step_time / devices:
                     the roofline quantity for memory-bound decode.

The personalization store is a rank-``--rank`` delta bank over the zoo
arch's init weights: each request lane expands ``base + (A @ B) / w`` for a
different client, so the weight traffic above is per-client weights — the
cost full fine-tuning would pay per lane — while the *bank* (what training
gossips, EF buffers, checkpoints and the paged store hold) is only
``d_delta`` floats per client.  The bench also times the training side on
the standard mnist_2nn/16-client setting — one rank-8 delta round vs the
dense full-width round — and reports that ratio next to ``d_delta / D``.

``--smoke`` (default) shrinks the arch config and asserts the paper-facing
criteria: rank-8 ``d_delta`` <= 10% of D on the bench model, and finite
timings.  ``--json bench-serve.json`` writes the table (the CI artifact).

Tuned-launcher environment: same recipe as round_bench.py (tcmalloc,
pinned eigen threads, persistent compilation cache).
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks.common import build_setting, emit

WARMUP = 2


def _nbytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def serve_bench(arch: str, clients: int, prompt_len: int, new_tokens: int,
                rank: int, smoke: bool) -> dict:
    """Time expand / prefill / steady-state decode for ``clients`` distinct
    delta-bank models of the zoo arch; returns the metric table."""
    from repro.configs.registry import get_config
    from repro.core.flat import bind_delta_spec, make_delta_spec
    from repro.launch.steps import make_personalized_serve_step
    from repro.models.registry import get_model_api

    cfg = get_config(arch, smoke=smoke)
    if not cfg.supports_decode():
        raise SystemExit(f"{cfg.name} is encoder-only: no decode path")
    api = get_model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))

    dspec = make_delta_spec(params, rank=rank)
    spec = bind_delta_spec(dspec, params)
    ps = make_personalized_serve_step(api, spec)

    bank = 0.02 * jax.random.normal(jax.random.PRNGKey(3),
                                    (clients, dspec.dim), dspec.dtype)
    w = jnp.ones((clients,), jnp.float32)
    ids = jnp.arange(clients, dtype=jnp.int32)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (clients, prompt_len), 0, cfg.vocab_size)
    cache_len = prompt_len + new_tokens
    batch = {"tokens": prompts}
    if cfg.task == "vlm":
        batch["image_feats"] = jax.random.normal(
            jax.random.PRNGKey(2), (clients, 8, cfg.frontend_dim))
    n_prefix = batch.get("image_feats", jnp.zeros((0, 0))).shape[1]

    expand = jax.jit(ps.expand)
    prefill = jax.jit(ps.prefill, static_argnums=(2,))
    decode = jax.jit(ps.decode_step)  # no donation: steps re-time one cache

    t0 = time.perf_counter()
    stacked = expand(bank, w, ids)
    jax.block_until_ready(stacked)
    expand_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    logits, caches = prefill(stacked, batch, cache_len)
    jax.block_until_ready(logits)
    prefill_s = time.perf_counter() - t0
    toks = logits[:, -1].argmax(-1).astype(jnp.int32)

    # Steady-state decode: compile + WARMUP steps, then median of the rest.
    pos0 = n_prefix + prompt_len
    steps = max(new_tokens - 1, 4)
    times = []
    for i in range(1 + WARMUP + steps):
        pos = jnp.int32(pos0 + min(i, new_tokens - 2))
        t0 = time.perf_counter()
        logits_i, caches = decode(stacked, caches, toks, pos)
        jax.block_until_ready(logits_i)
        if i > WARMUP:
            times.append(time.perf_counter() - t0)
        toks = logits_i.argmax(-1).astype(jnp.int32)
    step_s = statistics.median(times)

    n_dev = jax.device_count()
    stream_bytes = _nbytes(stacked) + _nbytes(caches)
    ms_per_step = 1e3 * step_s
    tokens_per_s = clients / step_s
    gbps_per_device = stream_bytes / step_s / n_dev / 1e9
    d_full = dspec.full.dim
    frac = dspec.dim / d_full

    emit(f"serve/{arch}/expand", 1e6 * expand_s,
         f"clients={clients},rank={rank},d_delta={dspec.dim}"
         f"({100 * frac:.1f}% of D)")
    emit(f"serve/{arch}/prefill", 1e6 * prefill_s,
         f"clients={clients},prompt={prompt_len}")
    emit(f"serve/{arch}/ms_per_step", ms_per_step,
         f"clients={clients},median-of-{steps},one dispatch per token")
    emit(f"serve/{arch}/tokens_per_s", tokens_per_s,
         "clients/step_s (one token per client per step)")
    emit(f"serve/{arch}/gbps_per_device", gbps_per_device,
         f"(stacked weights + KV) / step_s / {n_dev} devices")
    return {"arch": arch, "smoke": smoke, "clients": clients,
            "prompt_len": prompt_len, "new_tokens": new_tokens,
            "rank": rank, "d_delta": dspec.dim, "d_full": d_full,
            "delta_fraction": round(frac, 4),
            "expand_s": round(expand_s, 4),
            "prefill_s": round(prefill_s, 4),
            "ms_per_step": round(ms_per_step, 3),
            "tokens_per_s": round(tokens_per_s, 2),
            "gbps_per_device": round(gbps_per_device, 4),
            "stream_bytes": stream_bytes, "devices": n_dev}


def round_ratio(rank: int, rounds: int) -> dict:
    """Delta-vs-full-rank training round on the standard bench setting:
    the narrower bank must pull its weight where training pays for width
    (gossip, EF residuals, paging)."""
    from repro.core import FLTrainer, TopologyConfig, make_algo

    n = 16
    net, cdata, _ = build_setting(dataset="mnist", n_clients=n,
                                  samples_per_client=128)
    topo = TopologyConfig(kind="kout", n_clients=n, k_out=4)
    algo = make_algo("dfedsgpsm", local_steps=3, batch_size=32)
    timings, dims = {}, {}
    for mode in ("dense", "delta"):
        tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=0,
                       participation=0.25,
                       delta=rank if mode == "delta" else None)
        for _ in range(1 + WARMUP):
            tr.run_round()
        jax.block_until_ready(tr.state.params)
        ts = []
        for _ in range(rounds):
            t0 = time.perf_counter()
            tr.run_round()
            jax.block_until_ready(tr.state.params)
            ts.append(1e6 * (time.perf_counter() - t0))
        timings[mode] = statistics.median(ts)
        dims[mode] = tr.spec.dim
        emit(f"serve/round/{mode}", timings[mode],
             f"n={n},D={dims[mode]},rounds={rounds},median")
    ratio = timings["dense"] / timings["delta"]
    frac = dims["delta"] / dims["dense"]
    emit("serve/round/delta_ratio", ratio,
         f"dense_us/delta_us at rank={rank} "
         f"(d_delta={dims['delta']}, {100 * frac:.1f}% of D)")
    return {"rank": rank, "rounds": rounds, "d_full": dims["dense"],
            "d_delta": dims["delta"], "delta_fraction": round(frac, 4),
            "dense_us": round(timings["dense"], 1),
            "delta_us": round(timings["delta"], 1),
            "delta_ratio": round(ratio, 3)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=5,
                    help="timed rounds per side of the delta-vs-dense "
                         "training ratio")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="shrunk arch + criteria asserts (--no-smoke for "
                         "the full-size arch)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the metric table as JSON (CI uploads "
                         "bench-serve.json as an artifact)")
    args = ap.parse_args(argv)

    serve = serve_bench(args.arch, args.clients, args.prompt_len,
                        args.new_tokens, args.rank, args.smoke)
    ratio = round_ratio(args.rank, args.rounds)
    results = {"serve": serve, "round": ratio}

    if args.smoke:
        # Paper-facing criteria, asserted where CI can see them fail.
        assert ratio["delta_fraction"] <= 0.10, (
            f"rank-{args.rank} delta bank is {ratio['delta_fraction']:.1%} "
            "of D on the bench model; criterion is <= 10%")
        assert all(v > 0 for v in (serve["ms_per_step"],
                                   serve["tokens_per_s"],
                                   serve["gbps_per_device"])), serve
        print(f"# smoke OK: d_delta/D={ratio['delta_fraction']:.3f}, "
              f"{serve['tokens_per_s']:.1f} tokens/s over "
              f"{serve['clients']} personalized clients")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
        print(f"# wrote serve results -> {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
