"""Shared benchmark scaffolding: a scaled-down-but-faithful instance of the
paper's experimental setting (100 clients -> configurable), CSV emission."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import FLTrainer, TopologyConfig, make_algo
from repro.data.dirichlet import dirichlet_partition, stack_client_data
from repro.data.synthetic import make_dataset
from repro.launch.runtime import enable_compilation_cache
from repro.models.small import get_model

# Every bench entrypoint imports this module; cache executables across
# invocations so repeated CI runs stop paying the XLA recompile tax.
enable_compilation_cache()


def build_setting(
    dataset: str = "mnist",
    n_clients: int = 16,
    alpha: float = 0.3,  # Dirichlet; <=0 means IID
    n_train: int = 4000,
    n_test: int = 1000,
    samples_per_client: int = 256,
    model: str | None = None,
    seed: int = 0,
):
    train, test = make_dataset(dataset, n_train, n_test, seed=seed)
    parts = dirichlet_partition(train["y"], n_clients, alpha, seed=seed)
    cdata = stack_client_data(train, parts, pad_to=samples_per_client)
    cdata = {k: jnp.asarray(v) for k, v in cdata.items()}
    testj = {k: jnp.asarray(v) for k, v in test.items()}
    model = model or ("mnist_2nn" if dataset == "mnist" else "cifar_cnn")
    n_classes = 100 if dataset == "cifar100" else 10
    image = (784,) if dataset == "mnist" else (32, 32, 3)
    net = get_model(model, n_classes, image)
    return net, cdata, testj


def run_algo(
    name: str,
    net,
    cdata,
    testj,
    rounds: int = 30,
    n_clients: int = 16,
    participation: float = 0.25,
    local_steps: int = 5,
    seed: int = 0,
    eval_every: int = 0,
    **overrides,
):
    from repro.core import ALGORITHMS

    # D-PSGD/SGP are one-step methods in the paper (K=1); keep that.
    if ALGORITHMS[name].local_steps == 1:
        local_steps = 1
    algo = make_algo(name, local_steps=local_steps, batch_size=32, **overrides)
    topo = TopologyConfig(kind="kout", n_clients=n_clients,
                          k_out=max(int(participation * n_clients), 1))
    tr = FLTrainer(net.loss, net.init, cdata, algo, topo, seed=seed,
                   participation=participation)
    t0 = time.time()
    hist = tr.fit(rounds, test_data=testj if eval_every else None,
                  eval_every=eval_every)
    wall = time.time() - t0
    loss, acc = tr.evaluate(testj)
    return {"algo": name, "acc": acc, "loss": loss, "wall_s": wall,
            "us_per_round": 1e6 * wall / rounds, "history": hist}


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
