"""Benchmark driver — one module per paper table/figure + kernel micro-bench.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only table1,...]

Prints ``name,us_per_call,derived`` CSV rows.  Default mode is scaled down to
finish on a CPU container; --full approaches the paper's setting (100
clients, 300+ rounds) and is intended for real hardware.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    fig1_convergence,
    fig2_ablations,
    kernels_bench,
    round_bench,
    table1_accuracy,
    table2_modules,
)

SUITES = {
    "table1": table1_accuracy.main,
    "fig1": fig1_convergence.main,
    "fig2": fig2_ablations.main,
    "table2": table2_modules.main,
    "kernels": kernels_bench.main,
    "round": round_bench.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale (hours); default is CPU-scaled")
    ap.add_argument("--fast", action="store_true",
                    help="explicit alias for the default CPU-scaled mode")
    ap.add_argument("--only", default=None, help="comma list of suites")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    for name in only:
        t0 = time.time()
        try:
            SUITES[name](fast=not args.full)
        except Exception as e:  # keep the suite going; a failed row is data
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", file=sys.stdout)
        print(f"# suite {name} done in {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
