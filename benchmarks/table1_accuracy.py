"""Paper Table 1: top-1 test accuracy of all 9 algorithms across datasets and
non-IID levels (Dir-0.3 / Dir-0.6 / IID) — scaled-down synthetic setting.

CSV: name,us_per_call,derived  (derived = final test accuracy %).
"""
from __future__ import annotations

from benchmarks.common import build_setting, emit, run_algo

ALGOS = ["fedavg", "dpsgd", "dfedavg", "dfedavgm", "dfedsam", "sgp", "osgp",
         "dfedsgpsm", "dfedsgpsm_s"]


def main(fast: bool = False, datasets=("mnist",), alphas=(0.3, 0.6, 0.0)):
    # 16 clients in both modes: at 8 clients the per-client label skew is
    # extreme enough that momentum(0.9) x 5 local steps at lr 0.1 diverges
    # (measured; the paper's setting is 100 clients).
    rounds = 12 if fast else 25
    n_clients = 16
    results = {}
    for ds in datasets:
        for alpha in alphas:
            net, cdata, testj = build_setting(ds, n_clients=n_clients, alpha=alpha)
            split = f"dir{alpha}" if alpha > 0 else "iid"
            for algo in ALGOS:
                r = run_algo(algo, net, cdata, testj, rounds=rounds,
                             n_clients=n_clients)
                results[(ds, split, algo)] = r["acc"]
                emit(f"table1/{ds}/{split}/{algo}", r["us_per_round"],
                     f"acc={100 * r['acc']:.2f}%")
    return results


if __name__ == "__main__":
    main()
