"""Paper Figure 1: test-accuracy-vs-round convergence curves.

CSV: name,us_per_call,derived (derived = acc@25%,50%,100% of rounds),
plus per-round curves written to benchmarks/out/fig1_<algo>.csv.
"""
from __future__ import annotations

import os

from benchmarks.common import build_setting, emit, run_algo

ALGOS = ["dfedavgm", "dfedsam", "osgp", "dfedsgpsm"]
OUT = os.path.join(os.path.dirname(__file__), "out")


def main(fast: bool = False):
    rounds = 12 if fast else 30
    net, cdata, testj = build_setting("mnist", n_clients=16, alpha=0.3)
    os.makedirs(OUT, exist_ok=True)
    for algo in ALGOS:
        r = run_algo(algo, net, cdata, testj, rounds=rounds, n_clients=16,
                     eval_every=max(rounds // 6, 1))
        curve = [(h["round"], h["test_acc"]) for h in r["history"]
                 if "test_acc" in h]
        with open(os.path.join(OUT, f"fig1_{algo}.csv"), "w") as f:
            f.write("round,test_acc\n")
            f.writelines(f"{r0},{a:.4f}\n" for r0, a in curve)
        marks = ",".join(f"{100 * a:.1f}" for _, a in curve[:3])
        emit(f"fig1/{algo}", r["us_per_round"], f"acc_curve%={marks}")


if __name__ == "__main__":
    main()
