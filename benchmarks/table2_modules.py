"""Paper Table 2: module-augmentation ablation —
OSGP -> +Momentum (DFedSGPM) -> +SAM (DFedSGPSM) -> +Selection (DFedSGPSM-S).
"""
from __future__ import annotations

from benchmarks.common import build_setting, emit, run_algo

LADDER = ["osgp", "dfedsgpm", "dfedsgpsm", "dfedsgpsm_s"]


def main(fast: bool = False):
    rounds = 12 if fast else 25
    net, cdata, testj = build_setting("mnist", n_clients=16, alpha=0.3)
    accs = {}
    for algo in LADDER:
        r = run_algo(algo, net, cdata, testj, rounds=rounds, n_clients=16)
        accs[algo] = r["acc"]
        emit(f"table2/{algo}", r["us_per_round"], f"acc={100 * r['acc']:.2f}%")
    return accs


if __name__ == "__main__":
    main()
