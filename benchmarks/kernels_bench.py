"""Kernel microbenchmarks: Pallas (interpret on CPU / Mosaic on TPU) vs the
pure-jnp oracle, plus derived roofline bytes for the fused update.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import topology as topo
from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))  # compile + sync warmup, any output pytree
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return 1e6 * (time.perf_counter() - t0) / iters


def main(fast: bool = False):
    n, d = 128, 1 << (16 if fast else 20)
    P = topo.sample_kout(jax.random.PRNGKey(0), n, 10)
    X = jax.random.normal(jax.random.PRNGKey(1), (n, d), jnp.float32)
    us_ref = _time(jax.jit(ref.gossip_matmul_ref), P, X)
    emit("kernel/gossip_matmul/ref", us_ref,
         f"n={n},D={d},GB={2 * n * d * 4 / 1e9:.2f}")
    us_pal = _time(lambda p, x: ops.gossip_matmul(p, x), P, X)
    emit("kernel/gossip_matmul/pallas", us_pal, "interpret" if not ops.on_tpu() else "mosaic")

    # Sparse neighbor-indexed gossip on the same bank: O(n*k*D) vs O(n^2*D).
    nl = topo.sample_kout_neighbors(jax.random.PRNGKey(0), n, 10)
    us_sref = _time(jax.jit(ref.gossip_gather_ref), nl.idx, nl.wgt, X)
    emit("kernel/gossip_gather/ref", us_sref,
         f"n={n},k_max={nl.idx.shape[1]},D={d}")
    us_spal = _time(lambda i, w, x: ops.gossip_gather(i, w, x),
                    nl.idx, nl.wgt, X)
    emit("kernel/gossip_gather/pallas", us_spal,
         "panelized-interpret" if not ops.on_tpu() else "mosaic")
    emit("kernel/gossip_gather/vs_dense", us_pal / us_spal,
         "dense_us/sparse_us at k/n=%.2f" % (nl.idx.shape[1] / n))

    D = 1 << (18 if fast else 22)
    x = jax.random.normal(jax.random.PRNGKey(0), (D,))
    v = jnp.zeros((D,))
    g = jax.random.normal(jax.random.PRNGKey(1), (D,))
    us_ref = _time(jax.jit(lambda *a: ref.fused_update_ref(*a, 0.9, 0.1, 1.1)), x, v, g)
    hbm_bytes = 6 * D * 4
    emit("kernel/fused_update/ref", us_ref, f"D={D},bytes={hbm_bytes}")
    us_pal = _time(lambda *a: ops.fused_update(*a, 0.9, 0.1, 1.1), x, v, g)
    emit("kernel/fused_update/pallas", us_pal,
         f"roofline_us@819GBps={1e6 * hbm_bytes / 819e9:.1f}")

    B, H, S, hd = 1, 4, 512 if fast else 1024, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, S, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, hd), jnp.float32)
    vv = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, hd), jnp.float32)
    us_ref = _time(jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c)), q, k, vv)
    flops = 4 * B * H * S * S * hd
    emit("kernel/flash_attention/ref", us_ref, f"S={S},GFLOP={flops / 1e9:.1f}")
    us_pal = _time(lambda a, b, c: ops.flash_attention(a, b, c), q, k, vv)
    emit("kernel/flash_attention/pallas", us_pal,
         "interpret-mode-correctness" if not ops.on_tpu() else "mosaic")


if __name__ == "__main__":
    main()
