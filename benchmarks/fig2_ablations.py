"""Paper Figure 2: hyperparameter sensitivity of DFedSGPSM —
(a) momentum coefficient alpha, (b) client participation ratio,
(c) SAM perturbation radius rho.
"""
from __future__ import annotations

from benchmarks.common import build_setting, emit, run_algo


def main(fast: bool = False):
    rounds = 10 if fast else 20
    net, cdata, testj = build_setting("mnist", n_clients=16, alpha=0.3)

    for a in (0.1, 0.5, 0.7, 0.9):
        r = run_algo("dfedsgpsm", net, cdata, testj, rounds=rounds,
                     n_clients=16, alpha=a)
        emit(f"fig2a/alpha={a}", r["us_per_round"], f"acc={100 * r['acc']:.2f}%")

    for ratio in (0.125, 0.25, 0.5):
        r = run_algo("dfedsgpsm", net, cdata, testj, rounds=rounds,
                     n_clients=16, participation=ratio)
        emit(f"fig2b/participation={ratio}", r["us_per_round"],
             f"acc={100 * r['acc']:.2f}%")

    for rho in (0.05, 0.1, 0.2, 0.3):
        r = run_algo("dfedsgpsm", net, cdata, testj, rounds=rounds,
                     n_clients=16, rho=rho)
        emit(f"fig2c/rho={rho}", r["us_per_round"], f"acc={100 * r['acc']:.2f}%")


if __name__ == "__main__":
    main()
